"""AOT export: lower every L2 entry point to HLO *text* + write the manifest.

HLO text (not ``.serialize()``) is the interchange format: jax >= 0.5 emits
HloModuleProto with 64-bit instruction ids which the runtime's xla_extension
0.5.1 rejects; the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/gen_hlo.py). Lowering uses ``return_tuple=True``; the rust
side unwraps with ``to_tuple()``.

Run once via ``make artifacts``; python never executes on the tuning path.

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import dims, model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def i32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.int32)


def entry_points():
    """(name, fn, example_args) for every exported graph."""
    P, V = dims.P_POLICY, dims.P_VALUE
    B, BT, T = dims.B_POL, dims.B_TRAIN, dims.T_GAE
    return [
        (
            "policy_forward",
            model.policy_forward_flat,
            (f32(P), f32(B, dims.OBS_DIM), f32(dims.ACT_DIM)),
        ),
        (
            "value_forward",
            model.value_forward_flat,
            (f32(V), f32(B, dims.GSTATE_DIM)),
        ),
        (
            "gae",
            model.gae_flat,
            (f32(T), f32(T), f32(1), f32(2)),
        ),
        (
            "policy_train",
            model.policy_train_step,
            (
                f32(P), f32(P), f32(P), f32(1),
                f32(BT, dims.OBS_DIM), f32(dims.ACT_DIM),
                i32(BT), f32(BT), f32(BT), f32(BT),
            ),
        ),
        (
            "value_train",
            model.value_train_step,
            (
                f32(V), f32(V), f32(V), f32(1),
                f32(BT, dims.GSTATE_DIM), f32(BT), f32(BT),
            ),
        ),
    ]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "dims": {
            "obs_dim": dims.OBS_DIM,
            "act_dim": dims.ACT_DIM,
            "gstate_dim": dims.GSTATE_DIM,
            "hidden": dims.HIDDEN,
            "b_pol": dims.B_POL,
            "b_train": dims.B_TRAIN,
            "t_gae": dims.T_GAE,
            "p_policy": dims.P_POLICY,
            "p_value": dims.P_VALUE,
        },
        "hyper": {
            "clip_eps": model.CLIP_EPS,
            "entropy_coef": model.ENTROPY_COEF,
            "lr_policy": model.LR_POLICY,
            "lr_value": model.LR_VALUE,
            "max_grad_norm": model.MAX_GRAD_NORM,
        },
        "artifacts": {},
    }

    for name, fn, example in entry_points():
        lowered = jax.jit(fn).lower(*example)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": fname,
            "sha256_16": digest,
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
        f.write("\n")
    print(f"wrote {os.path.join(args.out_dir, 'manifest.json')}")


if __name__ == "__main__":
    main()
