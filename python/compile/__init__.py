"""ARCO build-time compile package (never imported at runtime)."""
