"""Static shape contract between the L2 JAX graphs and the L3 rust runtime.

AOT compilation fixes every shape at lowering time; these constants are the
single source of truth. `aot.py` copies them into ``artifacts/manifest.json``
so the rust coordinator never hardcodes them.
"""

# Observation fed to each agent's policy: normalized knob settings (7),
# agent one-hot (3), last reward, best-so-far, step fraction, occupancy,
# area ratio + 2 spare slots = 16.
OBS_DIM = 16

# Padded action space: the hardware agent steps 3 knobs x {dec,stay,inc}
# = 27 joint actions; the two software agents use 9 of the 27 via masks.
ACT_DIM = 27

# Global state for the centralized critic: concat of per-agent summaries
# plus task descriptors.
GSTATE_DIM = 24

# Hidden width of every MLP (paper §4.1: 20 neurons).
HIDDEN = 20

# Policy/value forward batch (candidate-set scoring); rust pads to this.
B_POL = 64

# PPO train-step minibatch; rust pads rollout slices to this.
B_TRAIN = 256

# GAE horizon (covers the paper's step_rl=500).
T_GAE = 512

# Parameter counts (flattened per layer: W row-major then b).
P_POLICY = (OBS_DIM * HIDDEN + HIDDEN) + (HIDDEN * ACT_DIM + ACT_DIM)
P_VALUE = (
    (GSTATE_DIM * HIDDEN + HIDDEN)
    + 2 * (HIDDEN * HIDDEN + HIDDEN)
    + (HIDDEN * 1 + 1)
)

assert P_POLICY == 907
assert P_VALUE == 1361
