"""L2: the MAPPO compute graphs (§2.2, Eqs. 1-3), built on the L1 kernels.

Entry points (all functions of flat f32 parameter vectors, matching the
rust-side flattening order: per layer, weights row-major then bias):

- ``policy_forward_flat``  — fused Pallas policy MLP + masked log-softmax
- ``value_forward_flat``   — fused Pallas critic MLP
- ``policy_train_step``    — PPO-clip actor update (loss, jax.grad, Adam)
- ``value_train_step``     — critic MSE update (Eq. 1)
- ``gae_flat``             — Pallas GAE kernel (Eq. 2)

Train steps use the pure-jnp ref math (pallas_call has no autodiff rule),
which the kernel tests pin to the kernels; forwards use the kernels
themselves, so the exported HLO exercises the Pallas path where it matters:
candidate scoring is the hot call (thousands per tuning iteration),
updates run once per iteration.

Hyper-parameters (clip epsilon, entropy coef, Adam lr, grad clip) are baked
into the lowered HLO as compile-time constants, mirroring how the paper
fixes them per run (Table 4); `aot.py` records them in the manifest.
"""

import jax
import jax.numpy as jnp

from . import dims
from .kernels import gae_pallas, mlp_pallas, ref

# --- Baked hyper-parameters (MAPPO paper defaults; Table 4 pipeline) -------
CLIP_EPS = 0.2
ENTROPY_COEF = 0.01
LR_POLICY = 5e-3
LR_VALUE = 5e-3
ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
MAX_GRAD_NORM = 10.0


# --- Parameter (un)flattening ----------------------------------------------

def policy_shapes():
    return [
        (dims.OBS_DIM, dims.HIDDEN),
        (dims.HIDDEN,),
        (dims.HIDDEN, dims.ACT_DIM),
        (dims.ACT_DIM,),
    ]


def value_shapes():
    return [
        (dims.GSTATE_DIM, dims.HIDDEN),
        (dims.HIDDEN,),
        (dims.HIDDEN, dims.HIDDEN),
        (dims.HIDDEN,),
        (dims.HIDDEN, dims.HIDDEN),
        (dims.HIDDEN,),
        (dims.HIDDEN, 1),
        (1,),
    ]


def unflatten(flat, shapes):
    out = []
    off = 0
    for s in shapes:
        n = 1
        for d in s:
            n *= d
        out.append(jnp.reshape(flat[off:off + n], s))
        off += n
    return out


# --- Forward entry points (Pallas path) -------------------------------------

def policy_forward_flat(params, obs, mask):
    """params: (P_POLICY,), obs: (B, OBS_DIM), mask: (ACT_DIM,).

    Returns masked log-probs (B, ACT_DIM).
    """
    w1, b1, w2, b2 = unflatten(params, policy_shapes())
    logits = mlp_pallas.policy_forward(obs, w1, b1, w2, b2)
    return ref.masked_log_softmax_ref(logits, mask)


def value_forward_flat(params, state):
    """params: (P_VALUE,), state: (B, GSTATE_DIM). Returns values (B,)."""
    w1, b1, w2, b2, w3, b3, w4, b4 = unflatten(params, value_shapes())
    return mlp_pallas.value_forward(state, w1, b1, w2, b2, w3, b3, w4, b4)


def gae_flat(rewards, values, bootstrap, gamma_lam):
    """Pallas GAE over a fixed T_GAE horizon."""
    return gae_pallas.gae(rewards, values, bootstrap, gamma_lam)


# --- Train-step entry points (jnp ref math + jax.grad + Adam) ---------------

def _policy_forward_ref_flat(params, obs, mask):
    w1, b1, w2, b2 = unflatten(params, policy_shapes())
    logits = ref.policy_forward_ref(obs, w1, b1, w2, b2)
    return ref.masked_log_softmax_ref(logits, mask)


def _value_forward_ref_flat(params, state):
    ws_bs = unflatten(params, value_shapes())
    ws = ws_bs[0::2]
    bs = ws_bs[1::2]
    return ref.value_forward_ref(state, ws, bs)


def _ppo_loss(params, obs, mask, actions, old_logp, adv, weight):
    """Mean PPO-clip surrogate + entropy bonus over weighted rows (Eq. 3)."""
    logp_all = _policy_forward_ref_flat(params, obs, mask)
    logp = jnp.take_along_axis(logp_all, actions[:, None], axis=1)[:, 0]
    ratio = jnp.exp(logp - old_logp)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - CLIP_EPS, 1.0 + CLIP_EPS) * adv
    surrogate = jnp.minimum(unclipped, clipped)
    probs = jnp.where(mask > 0, jnp.exp(logp_all), 0.0)
    ent = -jnp.sum(jnp.where(probs > 0, probs * jnp.log(jnp.maximum(probs, 1e-30)), 0.0), axis=1)
    wsum = jnp.maximum(jnp.sum(weight), 1.0)
    loss = -jnp.sum(surrogate * weight) / wsum - ENTROPY_COEF * jnp.sum(ent * weight) / wsum
    clip_frac = jnp.sum((unclipped > clipped).astype(jnp.float32) * weight) / wsum
    return loss, (jnp.sum(ent * weight) / wsum, clip_frac)


def _adam_update(params, grads, m, v, t, lr):
    """One Adam step with global-norm clipping; returns new (params, m, v, t)."""
    norm = jnp.sqrt(jnp.sum(grads * grads))
    scale = jnp.minimum(1.0, MAX_GRAD_NORM / jnp.maximum(norm, 1e-12))
    grads = grads * scale
    t_new = t + 1.0
    m_new = ADAM_B1 * m + (1.0 - ADAM_B1) * grads
    v_new = ADAM_B2 * v + (1.0 - ADAM_B2) * grads * grads
    mhat = m_new / (1.0 - ADAM_B1 ** t_new)
    vhat = v_new / (1.0 - ADAM_B2 ** t_new)
    params_new = params - lr * mhat / (jnp.sqrt(vhat) + ADAM_EPS)
    return params_new, m_new, v_new, t_new


def policy_train_step(params, m, v, t, obs, mask, actions, old_logp, adv, weight):
    """One PPO-clip update of an agent's policy.

    Shapes: params/m/v (P_POLICY,); t (1,); obs (B_TRAIN, OBS_DIM);
    mask (ACT_DIM,); actions (B_TRAIN,) i32; old_logp/adv/weight (B_TRAIN,).
    Returns (params', m', v', t', loss, entropy, clip_frac).
    """
    (loss, (entropy, clip_frac)), grads = jax.value_and_grad(_ppo_loss, has_aux=True)(
        params, obs, mask, actions, old_logp, adv, weight
    )
    params_n, m_n, v_n, t_n = _adam_update(params, grads, m, v, t[0], LR_POLICY)
    return (
        params_n,
        m_n,
        v_n,
        jnp.reshape(t_n, (1,)),
        jnp.reshape(loss, (1,)),
        jnp.reshape(entropy, (1,)),
        jnp.reshape(clip_frac, (1,)),
    )


def _value_loss(params, state, returns, weight):
    pred = _value_forward_ref_flat(params, state)
    err = pred - returns
    wsum = jnp.maximum(jnp.sum(weight), 1.0)
    return jnp.sum(err * err * weight) / wsum


def value_train_step(params, m, v, t, state, returns, weight):
    """One critic MSE update (Eq. 1). Returns (params', m', v', t', loss)."""
    loss, grads = jax.value_and_grad(_value_loss)(params, state, returns, weight)
    params_n, m_n, v_n, t_n = _adam_update(params, grads, m, v, t[0], LR_VALUE)
    return params_n, m_n, v_n, jnp.reshape(t_n, (1,)), jnp.reshape(loss, (1,))
