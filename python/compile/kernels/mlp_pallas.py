"""L1 Pallas kernels: fused MLP forward passes.

The MARL hot-spot is scoring batches of candidate configurations with the
policy and (for Confidence Sampling, Algorithm 2 line 2) the critic. These
kernels fuse the whole MLP — every matmul, bias and nonlinearity — into one
Pallas program so the intermediate activations never leave VMEM.

TPU adaptation notes (DESIGN.md §Hardware-Adaptation):

- the grid is 1-D over batch blocks; each program computes a
  ``(BLOCK_B, features)`` tile.
- the weight operands use "load whole" BlockSpecs (``None`` grid mapping):
  20-wide layers are a few KiB and live in VMEM for the kernel's lifetime.
- matmuls request ``preferred_element_type=f32`` so lowering targets the
  MXU with f32 accumulation.
- everything here runs with ``interpret=True``: the CPU PJRT plugin cannot
  execute Mosaic custom-calls, and the AOT HLO must load in the rust
  runtime. On a real TPU the same kernels compile unchanged.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile: small networks, modest batches — one VMEM-friendly block.
BLOCK_B = 32


def _policy_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, out_ref):
    """One batch block: logits = relu(x@w1+b1) @ w2 + b2."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...]
    h = jnp.maximum(h, 0.0)
    out = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...]
    out_ref[...] = out


@functools.partial(jax.jit, static_argnames=())
def policy_forward(x, w1, b1, w2, b2):
    """Fused policy-MLP forward.

    x: (B, OBS) f32; w1: (OBS, H); b1: (H,); w2: (H, A); b2: (A,).
    Returns logits (B, A) f32. B must be a multiple of BLOCK_B (rust pads).
    """
    B, obs = x.shape
    H = w1.shape[1]
    A = w2.shape[1]
    assert B % BLOCK_B == 0, f"batch {B} not a multiple of {BLOCK_B}"
    grid = (B // BLOCK_B,)
    return pl.pallas_call(
        _policy_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, obs), lambda i: (i, 0)),
            pl.BlockSpec((obs, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, A), lambda i: (0, 0)),
            pl.BlockSpec((A,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, A), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, A), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2)


def _value_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, w4_ref, b4_ref, out_ref):
    """One batch block of the critic: 3x tanh hidden, scalar head."""
    h = x_ref[...]
    h = jnp.tanh(jnp.dot(h, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...])
    h = jnp.tanh(jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32) + b2_ref[...])
    h = jnp.tanh(jnp.dot(h, w3_ref[...], preferred_element_type=jnp.float32) + b3_ref[...])
    v = jnp.dot(h, w4_ref[...], preferred_element_type=jnp.float32) + b4_ref[...]
    out_ref[...] = v


@functools.partial(jax.jit, static_argnames=())
def value_forward(x, w1, b1, w2, b2, w3, b3, w4, b4):
    """Fused critic forward. x: (B, GSTATE); returns (B,) f32."""
    B, gs = x.shape
    H = w1.shape[1]
    assert B % BLOCK_B == 0, f"batch {B} not a multiple of {BLOCK_B}"
    grid = (B // BLOCK_B,)
    out = pl.pallas_call(
        _value_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_B, gs), lambda i: (i, 0)),
            pl.BlockSpec((gs, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, H), lambda i: (0, 0)),
            pl.BlockSpec((H,), lambda i: (0,)),
            pl.BlockSpec((H, 1), lambda i: (0, 0)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, 1), jnp.float32),
        interpret=True,
    )(x, w1, b1, w2, b2, w3, b3, w4, b4)
    return out[:, 0]


def vmem_footprint_bytes(obs_dim, act_dim, gstate_dim, hidden):
    """Estimated per-program VMEM working set (f32), for DESIGN.md §Perf."""
    policy = (
        BLOCK_B * obs_dim  # x tile
        + obs_dim * hidden + hidden  # layer 1
        + hidden * act_dim + act_dim  # layer 2
        + BLOCK_B * hidden  # activations
        + BLOCK_B * act_dim  # out tile
    ) * 4
    value = (
        BLOCK_B * gstate_dim
        + gstate_dim * hidden + hidden
        + 2 * (hidden * hidden + hidden)
        + hidden + 1
        + 3 * BLOCK_B * hidden
        + BLOCK_B
    ) * 4
    return {"policy": policy, "value": value}
