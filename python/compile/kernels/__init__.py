"""L1 Pallas kernels and their pure-jnp oracles."""
