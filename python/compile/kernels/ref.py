"""Pure-jnp oracles for every Pallas kernel (the correctness contract).

Each ``*_ref`` function is the mathematically-plain definition; pytest
(``python/tests/test_kernels.py``) sweeps shapes/dtypes with hypothesis and
asserts the Pallas kernels match to float32 tolerance. The L2 train-step
graphs also use these definitions directly (autodiff needs jnp, not Pallas
calls), so kernel == ref is what keeps inference and training consistent.
"""

import jax
import jax.numpy as jnp


def dense(x, w, b):
    """x @ w + b with f32 accumulation."""
    return jnp.dot(x, w, preferred_element_type=jnp.float32) + b


def policy_forward_ref(x, w1, b1, w2, b2):
    """Policy MLP: one 20-wide ReLU hidden layer, linear logits head."""
    h = jnp.maximum(dense(x, w1, b1), 0.0)
    return dense(h, w2, b2)


def value_forward_ref(x, ws, bs):
    """Centralized critic: three tanh hidden layers, scalar head.

    ``ws``/``bs`` are length-4 lists (3 hidden + head).
    """
    h = x
    for w, b in zip(ws[:-1], bs[:-1]):
        h = jnp.tanh(dense(h, w, b))
    return dense(h, ws[-1], bs[-1])[:, 0]


def masked_log_softmax_ref(logits, mask):
    """Log-softmax over the unmasked action columns; masked cols -> large-neg."""
    neg = jnp.float32(-1e30)
    masked = jnp.where(mask > 0, logits, neg)
    m = jnp.max(masked, axis=-1, keepdims=True)
    z = jnp.sum(jnp.where(mask > 0, jnp.exp(masked - m), 0.0), axis=-1, keepdims=True)
    lse = m + jnp.log(z)
    return jnp.where(mask > 0, logits - lse, neg)


def gae_ref(rewards, values, bootstrap, gamma, lam):
    """Generalized Advantage Estimation, reverse recurrence (Eq. 2).

    Returns (advantages, returns).
    """
    next_values = jnp.concatenate([values[1:], jnp.reshape(bootstrap, (1,))])
    deltas = rewards + gamma * next_values - values

    def step(carry, delta):
        acc = delta + gamma * lam * carry
        return acc, acc

    _, rev_adv = jax.lax.scan(step, jnp.zeros((), deltas.dtype), deltas[::-1])
    adv = rev_adv[::-1]
    return adv, adv + values
