"""L1 Pallas kernel: Generalized Advantage Estimation (Eq. 2).

A single-program sequential kernel: GAE is a strict reverse recurrence
(adv[t] = delta[t] + gamma*lam*adv[t+1]), so the kernel keeps the whole
horizon (T_GAE=512 f32 = 2 KiB per array) resident in VMEM and runs one
fori_loop backwards. On TPU the win over the jnp version is avoiding T
separate scan-step dispatches; under interpret=True it is validated for
numerics only.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gae_kernel(rew_ref, val_ref, boot_ref, gl_ref, adv_ref, ret_ref):
    T = rew_ref.shape[0]
    gamma = gl_ref[0]
    lam = gl_ref[1]

    def body(i, acc):
        t = T - 1 - i
        next_v = jnp.where(t + 1 < T, val_ref[jnp.minimum(t + 1, T - 1)], boot_ref[0])
        delta = rew_ref[t] + gamma * next_v - val_ref[t]
        acc = delta + gamma * lam * acc
        adv_ref[t] = acc
        ret_ref[t] = acc + val_ref[t]
        return acc

    jax.lax.fori_loop(0, T, body, jnp.float32(0.0))


@functools.partial(jax.jit, static_argnames=())
def gae(rewards, values, bootstrap, gamma_lam):
    """rewards/values: (T,) f32; bootstrap: (1,) f32; gamma_lam: (2,) f32.

    Returns (advantages, returns), each (T,) f32.
    """
    T = rewards.shape[0]
    return pl.pallas_call(
        _gae_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((T,), jnp.float32),
            jax.ShapeDtypeStruct((T,), jnp.float32),
        ),
        interpret=True,
    )(rewards, values, bootstrap, gamma_lam)
