"""L2 graph tests: train steps behave like RL updates should, and the AOT
entry points lower to HLO cleanly."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, dims, model

jax.config.update("jax_platform_name", "cpu")


def init_policy_params(seed):
    return 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (dims.P_POLICY,), jnp.float32)


def init_value_params(seed):
    return 0.1 * jax.random.normal(jax.random.PRNGKey(seed), (dims.P_VALUE,), jnp.float32)


def full_mask():
    return jnp.ones((dims.ACT_DIM,), jnp.float32)


class TestPolicyTrain:
    def _batch(self, seed, b=dims.B_TRAIN):
        ks = jax.random.split(jax.random.PRNGKey(seed), 4)
        obs = jax.random.normal(ks[0], (b, dims.OBS_DIM), jnp.float32)
        actions = jax.random.randint(ks[1], (b,), 0, dims.ACT_DIM)
        adv = jax.random.normal(ks[2], (b,), jnp.float32)
        weight = jnp.ones((b,), jnp.float32)
        return obs, actions, adv, weight

    def test_update_changes_params_and_improves_surrogate(self):
        params = init_policy_params(0)
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        t = jnp.zeros((1,), jnp.float32)
        obs, actions, adv, weight = self._batch(1)
        mask = full_mask()
        lp = model.policy_forward_flat(params, jnp.tile(obs[: dims.B_POL], (1, 1)), mask)
        del lp
        old_logp = jax.vmap(lambda o, a: model._policy_forward_ref_flat(params, o[None], mask)[0, a])(
            obs, actions
        )
        losses = []
        for _ in range(10):
            params, m, v, t, loss, ent, cf = model.policy_train_step(
                params, m, v, t, obs, mask, actions, old_logp, adv, weight
            )
            losses.append(float(loss[0]))
        assert losses[-1] < losses[0], losses
        assert float(t[0]) == 10.0

    def test_padded_rows_do_not_contribute(self):
        params = init_policy_params(3)
        zeros = jnp.zeros_like(params)
        t = jnp.zeros((1,), jnp.float32)
        obs, actions, adv, _ = self._batch(2)
        mask = full_mask()
        old_logp = jax.vmap(lambda o, a: model._policy_forward_ref_flat(params, o[None], mask)[0, a])(
            obs, actions
        )
        half = dims.B_TRAIN // 2
        w_half = jnp.concatenate([jnp.ones(half), jnp.zeros(half)]).astype(jnp.float32)

        # Same update from (a) first half weighted, garbage in second half,
        # (b) first half weighted, different garbage.
        obs_b = obs.at[half:].set(123.0)
        adv_b = adv.at[half:].set(-99.0)
        p_a = model.policy_train_step(params, zeros, zeros, t, obs, mask, actions, old_logp, adv, w_half)[0]
        p_b = model.policy_train_step(params, zeros, zeros, t, obs_b, mask, actions, old_logp, adv_b, w_half)[0]
        np.testing.assert_allclose(np.asarray(p_a), np.asarray(p_b), rtol=1e-5, atol=1e-6)

    def test_masked_actions_never_gain_probability_mass(self):
        params = init_policy_params(4)
        mask = np.ones(dims.ACT_DIM, np.float32)
        mask[9:] = 0.0  # software agent: only 9 legal actions
        mask = jnp.asarray(mask)
        obs = jax.random.normal(jax.random.PRNGKey(5), (dims.B_POL, dims.OBS_DIM), jnp.float32)
        lp = model.policy_forward_flat(params, obs, mask)
        p = np.exp(np.asarray(lp))
        assert p[:, 9:].max() < 1e-20
        np.testing.assert_allclose(p[:, :9].sum(axis=1), np.ones(dims.B_POL), rtol=1e-5)


class TestValueTrain:
    def test_regresses_to_targets(self):
        params = init_value_params(7)
        m = jnp.zeros_like(params)
        v = jnp.zeros_like(params)
        t = jnp.zeros((1,), jnp.float32)
        state = jax.random.normal(jax.random.PRNGKey(8), (dims.B_TRAIN, dims.GSTATE_DIM), jnp.float32)
        returns = jnp.tanh(state[:, 0]) * 2.0
        weight = jnp.ones((dims.B_TRAIN,), jnp.float32)
        first = None
        last = None
        for _ in range(150):
            params, m, v, t, loss = model.value_train_step(params, m, v, t, state, returns, weight)
            last = float(loss[0])
            if first is None:
                first = last
        assert last < first * 0.3, (first, last)


class TestAotExport:
    @pytest.mark.parametrize("name,fn,example", aot.entry_points(), ids=lambda e: str(e)[:24])
    def test_every_entry_point_lowers(self, name, fn, example):
        lowered = jax.jit(fn).lower(*example)
        text = aot.to_hlo_text(lowered)
        assert "ENTRY" in text
        assert len(text) > 500

    def test_manifest_dims_match(self):
        eps = {name: example for name, _, example in aot.entry_points()}
        pf = eps["policy_forward"]
        assert pf[0].shape == (dims.P_POLICY,)
        assert pf[1].shape == (dims.B_POL, dims.OBS_DIM)
        pt = eps["policy_train"]
        assert pt[4].shape == (dims.B_TRAIN, dims.OBS_DIM)
        g = eps["gae"]
        assert g[0].shape == (dims.T_GAE,)


class TestParamFlattening:
    def test_policy_unflatten_layout(self):
        # The flat layout must be: W1 row-major, b1, W2 row-major, b2 —
        # the exact order rust's Mlp::flatten produces.
        flat = jnp.arange(dims.P_POLICY, dtype=jnp.float32)
        w1, b1, w2, b2 = model.unflatten(flat, model.policy_shapes())
        assert w1.shape == (dims.OBS_DIM, dims.HIDDEN)
        assert float(w1[0, 0]) == 0.0
        assert float(w1[0, 1]) == 1.0  # row-major
        nb1 = dims.OBS_DIM * dims.HIDDEN
        assert float(b1[0]) == nb1
        assert float(w2[0, 0]) == nb1 + dims.HIDDEN

    def test_value_param_count(self):
        shapes = model.value_shapes()
        total = sum(int(np.prod(s)) for s in shapes)
        assert total == dims.P_VALUE
