"""L1 kernel correctness: Pallas vs pure-jnp oracle.

Hypothesis sweeps batch sizes, value magnitudes and seeds; assert_allclose
against ref.py is THE correctness signal for the kernels that end up inside
the AOT artifacts.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import dims
from compile.kernels import gae_pallas, mlp_pallas, ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, *shape, scale=1.0):
    return scale * jax.random.normal(key, shape, dtype=jnp.float32)


def policy_params(seed, obs=dims.OBS_DIM, hid=dims.HIDDEN, act=dims.ACT_DIM):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    return (
        rand(ks[0], obs, hid, scale=0.5),
        rand(ks[1], hid, scale=0.1),
        rand(ks[2], hid, act, scale=0.5),
        rand(ks[3], act, scale=0.1),
    )


def value_params(seed, gs=dims.GSTATE_DIM, hid=dims.HIDDEN):
    ks = jax.random.split(jax.random.PRNGKey(seed), 8)
    return (
        rand(ks[0], gs, hid, scale=0.5), rand(ks[1], hid, scale=0.1),
        rand(ks[2], hid, hid, scale=0.5), rand(ks[3], hid, scale=0.1),
        rand(ks[4], hid, hid, scale=0.5), rand(ks[5], hid, scale=0.1),
        rand(ks[6], hid, 1, scale=0.5), rand(ks[7], 1, scale=0.1),
    )


class TestPolicyKernel:
    @settings(max_examples=20, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        blocks=st.integers(1, 4),
        scale=st.sampled_from([0.01, 1.0, 10.0]),
    )
    def test_matches_ref(self, seed, blocks, scale):
        B = blocks * mlp_pallas.BLOCK_B
        w1, b1, w2, b2 = policy_params(seed)
        x = rand(jax.random.PRNGKey(seed + 1), B, dims.OBS_DIM, scale=scale)
        got = mlp_pallas.policy_forward(x, w1, b1, w2, b2)
        want = ref.policy_forward_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_rejects_unpadded_batch(self):
        w1, b1, w2, b2 = policy_params(0)
        x = jnp.zeros((7, dims.OBS_DIM), jnp.float32)
        with pytest.raises(AssertionError):
            mlp_pallas.policy_forward(x, w1, b1, w2, b2)

    def test_zero_input_gives_bias_path(self):
        w1, b1, w2, b2 = policy_params(3)
        x = jnp.zeros((mlp_pallas.BLOCK_B, dims.OBS_DIM), jnp.float32)
        got = mlp_pallas.policy_forward(x, w1, b1, w2, b2)
        want = ref.policy_forward_ref(x, w1, b1, w2, b2)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
        # All rows identical.
        np.testing.assert_allclose(got[0], got[-1], rtol=0, atol=0)


class TestValueKernel:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 3))
    def test_matches_ref(self, seed, blocks):
        B = blocks * mlp_pallas.BLOCK_B
        params = value_params(seed)
        x = rand(jax.random.PRNGKey(seed + 9), B, dims.GSTATE_DIM)
        got = mlp_pallas.value_forward(x, *params)
        ws = list(params[0::2])
        bs = list(params[1::2])
        want = ref.value_forward_ref(x, ws, bs)
        assert got.shape == (B,)
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    def test_outputs_bounded_by_tanh_head(self):
        # tanh hidden keeps activations in [-1, 1]; head is linear, so
        # |v| <= ||w4||_1 + |b4|.
        params = value_params(5)
        x = rand(jax.random.PRNGKey(6), mlp_pallas.BLOCK_B, dims.GSTATE_DIM, scale=100.0)
        v = mlp_pallas.value_forward(x, *params)
        bound = float(jnp.sum(jnp.abs(params[6])) + jnp.abs(params[7])[0]) + 1e-4
        assert np.all(np.abs(np.asarray(v)) <= bound)


class TestGaeKernel:
    @settings(max_examples=25, deadline=None)
    @given(
        seed=st.integers(0, 2**31 - 1),
        t=st.sampled_from([4, 16, 100, dims.T_GAE]),
        gamma=st.sampled_from([0.0, 0.9, 0.99, 1.0]),
        lam=st.sampled_from([0.0, 0.95, 1.0]),
    )
    def test_matches_ref(self, seed, t, gamma, lam):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        rewards = rand(ks[0], t)
        values = rand(ks[1], t)
        boot = rand(ks[2], 1)
        gl = jnp.array([gamma, lam], jnp.float32)
        adv, ret = gae_pallas.gae(rewards, values, boot, gl)
        adv_ref, ret_ref = ref.gae_ref(rewards, values, boot[0], gamma, lam)
        np.testing.assert_allclose(adv, adv_ref, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(ret, ret_ref, rtol=1e-4, atol=1e-4)

    def test_zero_rewards_zero_values(self):
        t = 16
        z = jnp.zeros((t,), jnp.float32)
        adv, ret = gae_pallas.gae(z, z, jnp.zeros((1,)), jnp.array([0.9, 0.95], jnp.float32))
        np.testing.assert_allclose(adv, np.zeros(t), atol=0)
        np.testing.assert_allclose(ret, np.zeros(t), atol=0)

    def test_terminal_reward_discounts_backward(self):
        t = 3
        rewards = jnp.array([0.0, 0.0, 1.0], jnp.float32)
        values = jnp.zeros((t,), jnp.float32)
        adv, _ = gae_pallas.gae(
            rewards, values, jnp.zeros((1,)), jnp.array([0.9, 1.0], jnp.float32)
        )
        np.testing.assert_allclose(adv, [0.81, 0.9, 1.0], rtol=1e-6)


class TestMaskedLogSoftmax:
    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), n_masked=st.integers(0, dims.ACT_DIM - 1))
    def test_normalizes_over_unmasked(self, seed, n_masked):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        logits = rand(k1, 8, dims.ACT_DIM, scale=3.0)
        mask = np.ones(dims.ACT_DIM, np.float32)
        idx = jax.random.permutation(k2, dims.ACT_DIM)[:n_masked]
        mask[np.asarray(idx)] = 0.0
        lp = ref.masked_log_softmax_ref(logits, jnp.asarray(mask))
        p = np.where(mask > 0, np.exp(np.asarray(lp)), 0.0)
        np.testing.assert_allclose(p.sum(axis=1), np.ones(8), rtol=1e-5)
        assert np.all(np.asarray(lp)[:, mask == 0.0] <= -1e29)


class TestVmemFootprint:
    def test_fits_tpu_vmem(self):
        # The whole working set must fit a v4/v5 core's ~16 MiB VMEM with
        # huge margin (these are 20-neuron nets).
        fp = mlp_pallas.vmem_footprint_bytes(
            dims.OBS_DIM, dims.ACT_DIM, dims.GSTATE_DIM, dims.HIDDEN
        )
        assert fp["policy"] < 1 << 20
        assert fp["value"] < 1 << 20
