//! End-to-end validation driver: the full three-layer stack on a real
//! workload (EXPERIMENTS.md §End-to-end).
//!
//! ```bash
//! make artifacts && cargo run --release --example resnet18_e2e
//! ```
//!
//! Compiles ResNet-18 (17 conv layers / 10 unique tasks, Table 3) with all
//! three frameworks — AutoTVM, CHAMELEON, ARCO — on the VTA++ simulator,
//! exercising every layer of the system:
//!
//!   L1/L2: the MAPPO policy/critic HLO (with the fused Pallas MLP/GAE
//!          kernels inside) executes on PJRT for every ARCO exploration
//!          step and train update;
//!   L3:    design-space construction, codegen, cycle simulation, GBT
//!          surrogates, SA/RL/MARL planners, confidence sampling, batched
//!          parallel measurement.
//!
//! Prints the Table-6 row for ResNet-18, the Fig-5 throughput ratios and a
//! Fig-7-style convergence summary. Uses a reduced measurement budget
//! (ARCO_E2E_TRIALS, default 320/task) so the run completes in minutes;
//! pass the paper's 1000 via the environment to reproduce at full scale.

use arco::tuner::{compare_frameworks, Framework, TuneBudget};
use arco::util::stats::running_max;
use arco::workload::model_by_name;

fn main() {
    arco::util::log::init_from_env();
    let trials: usize = std::env::var("ARCO_E2E_TRIALS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(320);

    let model = model_by_name("resnet18").expect("zoo model");
    println!(
        "ResNet-18: {} conv layers, {} unique tasks, {:.2} conv GFLOPs",
        model.num_conv_tasks(),
        model.unique_tasks().len(),
        model.total_flops() as f64 / 1e9
    );

    let budget = TuneBudget { total_measurements: trials, batch: 64, ..Default::default() };
    let frameworks = Framework::paper_set();
    let report = compare_frameworks(&frameworks, &model, budget, true, 20260710)
        .expect("local backends never lose their fleet");

    println!("\n=== Table 6 row (mean inference time on VTA++, seconds) ===");
    for o in &report.outcomes {
        println!(
            "  {:<10} {:.5} s   ({:.2} inf/s, compile {:.1} s, {} measurements)",
            o.framework.name(),
            o.inference_secs,
            o.throughput(),
            o.compile_secs,
            o.measurements
        );
    }

    println!("\n=== Fig 5 (throughput vs AutoTVM) ===");
    for f in &frameworks {
        if let Some(rel) = report.throughput_vs_autotvm(*f) {
            println!("  {:<10} {:.3}x", f.name(), rel);
        }
    }

    println!("\n=== Fig 7 flavour (best GFLOPS after N measurements, heaviest task) ===");
    for o in &report.outcomes {
        if let Some(t) = o.tasks.iter().max_by_key(|t| t.result.trace.len()) {
            let curve: Vec<f64> = t.result.trace.iter().map(|e| e.gflops).collect();
            let best = running_max(&curve);
            let probes = [
                best.len() / 4,
                best.len() / 2,
                best.len().saturating_sub(1),
            ];
            let pts: Vec<String> = probes
                .iter()
                .filter(|&&i| i < best.len())
                .map(|&i| format!("@{}: {:.1}", i + 1, best[i]))
                .collect();
            println!("  {:<10} task {}  {}", o.framework.name(), t.task_id, pts.join("  "));
        }
    }

    // Shape assertions: the qualitative claims of the paper must hold.
    let auto = report.outcome(Framework::AutoTvm).unwrap().inference_secs;
    let arco_t = report.outcome(Framework::Arco).unwrap().inference_secs;
    assert!(
        arco_t <= auto * 1.02,
        "ARCO ({arco_t:.5}s) must not lose to AutoTVM ({auto:.5}s)"
    );
    println!("\nOK: ARCO >= AutoTVM throughput on ResNet-18 (shape of Fig 5 holds)");
}
