//! Compilation-as-a-service: a leader/worker deployment of the tuner.
//!
//! ```bash
//! cargo run --release --example compile_service
//! ```
//!
//! Models a small compilation farm: clients submit (model, framework)
//! compilation jobs into a queue; a pool of worker threads drains it, each
//! worker running the full per-task tuning pipeline; the leader aggregates
//! results and prints a job report. This is the deployment shape a team
//! would actually run ARCO in — one tuning service, many networks.
//!
//! All jobs measure through ONE shared `eval::Engine` (it is `Sync`), so a
//! configuration tuned for job 0 is a cache hit for every later job on the
//! same task — and with a journal, for every later *process* too.

use arco::eval::{Engine, EngineConfig};
use arco::tuner::{tune_model_with, Framework, TuneBudget};
use arco::workload::model_by_name;
use std::path::PathBuf;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Debug, Clone)]
struct Job {
    id: usize,
    model: &'static str,
    framework: Framework,
    trials: usize,
}

fn main() {
    arco::util::log::init_from_env();
    let t0 = Instant::now();

    // Client-submitted job queue.
    let jobs = vec![
        Job { id: 0, model: "alexnet", framework: Framework::Arco, trials: 128 },
        Job { id: 1, model: "alexnet", framework: Framework::AutoTvm, trials: 128 },
        Job { id: 2, model: "resnet18", framework: Framework::Arco, trials: 96 },
        Job { id: 3, model: "vgg11", framework: Framework::Arco, trials: 96 },
        Job { id: 4, model: "alexnet", framework: Framework::Chameleon, trials: 128 },
    ];
    let queue = Arc::new(Mutex::new(jobs));
    let (tx, rx) = mpsc::channel();

    let service_workers = 2usize; // concurrent jobs
    let sim_workers = 2usize; // simulator threads per job
    println!("compile service: {service_workers} job workers x {sim_workers} sim threads");

    // One engine for the whole service: shared cache across jobs, plus a
    // persistent journal so a restarted service reuses everything measured
    // by previous incarnations.
    let engine = Engine::new(EngineConfig {
        workers: sim_workers,
        journal: Some(PathBuf::from("results/service_journal.jsonl")),
        ..Default::default()
    })
    .expect("service engine (is another service holding the journal lock?)");

    std::thread::scope(|scope| {
        for wid in 0..service_workers {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let engine = &engine;
            scope.spawn(move || loop {
                let job = { queue.lock().unwrap().pop() };
                let Some(job) = job else { break };
                let model = model_by_name(job.model).unwrap();
                let budget = TuneBudget {
                    total_measurements: job.trials,
                    batch: 32,
                    workers: sim_workers,
                    ..Default::default()
                };
                let started = Instant::now();
                let out =
                    tune_model_with(engine, job.framework, &model, budget, true, 7 + job.id as u64)
                        .expect("local backends never lose their fleet");
                tx.send((wid, job, out, started.elapsed())).unwrap();
            });
        }
        drop(tx);

        // Leader: aggregate results as they stream in.
        let mut done = 0usize;
        for (wid, job, out, took) in rx {
            done += 1;
            println!(
                "[{:>6.2}s] worker{} job#{} {:<9} {:<9} -> inference {:.5}s, {} measurements, took {:.1}s",
                t0.elapsed().as_secs_f64(),
                wid,
                job.id,
                job.model,
                job.framework.name(),
                out.inference_secs,
                out.measurements,
                took.as_secs_f64()
            );
        }
        println!("service drained: {done} jobs");
        assert_eq!(done, 5);
    });
    println!("shared eval engine: {}", engine.summary());
}
