//! Quickstart: co-optimize one convolution layer with ARCO.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Tunes ResNet-18's most expensive 3x3 layer for ~200 simulated hardware
//! measurements, then compares the discovered (hardware, software)
//! configuration against the default VTA++ operating point.

use arco::eval::Engine;
use arco::marl::strategy::{Arco, ArcoParams};
use arco::space::ConfigSpace;
use arco::tuner::{tune_task_with, Strategy, TuneBudget};
use arco::workload::Conv2dTask;

fn main() {
    arco::util::log::init_from_env();

    // ResNet-18 stage-1 conv: 64ch 56x56, 3x3.
    let task = Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1);
    println!("task: {} ({:.2} GFLOPs)", task.short_id(), task.flops() as f64 / 1e9);

    // Full co-design space: hardware knobs tunable.
    let space = ConfigSpace::for_task(&task, true);
    println!("design space: {} knobs, {} configurations", space.num_knobs(), space.size());

    // All measurements flow through one batched, cached engine.
    let engine = Engine::vta_sim(arco::util::pool::default_workers());

    // Baseline: the default VTA++ point.
    let default_point = space.default_point();
    let default = engine.measure_one(&space, &default_point);
    println!(
        "default config: {}\n  -> {:.3} ms, {:.1} GFLOPS, {:.2} mm^2",
        space.render(&default_point),
        default.seconds * 1e3,
        default.gflops,
        default.area_mm2
    );

    // ARCO: three MAPPO agents + confidence sampling.
    let mut strategy = Arco::new(space.clone(), ArcoParams::quick(), 42);
    let budget = TuneBudget { total_measurements: 200, batch: 32, ..Default::default() };
    let result = tune_task_with(&engine, &space, &mut strategy, budget).expect("local backends never lose their fleet");

    let best_point = result.best_point.expect("tuning found a config");
    println!(
        "\nARCO best after {} measurements ({} invalid, {:.2}s wall):",
        result.measurements, result.invalid, result.wall_secs
    );
    println!("  {}", space.render(&best_point));
    println!(
        "  -> {:.3} ms, {:.1} GFLOPS, {:.2} mm^2 ({})",
        result.best.seconds * 1e3,
        result.best.gflops,
        result.best.area_mm2,
        strategy.diag()
    );
    println!(
        "\nspeedup over default VTA++: {:.2}x",
        default.seconds / result.best.seconds
    );
    println!("eval engine: {}", engine.summary());
    assert!(result.best.seconds <= default.seconds, "tuned config must not regress");
}
