//! Hardware/software co-design sweep: the design-space view behind ARCO's
//! hardware agent.
//!
//! ```bash
//! cargo run --release --example codesign_sweep
//! ```
//!
//! Enumerates every legal VTA++ GEMM geometry (BATCH x BLOCK_IN x
//! BLOCK_OUT), tunes the *software* knobs for each via a short random
//! search on a representative layer, and prints the area/latency Pareto
//! front. This shows why per-layer hardware shaping matters: the best
//! geometry differs between an early high-resolution layer and a late
//! channel-heavy layer, which is exactly the signal ARCO's hardware agent
//! learns.

use arco::eval::Engine;
use arco::space::ConfigSpace;
use arco::util::rng::Pcg32;
use arco::vta::area::{default_area_budget_mm2, total_area_mm2};
use arco::vta::VtaConfig;
use arco::workload::Conv2dTask;

/// Best software configuration for a fixed hardware geometry, by sampling.
/// The whole sample set goes to the engine as ONE batch: it deduplicates
/// collisions, serves revisited configs from the cache and simulates the
/// rest in parallel.
fn best_sw_for_hw(
    engine: &Engine,
    task: &Conv2dTask,
    batch: usize,
    block_in: usize,
    block_out: usize,
    samples: usize,
    rng: &mut Pcg32,
) -> Option<(f64, String)> {
    let space = ConfigSpace::for_task(task, true);
    let bi = |name: &str| space.knob_index(name).unwrap();
    let pos = |name: &str, v: usize| {
        space.knobs[bi(name)].values.iter().position(|&x| x == v)
    };
    let (ib, ici, ico) = (pos("tile_b", batch)?, pos("tile_ci", block_in)?, pos("tile_co", block_out)?);

    let plan: Vec<_> = (0..samples)
        .map(|_| {
            let mut p = space.random_point(rng);
            p.0[bi("tile_b")] = ib;
            p.0[bi("tile_ci")] = ici;
            p.0[bi("tile_co")] = ico;
            p
        })
        .collect();
    let mut best: Option<(f64, String)> = None;
    for (p, m) in engine.measure_paired(&space, plan).pairs {
        if m.valid && best.as_ref().map_or(true, |(s, _)| m.seconds < *s) {
            best = Some((m.seconds, space.render(&p)));
        }
    }
    best
}

fn sweep_layer(engine: &Engine, name: &str, task: &Conv2dTask) {
    println!("\n== {} {} ({:.2} GFLOPs) ==", name, task.short_id(), task.flops() as f64 / 1e9);
    let budget = default_area_budget_mm2();
    let mut rng = Pcg32::seeded(99);
    let mut rows: Vec<(f64, f64, String, String)> = Vec::new(); // (area, secs, geom, cfg)

    for &b in &[1usize, 2, 4] {
        for &ci in &[8usize, 16, 32, 64] {
            for &co in &[8usize, 16, 32, 64] {
                let hw = VtaConfig::with_gemm(b, ci, co);
                let area = total_area_mm2(&hw);
                if area > budget {
                    continue; // infeasible under Eq. 4's budget
                }
                if let Some((secs, cfg)) = best_sw_for_hw(engine, task, b, ci, co, 40, &mut rng) {
                    rows.push((area, secs, format!("{b}x{ci}x{co}"), cfg));
                }
            }
        }
    }

    // Pareto front on (area, latency).
    rows.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut best_secs = f64::INFINITY;
    println!("{:<10} {:>9} {:>11}   pareto", "geometry", "area mm2", "latency ms");
    for (area, secs, geom, _cfg) in &rows {
        let pareto = *secs < best_secs;
        if pareto {
            best_secs = *secs;
        }
        println!(
            "{:<10} {:>9.3} {:>11.3}   {}",
            geom,
            area,
            secs * 1e3,
            if pareto { "*" } else { "" }
        );
    }
    let winner = rows
        .iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .expect("at least one feasible geometry");
    println!("best geometry for this layer: {} ({:.3} ms)", winner.2, winner.1 * 1e3);
}

fn main() {
    arco::util::log::init_from_env();
    println!(
        "area budget: {:.3} mm^2 (1.25x default VTA++ instance)",
        default_area_budget_mm2()
    );
    let engine = Engine::vta_sim(arco::util::pool::default_workers());
    // An early wide layer vs a late channel-heavy layer: the co-design
    // optimum moves.
    sweep_layer(&engine, "early layer (ResNet-18 conv2_x)", &Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1));
    sweep_layer(&engine, "late layer (ResNet-18 conv5_x)", &Conv2dTask::new(1, 512, 7, 7, 512, 3, 3, 1, 1));
    println!("\neval engine: {}", engine.summary());
}
