#!/usr/bin/env bash
# Docs link check: every relative markdown link in README.md and docs/
# must point at a file (or file#anchor) that exists in the repo. External
# links (http/https/mailto) are skipped — CI has no network. Run from
# anywhere; paths resolve against the repo root.
set -euo pipefail
cd "$(dirname "$0")/.."

fail=0
# shellcheck disable=SC2207
files=(README.md $(ls docs/*.md 2>/dev/null || true))

for f in "${files[@]}"; do
    [ -f "$f" ] || continue
    dir=$(dirname "$f")
    # Pull out every inline-link target: [text](target). One per line,
    # tolerating several links on one line.
    while IFS= read -r target; do
        [ -n "$target" ] || continue
        case "$target" in
        http://* | https://* | mailto:*) continue ;;
        '#'*) continue ;; # same-file anchor; section drift is a review concern
        esac
        path=${target%%#*}
        [ -n "$path" ] || continue
        if ! [ -e "$dir/$path" ]; then
            echo "dead link in $f: ($target) -> $dir/$path does not exist" >&2
            fail=1
        fi
    done < <(grep -o '\[[^]]*\]([^)]*)' "$f" | sed 's/.*](\([^)]*\))/\1/')
done

if [ "$fail" -ne 0 ]; then
    echo "docs link check failed" >&2
    exit 1
fi
echo "docs link check ok: all relative links in README.md and docs/ resolve"
