#!/usr/bin/env bash
# End-to-end measurement-fleet smoke: the same seeded compare run must
# produce identical inference numbers through the in-process backend and
# through a loopback `serve-measure` shard — for both the analytical proxy
# and the vta-sim cycle oracle. Wall-clock outputs (compile time)
# legitimately differ between runs, so the diff targets
# results/table6_inference.md, which is a pure function of the
# measurements.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${ARCO_BIN:-target/release/arco}
SERVE_LOG=$(mktemp)
SERVER_PID=0
cleanup() {
    # Never `kill 0` (the whole process group) when no server is running.
    if [ "$SERVER_PID" -ne 0 ]; then
        kill "$SERVER_PID" 2>/dev/null || true
    fi
    rm -f "$SERVE_LOG"
}
trap cleanup EXIT

run_compare() {
    "$BIN" compare --models alexnet --frameworks autotvm \
        --config configs/smoke.json --quick --seed 7 --workers 2 "$@"
}

smoke_backend() {
    local backend=$1

    echo "== [$backend] pass 1: in-process =="
    run_compare --backend "$backend"
    cp results/table6_inference.md "/tmp/arco_t6_local_$backend.md"

    echo "== [$backend] starting serve-measure shard on loopback =="
    : >"$SERVE_LOG"
    "$BIN" serve-measure --addr 127.0.0.1:0 --backend "$backend" --workers 2 \
        >"$SERVE_LOG" 2>&1 &
    SERVER_PID=$!

    local addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/^serve-measure: listening on //p' "$SERVE_LOG" | head -n1)
        [ -n "$addr" ] && break
        kill -0 "$SERVER_PID" 2>/dev/null || { cat "$SERVE_LOG"; echo "server died"; exit 1; }
        sleep 0.1
    done
    [ -n "$addr" ] || { cat "$SERVE_LOG"; echo "server never reported its address"; exit 1; }
    echo "[$backend] shard at $addr"

    echo "== [$backend] pass 2: same run through --backend remote:$addr =="
    run_compare --backend "remote:$addr"
    cp results/table6_inference.md "/tmp/arco_t6_remote_$backend.md"

    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    SERVER_PID=0

    diff -u "/tmp/arco_t6_local_$backend.md" "/tmp/arco_t6_remote_$backend.md"
    echo "[$backend] ok: remote fleet measurements identical to in-process"
}

smoke_backend analytical
smoke_backend vta-sim
echo "smoke ok: remote == in-process for both backends"
