#!/usr/bin/env bash
# End-to-end fleet smoke. Each pass below proves one workflow documented
# in docs/OPERATIONS.md end to end, binary-only, over loopback:
#
#   smoke_backend          "Starting a fleet" — remote == in-process, per
#                          backend (analytical and vta-sim)
#   smoke_heterogeneous    "Heterogeneous fleets" — weighted placement on
#                          a throttled shard, identical numbers
#   smoke_warm_start       "Journal merge and warm start" — merge →
#                          --warm-start replays with zero fresh sims
#   smoke_warm_start_scale "Journal merge and warm start" — 20k-record
#                          preload inside the startup budget
#   smoke_pipelined        "Pipelined tuning" — depth-1 parity, depth-2
#                          shared-budget conservation
#   smoke_serve_tune       "Tuning as a service" — serve-tune daemon over
#                          a loopback shard; a second client's identical
#                          job is served from the shared cache (fresh=0)
#   smoke_store            "The shared measurement store" — a killed
#                          shard's measurements survive in --store; a
#                          fresh shard answers the same batch with zero
#                          simulations; store prune bounds the directory
#   smoke_multifidelity    "Multi-fidelity screening" — --fidelity exact
#                          is bit-identical to the default; screen:0.25
#                          on the analytical oracle lands on the same
#                          best with far fewer simulations, and the
#                          shared ledger conserves charges across tiers
#
# Wall-clock outputs (compile time) legitimately differ between runs, so
# the diffs target results/table6_inference.md, which is a pure function
# of the measurements.
set -euo pipefail
cd "$(dirname "$0")/.."

BIN=${ARCO_BIN:-target/release/arco}
SERVE_LOG=$(mktemp)
SERVE_LOG2=$(mktemp)
SERVER_PID=0
SERVER2_PID=0
cleanup() {
    # Never `kill 0` (the whole process group) when no server is running.
    if [ "$SERVER_PID" -ne 0 ]; then
        kill "$SERVER_PID" 2>/dev/null || true
    fi
    if [ "$SERVER2_PID" -ne 0 ]; then
        kill "$SERVER2_PID" 2>/dev/null || true
    fi
    rm -f "$SERVE_LOG" "$SERVE_LOG2"
}
trap cleanup EXIT

# Start a serve-measure shard ($1 = log file, extra args passed through).
# Prints "ADDR PID" on success. Runs inside command substitution, so the
# pid must travel via stdout (a subshell cannot set the caller's vars).
start_shard() {
    local log=$1
    shift
    : >"$log"
    "$BIN" serve-measure --addr 127.0.0.1:0 --workers 2 "$@" >"$log" 2>&1 &
    local pid=$!
    local addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/^serve-measure: listening on //p' "$log" | head -n1)
        [ -n "$addr" ] && break
        kill -0 "$pid" 2>/dev/null || { cat "$log" >&2; echo "server died" >&2; exit 1; }
        sleep 0.1
    done
    [ -n "$addr" ] || { cat "$log" >&2; echo "server never reported its address" >&2; exit 1; }
    echo "$addr $pid"
}

run_compare() {
    "$BIN" compare --models alexnet --frameworks autotvm \
        --config configs/smoke.json --quick --seed 7 --workers 2 "$@"
}

# docs/OPERATIONS.md § "Starting a fleet": a compare run through a
# loopback shard must be bit-identical to the in-process backend.
smoke_backend() {
    local backend=$1

    echo "== [$backend] pass 1: in-process =="
    run_compare --backend "$backend"
    cp results/table6_inference.md "/tmp/arco_t6_local_$backend.md"

    echo "== [$backend] starting serve-measure shard on loopback =="
    : >"$SERVE_LOG"
    "$BIN" serve-measure --addr 127.0.0.1:0 --backend "$backend" --workers 2 \
        >"$SERVE_LOG" 2>&1 &
    SERVER_PID=$!

    local addr=""
    for _ in $(seq 1 100); do
        addr=$(sed -n 's/^serve-measure: listening on //p' "$SERVE_LOG" | head -n1)
        [ -n "$addr" ] && break
        kill -0 "$SERVER_PID" 2>/dev/null || { cat "$SERVE_LOG"; echo "server died"; exit 1; }
        sleep 0.1
    done
    [ -n "$addr" ] || { cat "$SERVE_LOG"; echo "server never reported its address"; exit 1; }
    echo "[$backend] shard at $addr"

    echo "== [$backend] pass 2: same run through --backend remote:$addr =="
    run_compare --backend "remote:$addr"
    cp results/table6_inference.md "/tmp/arco_t6_remote_$backend.md"

    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    SERVER_PID=0

    diff -u "/tmp/arco_t6_local_$backend.md" "/tmp/arco_t6_remote_$backend.md"
    echo "[$backend] ok: remote fleet measurements identical to in-process"
}

# docs/OPERATIONS.md § "Heterogeneous fleets": --placement weighted
# moves wall-clock off a slow shard without changing a single number.
smoke_heterogeneous() {
    echo "== heterogeneous fleet: weighted placement on a throttled shard =="
    run_compare --backend analytical
    cp results/table6_inference.md /tmp/arco_t6_hetero_local.md

    local out fast slow
    out=$(start_shard "$SERVE_LOG" --backend analytical)
    fast=${out%% *}
    SERVER_PID=${out##* }
    # The second shard is artificially 5 ms/point slower: weighted
    # placement must route around it without changing a single number.
    out=$(start_shard "$SERVE_LOG2" --backend analytical --throttle-ms 5)
    slow=${out%% *}
    SERVER2_PID=${out##* }
    echo "fleet: fast=$fast slow=$slow (throttled)"

    run_compare --backend "remote:$fast,$slow" --placement weighted
    cp results/table6_inference.md /tmp/arco_t6_hetero_weighted.md

    kill "$SERVER_PID" "$SERVER2_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER2_PID" 2>/dev/null || true
    SERVER_PID=0
    SERVER2_PID=0

    diff -u /tmp/arco_t6_hetero_local.md /tmp/arco_t6_hetero_weighted.md
    echo "heterogeneous ok: weighted placement matches in-process numbers"
}

# docs/OPERATIONS.md § "Journal merge and warm start": merge shard
# journals, warm-start a fresh shard, replay with zero fresh simulations.
smoke_warm_start() {
    echo "== journal merge -> warm start round trip =="
    local j1=/tmp/arco_smoke_journal.jsonl
    local merged=/tmp/arco_smoke_merged.jsonl
    rm -f "$j1" "$j1.lock" "$merged" "$merged.lock"

    # Pass 1: in-process, journaling every measurement.
    run_compare --backend analytical --journal "$j1"
    cp results/table6_inference.md /tmp/arco_t6_warm_local.md

    "$BIN" journal merge "$merged" "$j1"

    # Pass 2: the same run through a shard warm-started from the merged
    # journal — identical numbers, and the client must report zero fresh
    # simulations (everything answered from the shard's inherited cache).
    local out addr
    out=$(start_shard "$SERVE_LOG" --backend analytical --warm-start "$merged")
    addr=${out%% *}
    SERVER_PID=${out##* }
    grep -q "preloaded=" "$SERVE_LOG" || { cat "$SERVE_LOG"; echo "shard must report preloaded count"; exit 1; }

    local warm_log=/tmp/arco_warm_run.log
    run_compare --backend "remote:$addr" | tee "$warm_log"
    cp results/table6_inference.md /tmp/arco_t6_warm_remote.md

    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    SERVER_PID=0

    diff -u /tmp/arco_t6_warm_local.md /tmp/arco_t6_warm_remote.md
    grep -q " simulations=0 " "$warm_log" || {
        echo "warm-started replay must cost zero fresh simulations; engine summary was:"
        grep "eval engine:" "$warm_log" || true
        exit 1
    }
    rm -f "$j1" "$j1.lock" "$merged" "$merged.lock"
    echo "warm start ok: merge -> warm-start replays the run from cache"
}

# docs/OPERATIONS.md § "Journal merge and warm start", at scale: a
# 20k-record preload must fit inside the shard's startup budget.
smoke_warm_start_scale() {
    echo "== warm start at scale: synthetic 20k-record journal preload =="
    local big=/tmp/arco_smoke_big_journal.jsonl
    rm -f "$big" "$big.lock"

    # Populate a journal an order of magnitude past what the compare smoke
    # produces; the streaming codec must replay it without noticeable
    # startup cost.
    "$BIN" journal synth "$big" --records 20000 --backend analytical --seed 11

    local t0 t1 out addr
    t0=$(date +%s)
    out=$(start_shard "$SERVE_LOG" --backend analytical --warm-start "$big")
    t1=$(date +%s)
    addr=${out%% *}
    SERVER_PID=${out##* }

    # Every synthesized record is unique and backend-matched, so the shard
    # must inherit all of them — an exact count, not a lower bound.
    grep -q "preloaded=20000" "$SERVE_LOG" || {
        cat "$SERVE_LOG"
        echo "shard must preload all 20000 synthesized records"
        exit 1
    }
    # Preload happens before the shard reports its address, so the shard
    # startup wall time bounds the replay; 30s catches any accidental
    # return to tree-parsing (or worse) without flaking on slow CI.
    if [ $((t1 - t0)) -gt 30 ]; then
        echo "warm-start preload of 20000 records took $((t1 - t0))s (>30s budget)"
        exit 1
    fi

    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    SERVER_PID=0
    rm -f "$big" "$big.lock"
    echo "warm start scale ok: 20000 records preloaded in $((t1 - t0))s"
}

# docs/OPERATIONS.md § "Pipelined tuning": depth 1 over the fleet stays
# bit-identical; depth 2 under --shared-budget conserves the ledger.
smoke_pipelined() {
    echo "== pipelined tuning: depth-1 parity and depth-2 budget conservation =="
    run_compare --backend analytical
    cp results/table6_inference.md /tmp/arco_t6_pipe_local.md

    local out fast second
    out=$(start_shard "$SERVE_LOG" --backend analytical)
    fast=${out%% *}
    SERVER_PID=${out##* }
    out=$(start_shard "$SERVE_LOG2" --backend analytical)
    second=${out%% *}
    SERVER2_PID=${out##* }
    echo "fleet: $fast, $second"

    # Depth 1 over the fleet must reproduce the in-process numbers exactly
    # (the serial loop is the reproducibility contract).
    run_compare --backend "remote:$fast,$second" --pipeline-depth 1
    cp results/table6_inference.md /tmp/arco_t6_pipe_d1.md
    diff -u /tmp/arco_t6_pipe_local.md /tmp/arco_t6_pipe_d1.md
    echo "pipelined ok: depth 1 over the fleet is identical to in-process"

    # Depth 2 with the shared ledger: budget conservation — no tenant may
    # be charged more than the per-task allowance, and every charge must
    # settle (no in-flight batch may leak a debit).
    local pipe_log=/tmp/arco_pipe2.log
    run_compare --backend "remote:$fast,$second" --pipeline-depth 2 --shared-budget | tee "$pipe_log"

    kill "$SERVER_PID" "$SERVER2_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER2_PID" 2>/dev/null || true
    SERVER_PID=0
    SERVER2_PID=0

    grep -q "^ledger\[alexnet\]: " "$pipe_log" || {
        echo "depth-2 shared-budget run must print its ledger summary"; exit 1;
    }
    # ledger[alexnet]: budget=N/task tenants=T charged=C fresh=F cache_served=S
    awk '/^ledger\[alexnet\]: / {
        found = 1   # the line exists; set before any early exit so END
                    # does not mis-report a parse/breach failure as "no
                    # ledger line found"
        for (i = 1; i <= NF; i++) {
            if ($i ~ /^budget=/)  { split($i, a, /[=\/]/); per_task = a[2] }
            if ($i ~ /^tenants=/) { split($i, a, "=");     tenants  = a[2] }
            if ($i ~ /^charged=/) { split($i, a, "=");     charged  = a[2] }
        }
        if (per_task == "" || tenants == "" || charged == "") {
            print "could not parse ledger summary: " $0; bad = 1; exit 1
        }
        if (charged + 0 > per_task * tenants) {
            print "budget breached: charged " charged " > " per_task "/task x " tenants " tenants"
            bad = 1; exit 1
        }
        print "pipelined ok: depth 2 conserved the budget (charged " charged \
              " <= " per_task "/task x " tenants " tenants)"
    }
    END {
        if (bad) { exit 1 }
        if (!found) { print "no ledger line found"; exit 1 }
    }' "$pipe_log"
}

# docs/OPERATIONS.md § "Tuning as a service": a serve-tune daemon over a
# loopback measure shard runs two clients' identical jobs; the first pays
# fresh measurements, the second is served entirely from the daemon's
# shared cache (fresh=0) — "measure once, charge everyone" over the wire.
smoke_serve_tune() {
    echo "== serve-tune: tuning-as-a-service daemon over a loopback shard =="
    local out shard_addr
    out=$(start_shard "$SERVE_LOG" --backend analytical)
    shard_addr=${out%% *}
    SERVER_PID=${out##* }

    : >"$SERVE_LOG2"
    "$BIN" serve-tune --addr 127.0.0.1:0 --backend "remote:$shard_addr" \
        --workers 2 --jobs 2 >"$SERVE_LOG2" 2>&1 &
    SERVER2_PID=$!
    local daemon_addr=""
    for _ in $(seq 1 100); do
        daemon_addr=$(sed -n 's/^serve-tune: listening on //p' "$SERVE_LOG2" | head -n1)
        [ -n "$daemon_addr" ] && break
        kill -0 "$SERVER2_PID" 2>/dev/null || { cat "$SERVE_LOG2" >&2; echo "daemon died" >&2; exit 1; }
        sleep 0.1
    done
    [ -n "$daemon_addr" ] || { cat "$SERVE_LOG2" >&2; echo "daemon never reported its address" >&2; exit 1; }
    echo "serve-tune daemon at $daemon_addr (fleet: $shard_addr)"

    submit_jobs() {
        "$BIN" tune submit --addr "$daemon_addr" --client "$1" --model alexnet \
            --framework random --trials 24 --batch 8 --seed 7 --quick --wait
    }

    local log1=/tmp/arco_tune_client1.log log2=/tmp/arco_tune_client2.log
    submit_jobs smoke1 | tee "$log1"
    grep -q "^tune submit: random on alexnet:" "$log1" || {
        echo "client 1 must print the submit summary"; exit 1;
    }
    # Same tasks, same seeds, a different client: the daemon's shared
    # engine has everything cached, so not one fresh simulation runs.
    submit_jobs smoke2 | tee "$log2"
    grep -q "fresh=0 " "$log2" || {
        echo "client 2 must be served from the shared cache (fresh=0); summary was:"
        grep "^tune submit:" "$log2" || true
        exit 1
    }
    # Both clients' identical jobs must land on identical numbers.
    local inf1 inf2
    inf1=$(sed -n 's/^tune submit: .*weighted inference \([0-9.e-]*\)s.*/\1/p' "$log1")
    inf2=$(sed -n 's/^tune submit: .*weighted inference \([0-9.e-]*\)s.*/\1/p' "$log2")
    [ -n "$inf1" ] && [ "$inf1" = "$inf2" ] || {
        echo "cache-served rerun changed the numbers: '$inf1' vs '$inf2'"; exit 1;
    }
    # The job table survives both runs and every job finished.
    "$BIN" tune status --addr "$daemon_addr" | tee /tmp/arco_tune_status.log
    [ "$(grep -c "^job " /tmp/arco_tune_status.log)" -eq 10 ] || {
        echo "daemon must hold 2 clients x 5 alexnet tasks = 10 jobs"; exit 1;
    }
    grep -q " failed " /tmp/arco_tune_status.log && { echo "no job may fail"; exit 1; }

    kill "$SERVER_PID" "$SERVER2_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER2_PID" 2>/dev/null || true
    SERVER_PID=0
    SERVER2_PID=0
    echo "serve-tune ok: second client served from the shared cache with identical numbers"
}

# docs/OPERATIONS.md § "The shared measurement store": measurements a
# killed shard paid for survive in the store directory; a brand-new
# shard on the same --store answers the identical batch without running
# one simulation; a 20k-record import then proves `store prune` bounds
# the directory to its byte budget.
smoke_store() {
    echo "== shared store: measure once, ever =="
    local store=/tmp/arco_smoke_store
    rm -rf "$store"

    run_compare --backend analytical
    cp results/table6_inference.md /tmp/arco_t6_store_local.md

    # Shard A pays for the measurements and writes them to the store.
    local out addr_a addr_b
    out=$(start_shard "$SERVE_LOG" --backend analytical --store "$store")
    addr_a=${out%% *}
    SERVER_PID=${out##* }
    grep -q "shared store at" "$SERVE_LOG" || {
        cat "$SERVE_LOG"; echo "shard must report its store directory"; exit 1;
    }
    run_compare --backend "remote:$addr_a"
    cp results/table6_inference.md /tmp/arco_t6_store_a.md
    diff -u /tmp/arco_t6_store_local.md /tmp/arco_t6_store_a.md

    # Kill shard A outright. Its cache and journal die with the process;
    # only the store survives.
    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    SERVER_PID=0

    # Shard B has an empty cache and no journal, yet the identical batch
    # must cost zero fresh simulations: every point is store-served and
    # rides the wire as fresh=false.
    out=$(start_shard "$SERVE_LOG2" --backend analytical --store "$store")
    addr_b=${out%% *}
    SERVER2_PID=${out##* }
    local store_log=/tmp/arco_store_run.log
    run_compare --backend "remote:$addr_b" | tee "$store_log"
    cp results/table6_inference.md /tmp/arco_t6_store_b.md
    diff -u /tmp/arco_t6_store_local.md /tmp/arco_t6_store_b.md
    grep -q " simulations=0 " "$store_log" || {
        echo "store-backed replay must cost zero fresh simulations; engine summary was:"
        grep "eval engine:" "$store_log" || true
        exit 1
    }
    "$BIN" store stat "$store"

    kill "$SERVER2_PID" 2>/dev/null || true
    wait "$SERVER2_PID" 2>/dev/null || true
    SERVER2_PID=0

    # Scale + bound: import a 20k-record synthetic history through tiny
    # segments (forcing rotation), then prune to a 256 KiB budget and
    # assert the directory actually fits it.
    local big=/tmp/arco_smoke_store_big.jsonl
    rm -f "$big" "$big.lock"
    "$BIN" journal synth "$big" --records 20000 --backend analytical --seed 11
    out=$(start_shard "$SERVE_LOG" --backend analytical \
        --warm-start "$big" --store "$store" --store-segment-kib 64)
    SERVER_PID=${out##* }
    grep -q "imported" "$SERVE_LOG" || {
        cat "$SERVE_LOG"; echo "shard must import its warm-start history into the store"; exit 1;
    }
    kill "$SERVER_PID" 2>/dev/null || true
    wait "$SERVER_PID" 2>/dev/null || true
    SERVER_PID=0

    local prune_log=/tmp/arco_store_prune.log
    "$BIN" store prune "$store" --budget-kib 256 | tee "$prune_log"
    awk '/^store prune: / {
        found = 1
        # store prune: {dir}: {d} of {n} segment(s) deleted, {b0} -> {b1} bytes (budget {q}), ...
        for (i = 1; i <= NF; i++) {
            if ($i == "->") { after = $(i + 1) }
        }
        if (after == "" ) { print "could not parse prune summary: " $0; exit 1 }
        if (after + 0 > 256 * 1024) {
            print "store prune left " after " bytes, over the 256 KiB budget"; exit 1
        }
        print "store prune bounded the directory to " after " bytes (budget 262144)"
    }
    END { if (!found) { print "no store prune summary printed"; exit 1 } }' "$prune_log"
    du -sb "$store" | awk '{ if ($1 + 0 > 512 * 1024) {
        print "store directory still holds " $1 " bytes on disk after prune"; exit 1 } }'

    rm -f "$big" "$big.lock"
    rm -rf "$store"
    echo "store ok: a fresh shard replayed a dead shard's run from the store, and prune bounded it"
}

# docs/OPERATIONS.md § "Multi-fidelity screening": --fidelity exact is
# bit-identical to the default loop; screen:0.25 against the analytical
# backend (where the screening model is the oracle) must land on the
# same best configurations with far fewer simulations, and a
# shared-budget run must conserve charges across the tiers.
smoke_multifidelity() {
    echo "== multi-fidelity: calibrated screening in front of the simulator budget =="
    # Random search ignores observations, so the planned candidates are
    # identical at every fidelity — and with the analytical backend the
    # (seed-calibrated) screening model scores candidates exactly as the
    # simulator would, so the per-batch best always survives the filter:
    # table6 must come out identical, only the simulation count may drop.
    run_multifid() {
        "$BIN" compare --models alexnet --frameworks random \
            --config configs/smoke.json --quick --seed 7 --workers 2 \
            --backend analytical "$@"
    }
    local exact_log=/tmp/arco_mf_exact.log screen_log=/tmp/arco_mf_screen.log
    run_multifid | tee "$exact_log"
    cp results/table6_inference.md /tmp/arco_t6_mf_default.md

    # `--fidelity exact` spelled out is the default: same table, and no
    # screening state may leak into the output.
    run_multifid --fidelity exact
    cp results/table6_inference.md /tmp/arco_t6_mf_exact.md
    diff -u /tmp/arco_t6_mf_default.md /tmp/arco_t6_mf_exact.md
    grep -q " screened=" "$exact_log" && {
        echo "exact-mode output must carry no screened= token"; exit 1;
    }

    run_multifid --fidelity screen:0.25 | tee "$screen_log"
    cp results/table6_inference.md /tmp/arco_t6_mf_screen.md
    diff -u /tmp/arco_t6_mf_default.md /tmp/arco_t6_mf_screen.md
    grep -q " screened=" "$screen_log" || {
        echo "screening run must report screened points"; exit 1;
    }

    # Fewer simulator measurements for the same candidate budget: with
    # keep=0.25 (plus the exploration slice) the screening run must cost
    # at most 70% of exact mode's simulations.
    local exact_sims screen_sims
    exact_sims=$(sed -n 's/.* simulations=\([0-9]*\).*/\1/p' "$exact_log" | head -n1)
    screen_sims=$(sed -n 's/.* simulations=\([0-9]*\).*/\1/p' "$screen_log" | head -n1)
    [ -n "$exact_sims" ] && [ -n "$screen_sims" ] || {
        echo "could not parse simulations= from the engine summaries"; exit 1;
    }
    if [ $((screen_sims * 10)) -gt $((exact_sims * 7)) ]; then
        echo "screening ran $screen_sims simulations vs $exact_sims exact (needed <= 70%)"
        exit 1
    fi
    echo "multi-fidelity: $screen_sims simulations at screen:0.25 vs $exact_sims exact, identical table6"

    # Cross-tier conservation on the shared ledger: every admitted
    # candidate settles exactly once — fresh, cache-served, or screened.
    local ledger_log=/tmp/arco_mf_ledger.log
    run_multifid --fidelity screen:0.25 --shared-budget | tee "$ledger_log"
    awk '/^ledger\[alexnet\]: / {
        found = 1
        for (i = 1; i <= NF; i++) {
            if ($i ~ /^charged=/)      { split($i, a, "="); charged  = a[2] }
            if ($i ~ /^fresh=/)        { split($i, a, "="); fresh    = a[2] }
            if ($i ~ /^cache_served=/) { split($i, a, "="); cache    = a[2] }
            if ($i ~ /^screened=/)     { split($i, a, "="); screened = a[2] }
        }
        if (charged == "" || fresh == "" || cache == "") {
            print "could not parse ledger summary: " $0; bad = 1; exit 1
        }
        if (screened + 0 <= 0) {
            print "shared-budget screening run must screen points: " $0; bad = 1; exit 1
        }
        if (charged + 0 != fresh + cache + screened + 0) {
            print "ledger not conserved across tiers: charged " charged \
                  " != fresh " fresh " + cache_served " cache " + screened " screened
            bad = 1; exit 1
        }
        print "multi-fidelity ok: ledger conserved (charged " charged " = " fresh \
              " fresh + " cache " cached + " screened " screened)"
    }
    END {
        if (bad) { exit 1 }
        if (!found) { print "no ledger line found"; exit 1 }
    }' "$ledger_log"
}

smoke_backend analytical
smoke_backend vta-sim
smoke_heterogeneous
smoke_warm_start
smoke_warm_start_scale
smoke_pipelined
smoke_serve_tune
smoke_store
smoke_multifidelity
echo "smoke ok: remote == in-process, weighted placement, warm start (incl. 20k-record preload), pipelined tuning, serve-tune, the shared store and multi-fidelity screening verified"
