#!/usr/bin/env python3
"""Bench trend report: compare this commit's BENCH_*.json timings against
the previous commit's artifact and fail on a large engine regression.

The `bench-quick` CI job uploads `results/bench/*.json` (renamed
`BENCH_<suite>_<sha>.json`) per commit. This script pairs benches by
(suite, bench name) between a baseline directory and a current directory,
prints the trend table, and exits non-zero when any bench regresses by
more than the threshold (default 25% on mean_ns).

Quick-mode timings on shared CI runners are noisy; the default threshold
is deliberately loose so only step-change regressions (an accidental
O(n^2), a lost cache) trip it. Benches present on only one side are
reported but never fatal (suites come and go).

Usage:
  scripts/bench_trend.py --prev DIR --curr DIR [--threshold 25]

Exit codes: 0 ok / nothing comparable, 1 regression, 2 usage error.
"""

import argparse
import glob
import json
import os
import sys


def load_dir(path):
    """Map (suite, bench name) -> mean_ns over every bench JSON in path."""
    out = {}
    for f in sorted(glob.glob(os.path.join(path, "*.json"))):
        try:
            with open(f) as fh:
                doc = json.load(fh)
        except (OSError, json.JSONDecodeError) as e:
            print(f"warning: skipping unreadable {f}: {e}", file=sys.stderr)
            continue
        suite = doc.get("suite")
        results = doc.get("results")
        if not isinstance(suite, str) or not isinstance(results, list):
            print(f"warning: {f} is not a bench summary, skipping", file=sys.stderr)
            continue
        for r in results:
            name, mean = r.get("name"), r.get("mean_ns")
            if isinstance(name, str) and isinstance(mean, (int, float)) and mean > 0:
                out[(suite, name)] = float(mean)
    return out


def fmt_ns(ns):
    if ns >= 1e9:
        return f"{ns / 1e9:.3f}s"
    if ns >= 1e6:
        return f"{ns / 1e6:.3f}ms"
    if ns >= 1e3:
        return f"{ns / 1e3:.3f}us"
    return f"{ns:.1f}ns"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--prev", required=True, help="baseline bench dir (previous commit)")
    ap.add_argument("--curr", required=True, help="current bench dir")
    ap.add_argument(
        "--threshold",
        type=float,
        default=25.0,
        help="max allowed mean_ns regression, percent (default 25)",
    )
    args = ap.parse_args()
    if not os.path.isdir(args.curr):
        print(f"error: current dir {args.curr} does not exist", file=sys.stderr)
        return 2

    prev = load_dir(args.prev) if os.path.isdir(args.prev) else {}
    curr = load_dir(args.curr)
    if not prev:
        print("bench-trend: no baseline artifact (first run or cache miss) — nothing to compare")
        return 0
    if not curr:
        print("bench-trend: error: no current bench results", file=sys.stderr)
        return 2

    shared = sorted(set(prev) & set(curr))
    regressions = []
    print(f"bench-trend: {len(shared)} comparable bench(es), threshold +{args.threshold:.0f}%")
    print(f"{'suite/bench':<52} {'prev':>10} {'curr':>10} {'delta':>8}")
    for key in shared:
        suite, name = key
        delta = 100.0 * (curr[key] - prev[key]) / prev[key]
        marker = ""
        if delta > args.threshold:
            marker = "  << REGRESSION"
            regressions.append((key, delta))
        print(
            f"{suite + '/' + name:<52} {fmt_ns(prev[key]):>10} {fmt_ns(curr[key]):>10} "
            f"{delta:>+7.1f}%{marker}"
        )
    for key in sorted(set(curr) - set(prev)):
        print(f"{key[0] + '/' + key[1]:<52} {'-':>10} {fmt_ns(curr[key]):>10}     new")
    for key in sorted(set(prev) - set(curr)):
        print(f"{key[0] + '/' + key[1]:<52} {fmt_ns(prev[key]):>10} {'-':>10} dropped")

    if regressions:
        worst = max(regressions, key=lambda kv: kv[1])
        print(
            f"bench-trend: FAIL — {len(regressions)} bench(es) regressed past "
            f"+{args.threshold:.0f}% (worst: {worst[0][0]}/{worst[0][1]} {worst[1]:+.1f}%)",
            file=sys.stderr,
        )
        return 1
    print("bench-trend: ok — no regression past the threshold")
    return 0


if __name__ == "__main__":
    sys.exit(main())
