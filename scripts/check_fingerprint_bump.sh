#!/usr/bin/env bash
# Fingerprint guard: a change to the cycle-accounting code in
# rust/src/vta/sim.rs silently invalidates every journal and every
# cross-fleet comparison unless CYCLE_MODEL_VERSION is bumped with it
# (the version feeds eval::Fingerprint, which gates journal reuse and
# shard admission — see docs/WIRE.md "Fingerprint").
#
# This script fails when a diff touches substantive (non-comment,
# non-blank) lines of sim.rs without also changing the
# CYCLE_MODEL_VERSION line. Pure comment/whitespace edits pass.
#
# Usage: check_fingerprint_bump.sh [base-ref]
#   base-ref defaults to origin/$GITHUB_BASE_REF (in a PR), else HEAD^.
set -euo pipefail
cd "$(dirname "$0")/.."

SIM=rust/src/vta/sim.rs

base="${1:-}"
if [ -z "$base" ]; then
    if [ -n "${GITHUB_BASE_REF:-}" ]; then
        base="origin/${GITHUB_BASE_REF}"
    else
        base="HEAD^"
    fi
fi

if ! git rev-parse --verify --quiet "$base" >/dev/null; then
    echo "fingerprint-guard: base ref '$base' not found; skipping" >&2
    exit 0
fi

# Only added/removed lines of the simulator, no context lines.
diff=$(git diff -U0 "$base" -- "$SIM" || true)
if [ -z "$diff" ]; then
    echo "fingerprint-guard: $SIM untouched vs $base"
    exit 0
fi

# Substantive = an added/removed line that is not blank and not a pure
# comment line (//, //!, ///, or block-comment interior starting with *).
substantive=$(printf '%s\n' "$diff" |
    grep -E '^[+-]' | grep -vE '^(\+\+\+|---)' |
    sed -E 's/^[+-][[:space:]]*//' |
    grep -vE '^(//|\*|/\*|\*/|$)' || true)

if [ -z "$substantive" ]; then
    echo "fingerprint-guard: only comments/whitespace changed in $SIM"
    exit 0
fi

if printf '%s\n' "$diff" | grep -E '^[+-]' | grep -q 'CYCLE_MODEL_VERSION'; then
    echo "fingerprint-guard: $SIM changed and CYCLE_MODEL_VERSION was bumped"
    exit 0
fi

echo "fingerprint-guard: $SIM cycle-accounting code changed vs $base without a" >&2
echo "CYCLE_MODEL_VERSION bump. Old journals would replay numbers from a" >&2
echo "different cycle model. Bump CYCLE_MODEL_VERSION in $SIM (and mention the" >&2
echo "change in docs/WIRE.md if the fingerprint schema moved)." >&2
exit 1
