//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! provides the (small) subset of `anyhow` the workspace actually uses:
//! [`Error`], [`Result`], [`Error::msg`], and the [`anyhow!`] / [`bail!`]
//! macros, with the same blanket `From<E: std::error::Error>` conversion
//! that makes `?` work on arbitrary error types. Swapping this path
//! dependency for the real crates.io `anyhow` requires no source changes.

use std::error::Error as StdError;
use std::fmt;

/// A type-erased error, convertible from any `std::error::Error`.
///
/// Like the real `anyhow::Error`, this deliberately does **not** implement
/// `std::error::Error` itself — that is what keeps the blanket `From`
/// conversion coherent with the reflexive `From<T> for T` impl.
pub struct Error {
    inner: Box<dyn StdError + Send + Sync + 'static>,
}

impl Error {
    /// Wrap a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: StdError + Send + Sync + 'static,
    {
        Error { inner: Box::new(error) }
    }

    /// Build an error from a displayable message (`map_err(Error::msg)`).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        Error { inner: Box::new(MessageError(message)) }
    }

    /// Borrow the underlying error.
    pub fn as_ref(&self) -> &(dyn StdError + Send + Sync + 'static) {
        &*self.inner
    }

    /// The lowest-level source of this error.
    pub fn root_cause(&self) -> &(dyn StdError + 'static) {
        let mut cause: &(dyn StdError + 'static) = &*self.inner;
        while let Some(next) = cause.source() {
            cause = next;
        }
        cause
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.inner, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Match anyhow's alternate-free rendering: the message, then the
        // source chain.
        write!(f, "{}", self.inner)?;
        let mut source = self.inner.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: StdError + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// `anyhow::Result<T>` — `Result` with a type-erased error default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A plain-message error (what `anyhow!`/`Error::msg` produce).
struct MessageError<M>(M);

impl<M: fmt::Display> fmt::Display for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Debug::fmt(&self.0, f)
    }
}

impl<M: fmt::Display + fmt::Debug> StdError for MessageError<M> {}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)+) => {
        $crate::Error::msg(format!($($arg)+))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)+) => {
        return Err($crate::anyhow!($($arg)+))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing")
    }

    #[test]
    fn question_mark_converts() {
        fn inner() -> Result<()> {
            let r: std::result::Result<(), std::io::Error> = Err(io_err());
            r?;
            Ok(())
        }
        let e = inner().unwrap_err();
        assert!(e.to_string().contains("missing"));
    }

    #[test]
    fn macro_formats() {
        let e: Error = anyhow!("bad {} ({})", "thing", 7);
        assert_eq!(e.to_string(), "bad thing (7)");
    }

    #[test]
    fn bail_returns_err() {
        fn inner(x: usize) -> Result<usize> {
            if x == 0 {
                bail!("zero not allowed");
            }
            Ok(x)
        }
        assert!(inner(1).is_ok());
        assert_eq!(inner(0).unwrap_err().to_string(), "zero not allowed");
    }

    #[test]
    fn msg_accepts_string() {
        let e = Error::msg("plain".to_string());
        assert_eq!(format!("{e}"), "plain");
        assert_eq!(format!("{e:?}"), "plain");
    }
}
