//! Compile-only stub of the `xla` (PJRT) crate.
//!
//! The offline build environment cannot fetch or link the real XLA/PJRT
//! runtime, but `arco::runtime::Engine` is written against the `xla` crate
//! API. This stub mirrors exactly the surface that code uses so the crate
//! builds everywhere; every runtime entry point returns an [`Error`] saying
//! PJRT is unavailable. `Backend::auto` therefore always falls back to the
//! native mirror, and the `runtime_parity` tests self-skip.
//!
//! To run the real AOT/XLA path, replace this path dependency in the root
//! `Cargo.toml` with the actual `xla` crate; no source changes are needed.

use std::fmt;

/// Error type mirroring `xla::Error` (message-only in the stub).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: PJRT is unavailable in this offline build \
                 (vendor/xla is a compile-only stub; link the real `xla` crate to enable it)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

/// Element types a [`Literal`] can carry.
pub trait NativeType: Copy {}
impl NativeType for f32 {}
impl NativeType for f64 {}
impl NativeType for i32 {}
impl NativeType for i64 {}
impl NativeType for u8 {}

/// Host-side tensor value (opaque in the stub).
#[derive(Debug, Clone)]
pub struct Literal(());

impl Literal {
    /// Rank-1 literal from a flat slice.
    pub fn vec1<T: NativeType>(_data: &[T]) -> Literal {
        Literal(())
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
        Ok(Literal(()))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>, Error> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error::unavailable("Literal::to_tuple"))
    }
}

/// Parsed HLO module (opaque in the stub).
pub struct HloModuleProto(());

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation ready for compilation.
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    /// Execute with per-device argument lists; mirrors the real crate's
    /// `Vec<Vec<PjRtBuffer>>` result shape.
    pub fn execute<L>(&self, _inputs: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle. `cpu()` always fails in the stub, which is the single
/// gate that keeps all other stubbed entry points unreachable at runtime.
pub struct PjRtClient(());

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT is unavailable"));
    }

    #[test]
    fn literal_construction_is_cheap_and_infallible() {
        let l = Literal::vec1(&[1.0f32, 2.0]);
        assert!(l.reshape(&[2, 1]).is_ok());
        assert!(l.to_vec::<f32>().is_err());
    }
}
