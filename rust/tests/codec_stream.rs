//! Differential and regression tests for the zero-copy streaming codec.
//!
//! The contract under test: the streaming reader/writer and the JSON tree
//! codec are two implementations of ONE grammar and ONE record schema.
//! Every record, request and response frame the tree encoder produces must
//! come out of the streaming encoder byte-for-byte identical (so old
//! journals hash-match new writer output), and the streaming decoders must
//! invert the writers exactly. The single sanctioned divergence is integer
//! fidelity: `cycles` above 2^53 survive the streaming path exactly where
//! the tree's f64 numbers corrupt them.

use arco::eval::proto::{
    record_from_line, record_identity_from_line, record_to_json, request_from_line,
    response_from_line, write_frame, write_record_line, write_request_frame, write_response_frame,
    Request, Response,
};
use arco::eval::{MeasureResult, PointKey};
use arco::prop_assert;
use arco::space::ConfigSpace;
use arco::util::json::stream::{Reader, StreamWriter, Token};
use arco::util::json::Json;
use arco::util::prop::check;
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;

fn space() -> ConfigSpace {
    ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
}

/// A measurement with tree-exact numbers (`cycles` kept below 2^53 so the
/// byte-identity comparison against the f64 tree encoding is fair).
fn random_result(rng: &mut Pcg32, valid: bool) -> MeasureResult {
    if valid {
        MeasureResult {
            seconds: (rng.gen_range(1_000_000) as f64 + 1.0) * 1e-9,
            cycles: rng.next_u64() >> 12,
            gflops: rng.gen_f64() * 100.0,
            area_mm2: rng.gen_f64() * 10.0,
            occupancy: rng.gen_f64(),
            valid: true,
        }
    } else {
        MeasureResult {
            seconds: f64::INFINITY,
            cycles: 0,
            gflops: 0.0,
            area_mm2: 0.0,
            occupancy: 0.0,
            valid: false,
        }
    }
}

/// Random bounded-depth JSON documents: every scalar kind, strings with
/// and without escapes, finite numbers in several spellings.
fn gen_json(rng: &mut Pcg32, depth: usize) -> Json {
    let pick = if depth == 0 { rng.gen_range(4) } else { rng.gen_range(6) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.gen_bool(0.5)),
        2 => match rng.gen_range(3) {
            0 => Json::num(rng.gen_range(1_000_000) as f64),
            1 => Json::num(-(rng.gen_range(1_000) as f64) - 0.5),
            _ => Json::num(rng.gen_f64() * 1e9),
        },
        3 => Json::str(gen_string(rng)),
        4 => Json::Arr((0..rng.gen_range(4)).map(|_| gen_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.gen_range(4)).map(|_| (gen_string(rng), gen_json(rng, depth - 1))).collect(),
        ),
    }
}

fn gen_string(rng: &mut Pcg32) -> String {
    let pool: [&str; 8] = [
        "plain",
        "with space",
        "q\"uote",
        "back\\slash",
        "tab\tand\nnewline",
        "ünïcodé 😀",
        "\u{1}control\u{1f}",
        "",
    ];
    (*rng.choose(&pool)).to_string()
}

#[test]
fn generated_documents_roundtrip_compact_and_pretty() {
    check(
        "json-roundtrip",
        0xC0DEC,
        300,
        |rng| gen_json(rng, 3),
        |v| {
            let dump = v.dump();
            let back = Json::parse(&dump).map_err(|e| format!("reparse of {dump}: {e}"))?;
            prop_assert!(back == *v, "dump/parse drifted for {dump}");
            let pretty = v.pretty();
            let back = Json::parse(&pretty).map_err(|e| format!("pretty reparse: {e}"))?;
            prop_assert!(back == *v, "pretty/parse drifted for {dump}");
            // The streaming reader must skip any document it can parse,
            // landing exactly at the end of input.
            let mut r = Reader::new(&dump);
            r.skip_value().map_err(|e| format!("skip_value on {dump}: {e}"))?;
            prop_assert!(r.at_end(), "skip_value left input behind in {dump}");
            Ok(())
        },
    );
}

#[test]
fn tricky_documents_pin_the_grammar() {
    // (input, canonical dump) pairs pin escape decoding, surrogate pairs,
    // number spellings and nesting — the cases where a second grammar
    // implementation would quietly drift.
    let cases: [(&str, &str); 8] = [
        (r#"{"a":1,"b":[true,false,null]}"#, r#"{"a":1,"b":[true,false,null]}"#),
        ("  [ 1 , 2.5 , -3e2 ]  ", "[1,2.5,-300]"),
        (r#""\u0041\u00e9\ud83d\ude00""#, "\"Aé😀\""),
        ("\"tab\\tnewline\\n\"", "\"tab\\tnewline\\n\""),
        ("1e3", "1000"),
        ("0.5", "0.5"),
        (r#"{"nested":{"deep":[[[]]]}}"#, r#"{"nested":{"deep":[[[]]]}}"#),
        ("-0.25e1", "-2.5"),
    ];
    for (input, want) in cases {
        let v = Json::parse(input).unwrap_or_else(|e| panic!("{input}: {e}"));
        assert_eq!(v.dump(), want, "input {input}");
    }
    let rejects = [
        "",
        "{",
        "[1,",
        "tru",
        "{\"a\" 1}",
        "1 2",
        "{]",
        "[,1]",
        "\"\\ud800\"",
        "\"\\q\"",
    ];
    for bad in rejects {
        assert!(Json::parse(bad).is_err(), "should reject {bad:?}");
    }
}

#[test]
fn record_lines_match_the_tree_encoding_byte_for_byte() {
    let s = space();
    check(
        "record-line-identity",
        7,
        150,
        |rng| {
            let p = s.random_point(rng);
            let key = PointKey::of(&s, &p);
            let backend = if rng.gen_bool(0.5) { "vta-sim" } else { "analytical" };
            let valid = rng.gen_bool(0.8);
            (backend, key, random_result(rng, valid))
        },
        |(backend, key, result)| {
            let mut buf = Vec::new();
            write_record_line(&mut buf, backend, key, result).unwrap();
            let mut tree = record_to_json(backend, key, result).dump();
            tree.push('\n');
            prop_assert!(
                buf == tree.as_bytes(),
                "streaming line != tree line:\n  stream: {}\n  tree:   {tree}",
                String::from_utf8_lossy(&buf)
            );
            // The streaming decoders invert the writer.
            let line = std::str::from_utf8(&buf).unwrap().trim_end_matches('\n');
            let (b2, k2, r2) = record_from_line(line)
                .ok_or_else(|| "record_from_line rejected its own writer".to_string())?;
            prop_assert!(b2 == *backend && k2 == *key, "record identity drifted");
            prop_assert!(r2 == *result, "record payload drifted: {r2:?} vs {result:?}");
            let (b3, k3) = record_identity_from_line(line)
                .ok_or_else(|| "lazy identity decode failed".to_string())?;
            prop_assert!(b3 == *backend && k3 == *key, "lazy identity drifted");
            Ok(())
        },
    );
}

#[test]
fn wire_frames_match_the_tree_encoding_byte_for_byte() {
    let s = space();
    let mut rng = Pcg32::seeded(11);
    let points: Vec<Vec<usize>> =
        (0..64).map(|_| PointKey::of(&s, &s.random_point(&mut rng)).values).collect();
    let req = Request::Measure { task: s.task, points };
    let mut stream_buf = Vec::new();
    write_request_frame(&mut stream_buf, &req).unwrap();
    let mut tree_buf = Vec::new();
    write_frame(&mut tree_buf, &req.to_json()).unwrap();
    assert_eq!(stream_buf, tree_buf, "measure request frame drifted");
    let line = std::str::from_utf8(&stream_buf).unwrap().trim_end_matches('\n');
    assert_eq!(request_from_line(line), Some(req), "request decode must invert the writer");

    let results: Vec<MeasureResult> = (0..64)
        .map(|i| {
            let valid = i % 7 != 0;
            random_result(&mut rng, valid)
        })
        .collect();
    let fresh: Vec<bool> = (0..64).map(|i| i % 3 != 0).collect();
    for active_batches in [None, Some(5)] {
        let resp = Response::Results {
            results: results.clone(),
            fresh: fresh.clone(),
            active_batches,
        };
        let mut stream_buf = Vec::new();
        write_response_frame(&mut stream_buf, &resp).unwrap();
        let mut tree_buf = Vec::new();
        write_frame(&mut tree_buf, &resp.to_json()).unwrap();
        assert_eq!(stream_buf, tree_buf, "results response frame drifted");
        let line = std::str::from_utf8(&stream_buf).unwrap().trim_end_matches('\n');
        assert_eq!(
            response_from_line(line),
            Some(resp),
            "response decode must invert the writer"
        );
    }
}

#[test]
fn non_hot_frames_still_roundtrip_through_the_line_decoders() {
    // Ping / stats / error frames take the tree fallback inside the
    // streaming entry points; they must keep working unchanged.
    for req in [Request::Ping, Request::Stats] {
        let mut buf = Vec::new();
        write_request_frame(&mut buf, &req).unwrap();
        let line = std::str::from_utf8(&buf).unwrap().trim_end_matches('\n');
        assert_eq!(request_from_line(line), Some(req));
    }
    let err = Response::Error("unintelligible request".to_string());
    let mut buf = Vec::new();
    write_response_frame(&mut buf, &err).unwrap();
    let line = std::str::from_utf8(&buf).unwrap().trim_end_matches('\n');
    assert_eq!(response_from_line(line), Some(err));
    // Field order must not matter to the strict decoders.
    let reordered = r#"{"results":[],"ok":true,"fresh":[]}"#;
    assert_eq!(
        response_from_line(reordered),
        Some(Response::Results { results: vec![], fresh: vec![], active_batches: None })
    );
    // Junk is rejected by both decode paths.
    assert_eq!(request_from_line("{\"op\":\"measure\",\"task\":"), None);
    assert_eq!(response_from_line("not json"), None);
}

#[test]
fn cycle_counts_above_2_53_survive_the_streaming_codec() {
    let s = space();
    let mut rng = Pcg32::seeded(3);
    let key = PointKey::of(&s, &s.random_point(&mut rng));
    let big = (1u64 << 53) + 3; // not representable as f64
    let r = MeasureResult {
        seconds: 1.5e-3,
        cycles: big,
        gflops: 1.0,
        area_mm2: 2.0,
        occupancy: 0.5,
        valid: true,
    };
    let mut buf = Vec::new();
    write_record_line(&mut buf, "vta-sim", &key, &r).unwrap();
    let line = std::str::from_utf8(&buf).unwrap().trim_end_matches('\n');
    let (_, _, back) = record_from_line(line).unwrap();
    assert_eq!(back.cycles, big, "u64 cycles must survive the streaming path exactly");
    // The legacy tree path really is lossy here — the corruption the
    // streaming codec exists to fix.
    let tree_line = record_to_json("vta-sim", &key, &r).dump();
    let (_, _, tree_back) = record_from_line(&tree_line).unwrap();
    assert_ne!(tree_back.cycles, big, "sanity: the f64 tree encoding rounds 2^53+3");
    assert_eq!(tree_back.cycles, (big as f64) as u64);
}

#[test]
fn u64_and_i64_values_roundtrip_exactly_through_writer_and_reader() {
    for v in [0u64, 1, (1 << 53) - 1, 1 << 53, (1 << 53) + 1, u64::MAX - 1, u64::MAX] {
        let mut buf = Vec::new();
        StreamWriter::new(&mut buf).u64_val(v).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut r = Reader::new(&text);
        match r.next_token() {
            Some(Token::Num(n)) => assert_eq!(n.as_u64(), Some(v), "u64 {v} via {text}"),
            t => panic!("unexpected token {t:?} for u64 {v}"),
        }
    }
    for v in [i64::MIN, i64::MIN + 1, -1i64, 0, 1, i64::MAX - 1, i64::MAX] {
        let mut buf = Vec::new();
        StreamWriter::new(&mut buf).i64_val(v).unwrap();
        let text = String::from_utf8(buf).unwrap();
        let mut r = Reader::new(&text);
        match r.next_token() {
            Some(Token::Num(n)) => assert_eq!(n.as_i64(), Some(v), "i64 {v} via {text}"),
            t => panic!("unexpected token {t:?} for i64 {v}"),
        }
    }
}
