//! Acceptance tests for pipelined asynchronous tuning (`--pipeline-depth`):
//!
//! - depth 1 reproduces the classic serial plan → measure → observe loop
//!   bit-identically (best point, trace, ledger charges),
//! - depth ≥ 2 never breaches `total_measurements` or a shared ledger's
//!   allowance (charge-before-submit),
//! - a strategy early-stop and a mid-pipeline fleet loss both drain every
//!   in-flight batch cleanly (observed or settled — never leaked), and
//! - on a throttled two-shard fleet, depth 2 completes a fixed budget in
//!   measurably less wall-clock than depth 1 with identical measured
//!   values — the paper's optimization-time lever (§ "42.2% reduction").

use arco::baselines::autotvm::{AutoTvm, AutoTvmParams};
use arco::baselines::RandomSearch;
use arco::eval::{
    serve_measure_local_with, AnalyticalBackend, BackendSpec, BudgetLedger, Dispatcher, Engine,
    EngineConfig, FleetLostError, MeasureBackend, MeasureResult, PointKey, ServeOptions,
};
use arco::space::{ConfigSpace, PointConfig};
use arco::tuner::{tune_task_tenant, tune_task_with, Strategy, TenantContext, TuneBudget};
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn space() -> ConfigSpace {
    ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
}

fn analytical() -> Engine {
    Engine::with_backend(Box::new(AnalyticalBackend), 2, true)
}

/// `n` points with pairwise-distinct cache identities.
fn distinct_points(s: &ConfigSpace, seed: u64, n: usize) -> Vec<PointConfig> {
    let mut rng = Pcg32::seeded(seed);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    while out.len() < n {
        let p = s.random_point(&mut rng);
        if seen.insert(PointKey::of(s, &p)) {
            out.push(p);
        }
    }
    out
}

/// Everything a trace entry carries except the wall-clock stamp (which no
/// two runs can share bit-for-bit).
type TraceRow = (usize, usize, f64, f64, bool, f64);

fn trace_rows(result: &arco::tuner::TaskTuneResult) -> Vec<TraceRow> {
    result
        .trace
        .iter()
        .map(|e| (e.ordinal, e.iteration, e.gflops, e.best_gflops, e.valid, e.modeled_cum_secs))
        .collect()
}

/// A from-scratch reimplementation of the pre-pipelining serial loop —
/// the reference the depth-1 pipeline must reproduce bit-identically.
fn serial_reference(
    engine: &Engine,
    s: &ConfigSpace,
    strategy: &mut dyn Strategy,
    budget: TuneBudget,
) -> (Option<PointConfig>, MeasureResult, usize, Vec<TraceRow>) {
    let mut best = MeasureResult {
        seconds: f64::INFINITY,
        cycles: 0,
        gflops: 0.0,
        area_mm2: 0.0,
        occupancy: 0.0,
        valid: false,
    };
    let mut best_point = None;
    let mut measured = 0usize;
    let mut iteration = 0usize;
    let mut modeled = 0.0f64;
    let mut rows = Vec::new();
    while measured < budget.total_measurements && iteration < budget.max_iterations {
        let want = budget.batch.min(budget.total_measurements - measured);
        let mut plan = strategy.plan(want);
        plan.truncate(want);
        if plan.is_empty() {
            break;
        }
        let batch = engine.try_measure_paired(s, plan).unwrap();
        for (p, r) in &batch.pairs {
            measured += 1;
            modeled += if r.valid {
                budget.measure_overhead_secs + budget.measure_repeats as f64 * r.seconds
            } else {
                budget.invalid_timeout_secs
            };
            if r.valid && r.area_mm2 <= budget.area_budget_mm2 && r.seconds < best.seconds {
                best = *r;
                best_point = Some(p.clone());
            }
            rows.push((measured, iteration, r.gflops, best.gflops, r.valid, modeled));
        }
        strategy.observe(&batch.pairs);
        iteration += 1;
    }
    (best_point, best, measured, rows)
}

#[test]
fn depth_1_reproduces_the_serial_loop_bit_identically() {
    let s = space();
    let budget = TuneBudget { total_measurements: 48, batch: 16, workers: 2, ..Default::default() };
    assert_eq!(budget.pipeline_depth, 1, "serial must be the default");

    // Reference: the hand-rolled pre-refactor loop, model-based strategy
    // (AutoTVM replans from every observation, so any ordering or
    // staleness drift in the pipeline would change its plans).
    let mut reference_strategy = AutoTvm::new(s.clone(), AutoTvmParams::quick(), 17);
    let (ref_best_point, ref_best, ref_measured, ref_rows) =
        serial_reference(&analytical(), &s, &mut reference_strategy, budget);

    let mut strategy = AutoTvm::new(s.clone(), AutoTvmParams::quick(), 17);
    let out = tune_task_with(&analytical(), &s, &mut strategy, budget).unwrap();

    assert_eq!(out.best_point, ref_best_point, "depth-1 best point diverged from serial");
    assert_eq!(out.best.seconds, ref_best.seconds);
    assert_eq!(out.best.cycles, ref_best.cycles);
    assert_eq!(out.measurements, ref_measured);
    assert_eq!(trace_rows(&out), ref_rows, "depth-1 trace diverged from serial");
}

#[test]
fn depth_1_and_depth_2_are_identical_for_an_observation_free_strategy() {
    // Random search ignores observations entirely, so pipelining cannot
    // change its plans: depth 2 must reproduce depth 1 exactly — same
    // best point, same trace values, same in-order ordinals.
    let s = space();
    let serial_budget =
        TuneBudget { total_measurements: 60, batch: 12, workers: 2, ..Default::default() };
    let piped_budget = TuneBudget { pipeline_depth: 2, ..serial_budget };

    let mut strat = RandomSearch::new(s.clone(), 23);
    let serial = tune_task_with(&analytical(), &s, &mut strat, serial_budget).unwrap();
    let mut strat = RandomSearch::new(s.clone(), 23);
    let piped = tune_task_with(&analytical(), &s, &mut strat, piped_budget).unwrap();

    assert_eq!(serial.best_point, piped.best_point);
    assert_eq!(serial.best.seconds, piped.best.seconds);
    assert_eq!(serial.measurements, piped.measurements);
    assert_eq!(trace_rows(&serial), trace_rows(&piped));
    for (i, e) in piped.trace.iter().enumerate() {
        assert_eq!(e.ordinal, i + 1, "pipelined trace ordinals must stay in order");
    }
}

#[test]
fn deep_pipeline_never_breaches_budget_or_ledger() {
    let s = space();
    let engine = analytical();
    let ledger = BudgetLedger::new(10);
    let dispatcher = Dispatcher::new(1);
    let tenant = TenantContext {
        ledger: Some(&ledger),
        dispatcher: &dispatcher,
        framework: "random",
        task_id: "t0",
        observer: None,
    };
    let mut strategy = RandomSearch::new(s.clone(), 3);
    // The local budget is not binding (100 points allowed); the shared
    // 10-point ledger is — and three batches can be in flight at once, so
    // only charge-before-submit keeps the pipeline inside the allowance.
    let budget = TuneBudget {
        total_measurements: 100,
        batch: 4,
        workers: 2,
        pipeline_depth: 3,
        ..Default::default()
    };
    let out = tune_task_tenant(&engine, &s, &mut strategy, budget, Some(&tenant)).unwrap();
    assert_eq!(out.measurements, 10, "the shared ledger must cap the pipelined job");
    assert_eq!(out.trace.len(), 10);
    let account = ledger.account("random", "t0");
    assert_eq!(account.charged, 10);
    assert_eq!(account.settled(), 10, "every in-flight charge must settle");
    assert_eq!(ledger.remaining("random", "t0"), 0);

    // And the local budget cap holds on its own at depth 2.
    let engine = analytical();
    let mut strategy = RandomSearch::new(s.clone(), 5);
    let budget = TuneBudget {
        total_measurements: 10,
        batch: 4,
        workers: 2,
        pipeline_depth: 2,
        ..Default::default()
    };
    let out = tune_task_with(&engine, &s, &mut strategy, budget).unwrap();
    assert_eq!(out.measurements, 10, "total_measurements must bound the pipeline");
    assert_eq!(out.trace.last().unwrap().ordinal, 10);
}

/// Plans a fixed script of batches, then stops; counts observations.
struct ScriptedPlanner {
    batches: Vec<Vec<PointConfig>>,
    next: usize,
    observed: usize,
}

impl Strategy for ScriptedPlanner {
    fn name(&self) -> &'static str {
        "scripted"
    }
    fn plan(&mut self, _batch: usize) -> Vec<PointConfig> {
        let batch = self.batches.get(self.next).cloned().unwrap_or_default();
        self.next += 1;
        batch
    }
    fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
        self.observed += results.len();
    }
    fn max_pipeline_depth(&self) -> usize {
        usize::MAX
    }
}

#[test]
fn strategy_early_stop_drains_every_inflight_batch() {
    let s = space();
    let engine = analytical();
    let points = distinct_points(&s, 71, 12);
    let mut strategy = ScriptedPlanner {
        batches: points.chunks(4).map(<[PointConfig]>::to_vec).collect(),
        next: 0,
        observed: 0,
    };
    // Depth 3: all three batches can be in flight when the strategy
    // returns its empty fourth plan — every one must still be observed.
    let budget = TuneBudget {
        total_measurements: 100,
        batch: 4,
        workers: 2,
        pipeline_depth: 3,
        ..Default::default()
    };
    let out = tune_task_with(&engine, &s, &mut strategy, budget).unwrap();
    assert_eq!(out.measurements, 12, "early stop must drain in-flight batches, not drop them");
    assert_eq!(strategy.observed, 12, "every drained batch must reach observe()");
    assert_eq!(out.trace.len(), 12);
    for (i, e) in out.trace.iter().enumerate() {
        assert_eq!(e.ordinal, i + 1);
    }
    assert_eq!(out.trace.last().unwrap().iteration, 2, "three planning iterations ran");
}

/// An analytical oracle whose substrate vanishes after serving two batch
/// calls — the mid-pipeline whole-fleet outage.
struct DyingBackend {
    calls: AtomicUsize,
}

impl MeasureBackend for DyingBackend {
    fn name(&self) -> &'static str {
        "dying"
    }
    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        AnalyticalBackend.measure(space, point)
    }
    fn try_measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
        if self.calls.fetch_add(1, Ordering::SeqCst) >= 2 {
            return Err(anyhow::Error::new(FleetLostError {
                undeliverable: points.len(),
                rounds: 1,
                last_error: "synthetic mid-pipeline outage".into(),
            }));
        }
        Ok(self.measure_many_traced(space, points, workers))
    }
}

#[test]
fn fleet_loss_mid_pipeline_fails_cleanly_and_settles_completed_batches() {
    let s = space();
    let engine = Engine::with_backend(Box::new(DyingBackend { calls: AtomicUsize::new(0) }), 2, true);
    let ledger = BudgetLedger::new(100);
    let dispatcher = Dispatcher::new(2);
    let tenant = TenantContext {
        ledger: Some(&ledger),
        dispatcher: &dispatcher,
        framework: "random",
        task_id: "t0",
        observer: None,
    };
    let mut strategy = RandomSearch::new(s.clone(), 7);
    let budget = TuneBudget {
        total_measurements: 24,
        batch: 4,
        workers: 2,
        pipeline_depth: 2,
        ..Default::default()
    };
    let err = tune_task_tenant(&engine, &s, &mut strategy, budget, Some(&tenant)).unwrap_err();
    assert!(
        err.as_ref().downcast_ref::<FleetLostError>().is_some(),
        "expected FleetLostError, got: {err}"
    );

    // The backend served exactly two 4-point batches before the outage:
    // those 8 points are settled — even a batch that completed *after*
    // the failure was first observed settles via the error-path drain —
    // while the batches the fleet never answered stay
    // charged-but-unsettled (honest accounting). How many batches got
    // submitted before the failure drained (3 or 4) depends on thread
    // scheduling, so the charge is bounded, not exact.
    let account = ledger.account("random", "t0");
    assert_eq!(account.settled(), 8, "completed batches must settle even on the error path");
    assert!(
        account.charged >= 12 && account.charged <= 16,
        "charge-before-submit must cover every submitted batch (charged {})",
        account.charged
    );
    assert!(
        account.charged > account.settled(),
        "the unanswered batches must stay charged-but-unsettled"
    );
    // The dispatcher leaked no permits: a fresh checkout succeeds at once.
    drop(dispatcher.checkout());
}

#[test]
fn depth_2_on_a_throttled_two_shard_fleet_beats_depth_1_with_identical_numbers() {
    // The acceptance scenario: a fixed budget on a two-shard fleet with
    // injected per-point latency. Depth 1 pays (batches x batch-latency)
    // serially; depth 2 keeps both batches' chunks in flight, so the
    // shards' (parallel) sleeps overlap and wall-clock roughly halves.
    // Measured values must be bit-identical — pipelining moves time, not
    // numbers.
    let delay = Duration::from_millis(5);
    let budget_points = 144usize;
    let batch = 24usize;
    let run = |depth: usize| {
        let shard_a = serve_measure_local_with(
            Arc::new(Engine::new(EngineConfig {
                backend: arco::eval::BackendKind::Analytical.into(),
                workers: 2,
                ..Default::default()
            })
            .unwrap()),
            ServeOptions { measure_delay: delay, ..ServeOptions::default() },
        )
        .unwrap();
        let shard_b = serve_measure_local_with(
            Arc::new(Engine::new(EngineConfig {
                backend: arco::eval::BackendKind::Analytical.into(),
                workers: 2,
                ..Default::default()
            })
            .unwrap()),
            ServeOptions { measure_delay: delay, ..ServeOptions::default() },
        )
        .unwrap();
        let engine = Engine::new(EngineConfig {
            backend: BackendSpec::Remote(vec![
                shard_a.addr().to_string(),
                shard_b.addr().to_string(),
            ]),
            workers: 2,
            ..Default::default()
        })
        .unwrap();
        let s = space();
        let mut strategy = RandomSearch::new(s.clone(), 29);
        let budget = TuneBudget {
            total_measurements: budget_points,
            batch,
            workers: 2,
            pipeline_depth: depth,
            ..Default::default()
        };
        let started = Instant::now();
        let out = tune_task_with(&engine, &s, &mut strategy, budget).unwrap();
        let elapsed = started.elapsed();
        shard_a.shutdown();
        shard_b.shutdown();
        (out, elapsed)
    };

    let (serial, serial_elapsed) = run(1);
    let (piped, piped_elapsed) = run(2);

    // Identical numbers for the shared (identically planned) points.
    assert_eq!(serial.measurements, budget_points);
    assert_eq!(piped.measurements, budget_points);
    assert_eq!(serial.best_point, piped.best_point, "pipelining changed the best point");
    assert_eq!(serial.best.seconds, piped.best.seconds);
    assert_eq!(trace_rows(&serial), trace_rows(&piped), "pipelining changed measured values");

    // Measurably less wall-clock: the injected latency dominates both
    // runs (6 batches x 12 points/shard x 5 ms >= 360 ms serial), so the
    // overlap must show even on a loaded CI machine.
    assert!(
        piped_elapsed.as_secs_f64() < serial_elapsed.as_secs_f64() * 0.85,
        "depth 2 ({piped_elapsed:?}) should beat depth 1 ({serial_elapsed:?}) \
         on a throttled two-shard fleet"
    );
}
