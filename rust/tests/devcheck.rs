//! `arco devcheck` integration tests: each fixture under
//! `rust/tests/fixtures/devcheck/` trips exactly one rule with the
//! documented diagnostic (checked under a *virtual* path, so the
//! fixtures themselves never pollute the real-repo walk), and the
//! repository itself is clean.

use arco::devcheck::model::SourceFile;
use arco::devcheck::{check_repo, codec, guard_io, ledger_order, panic_free, wire_docs, Finding};
use std::path::Path;

/// Parse a fixture under a virtual repo path and run one rule over it,
/// applying the same suppression filter `check_repo` uses.
fn run_rule<F>(virtual_path: &str, fixture: &str, rule: F) -> Vec<Finding>
where
    F: Fn(&SourceFile) -> Vec<Finding>,
{
    let f = SourceFile::parse(virtual_path.to_string(), fixture);
    rule(&f)
        .into_iter()
        .filter(|fd| !f.allowed(fd.rule, fd.line))
        .collect()
}

#[test]
fn panic_fixture_trips_panic_free_once() {
    let fs = run_rule(
        "rust/src/eval/server.rs",
        include_str!("fixtures/devcheck/panic_unwrap.rs"),
        panic_free::check,
    );
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "panic-free");
    assert_eq!(fs[0].line, 5);
    assert!(fs[0].message.contains(".unwrap()"), "{}", fs[0].message);
    // The documented diagnostic line format.
    assert!(fs[0]
        .render()
        .starts_with("devcheck: panic-free: rust/src/eval/server.rs:5: "));
}

#[test]
fn suppression_marker_waives_the_finding() {
    let fs = run_rule(
        "rust/src/eval/server.rs",
        include_str!("fixtures/devcheck/panic_suppressed.rs"),
        panic_free::check,
    );
    assert!(fs.is_empty(), "{fs:?}");
}

#[test]
fn ledger_fixture_trips_ledger_order_once() {
    let fs = run_rule(
        "rust/src/tuner/task_tuner.rs",
        include_str!("fixtures/devcheck/ledger_missing_charge.rs"),
        ledger_order::check,
    );
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "ledger-order");
    assert_eq!(fs[0].line, 5);
    assert!(fs[0].message.contains("rogue_tuner"), "{}", fs[0].message);
    assert!(
        fs[0].message.contains("no preceding `charge"),
        "{}",
        fs[0].message
    );
}

#[test]
fn screen_fixture_trips_ledger_order_once() {
    let fs = run_rule(
        "rust/src/tuner/task_tuner.rs",
        include_str!("fixtures/devcheck/screen_missing_charge.rs"),
        ledger_order::check,
    );
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "ledger-order");
    assert_eq!(fs[0].line, 8);
    assert!(fs[0].message.contains("rogue_screener"), "{}", fs[0].message);
    assert!(
        fs[0].message.contains("`screen_batch`"),
        "{}",
        fs[0].message
    );
}

#[test]
fn codec_fixture_trips_codec_discipline_once() {
    let fs = run_rule(
        "rust/src/eval/proto.rs",
        include_str!("fixtures/devcheck/codec_tree_parse.rs"),
        codec::check,
    );
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "codec-discipline");
    assert_eq!(fs[0].line, 6);
    assert!(fs[0].message.contains("decode_hot"), "{}", fs[0].message);
}

#[test]
fn guard_fixture_trips_guard_io_once() {
    let fs = run_rule(
        "rust/src/eval/tune_server.rs",
        include_str!("fixtures/devcheck/guard_across_io.rs"),
        guard_io::check,
    );
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "guard-io");
    assert_eq!(fs[0].line, 7);
    assert!(fs[0].message.contains("`jobs`"), "{}", fs[0].message);
}

#[test]
fn wire_fixture_trips_wire_docs_once() {
    let proto = SourceFile::parse(
        "rust/src/eval/proto.rs".to_string(),
        include_str!("fixtures/devcheck/wire_undocumented_field.rs"),
    );
    let wire_md = "| `task` | the task shape | yes |";
    let fs = wire_docs::check(&[&proto], wire_md, "");
    assert_eq!(fs.len(), 1, "{fs:?}");
    assert_eq!(fs[0].rule, "wire-docs");
    assert_eq!(fs[0].line, 6);
    assert!(fs[0].message.contains("\"mystery\""), "{}", fs[0].message);
}

#[test]
fn wire_docs_catches_drift_in_both_directions() {
    let proto = SourceFile::parse(
        "rust/src/eval/tune_server.rs".to_string(),
        r#"fn reply() -> TuneResponse {
            TuneResponse::Error(format!("quota exhausted: client {c} has spent its {q} points"))
        }"#,
    );
    // Direction docs -> code: a documented text with drifted wording.
    let ops = "## Failure modes\n\
               | `quota exhausted: client {c} ran out of {q} points` | quota | raise it |";
    let fs = wire_docs::check(&[&proto], "", ops);
    let rules: Vec<&str> = fs.iter().map(|f| f.file.as_str()).collect();
    // Both sides flag: the doc text matches no literal, and the Error
    // reply matches no doc text.
    assert!(rules.contains(&"docs/OPERATIONS.md"), "{fs:?}");
    assert!(rules.contains(&"rust/src/eval/tune_server.rs"), "{fs:?}");

    // With matching wording both directions are clean.
    let ops_ok = "## Failure modes\n\
                  | `quota exhausted: client {c} has spent its {q} points` | quota | raise it |";
    assert!(wire_docs::check(&[&proto], "", ops_ok).is_empty());
}

/// The acceptance gate: the repository itself carries no violations.
/// Every deliberate exception is suppressed at its site with a
/// justification comment.
#[test]
fn repository_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let findings = check_repo(root).expect("devcheck walk");
    let rendered: Vec<String> = findings.iter().map(|f| f.render()).collect();
    assert!(
        findings.is_empty(),
        "devcheck found violations in the repo:\n{}",
        rendered.join("\n")
    );
}
