//! Integration: the AOT/XLA backend must agree with the native mirror.
//!
//! These tests require `make artifacts` to have run AND a real PJRT-backed
//! `xla` crate (the offline build vendors a compile-only stub); they
//! self-skip (with a loud message) when either is unavailable so
//! `cargo test` stays green in a fresh checkout.

use arco::ml::{ppo, Mat, Mlp};
use arco::runtime::manifest::artifacts_dir;
use arco::runtime::{Engine, ModelDims};
use arco::util::prop::assert_allclose_f32;
use arco::util::rng::Pcg32;

fn engine_or_skip() -> Option<Engine> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    match Engine::load(&dir) {
        Ok(e) => Some(e),
        Err(e) => {
            eprintln!(
                "SKIP: artifacts present but the PJRT engine failed to load ({e}); \
                 link the real `xla` crate instead of vendor/xla to run parity tests"
            );
            None
        }
    }
}

fn dims() -> ModelDims {
    ModelDims::default()
}

#[test]
fn policy_forward_parity_native_vs_xla() {
    let Some(engine) = engine_or_skip() else { return };
    let d = dims();
    let mut rng = Pcg32::seeded(1234);
    let mlp = Mlp::policy(d.obs_dim, d.act_dim, &mut rng);
    let params = mlp.flatten();

    let obs_mat = Mat::rand_init(d.b_pol, d.obs_dim, &mut rng);
    let mut mask = vec![1.0f32; d.act_dim];
    for m in mask.iter_mut().skip(9) {
        *m = 0.0; // software-agent mask
    }

    // Native: logits -> masked log softmax.
    let cache = mlp.forward(&obs_mat);
    let native_lp = ppo::masked_log_softmax(cache.output(), &mask);

    // XLA path.
    let xla_lp = engine.policy_forward(&params, &obs_mat.data, &mask).unwrap();

    // Compare only unmasked entries (masked are -inf vs -1e30 sentinels).
    for r in 0..d.b_pol {
        for c in 0..d.act_dim {
            if mask[c] > 0.0 {
                let a = native_lp.at(r, c);
                let b = xla_lp[r * d.act_dim + c];
                assert!(
                    (a - b).abs() < 1e-4,
                    "logp[{r},{c}]: native {a} vs xla {b}"
                );
            }
        }
    }
}

#[test]
fn value_forward_parity_native_vs_xla() {
    let Some(engine) = engine_or_skip() else { return };
    let d = dims();
    let mut rng = Pcg32::seeded(77);
    let mlp = Mlp::value(d.gstate_dim, &mut rng);
    let params = mlp.flatten();
    let state = Mat::rand_init(d.b_pol, d.gstate_dim, &mut rng);

    let native: Vec<f32> = {
        let cache = mlp.forward(&state);
        cache.output().data.clone()
    };
    let xla = engine.value_forward(&params, &state.data).unwrap();
    assert_allclose_f32(&native, &xla, 1e-4, 1e-5, "value forward parity");
}

#[test]
fn gae_parity_native_vs_xla() {
    let Some(engine) = engine_or_skip() else { return };
    let d = dims();
    let mut rng = Pcg32::seeded(5);
    let rewards: Vec<f32> = (0..d.t_gae).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
    let values: Vec<f32> = (0..d.t_gae).map(|_| rng.gen_f32()).collect();
    let (gamma, lam) = (0.99f32, 0.95f32);
    let (native_adv, native_ret) = ppo::gae(&rewards, &values, 0.3, gamma, lam);
    let (xla_adv, xla_ret) = engine.gae(&rewards, &values, 0.3, gamma, lam).unwrap();
    assert_allclose_f32(&native_adv, &xla_adv, 2e-3, 2e-3, "gae adv parity");
    assert_allclose_f32(&native_ret, &xla_ret, 2e-3, 2e-3, "gae ret parity");
}

#[test]
fn policy_train_step_reduces_loss_and_matches_native_direction() {
    let Some(engine) = engine_or_skip() else { return };
    let d = dims();
    let mut rng = Pcg32::seeded(99);
    let mlp = Mlp::policy(d.obs_dim, d.act_dim, &mut rng);
    let mut params = mlp.flatten();
    let mut m = vec![0.0f32; d.p_policy];
    let mut v = vec![0.0f32; d.p_policy];
    let mut t = 0.0f32;

    let obs = Mat::rand_init(d.b_train, d.obs_dim, &mut rng);
    let mask = vec![1.0f32; d.act_dim];
    // Old log-probs from the initial policy; fixed advantages.
    let cache = mlp.forward(&obs);
    let lp = ppo::masked_log_softmax(cache.output(), &mask);
    let probs = lp.map(|x| if x.is_finite() { x.exp() } else { 0.0 });
    let actions = ppo::sample_actions(&probs, &mut rng);
    let old_logp: Vec<f32> = actions.iter().enumerate().map(|(r, &a)| lp.at(r, a)).collect();
    let adv: Vec<f32> = (0..d.b_train).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
    let weight = vec![1.0f32; d.b_train];
    let actions_i32: Vec<i32> = actions.iter().map(|&a| a as i32).collect();

    let mut losses = Vec::new();
    for _ in 0..8 {
        let out = engine
            .policy_train(
                &params, &m, &v, t, &obs.data, &mask, &actions_i32, &old_logp, &adv, &weight,
            )
            .unwrap();
        losses.push(out.loss);
        params = out.params;
        m = out.m;
        v = out.v;
        t = out.t;
    }
    assert_eq!(t, 8.0);
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "losses should fall: {losses:?}"
    );
}

#[test]
fn value_train_step_regresses() {
    let Some(engine) = engine_or_skip() else { return };
    let d = dims();
    let mut rng = Pcg32::seeded(31);
    let mlp = Mlp::value(d.gstate_dim, &mut rng);
    let mut params = mlp.flatten();
    let mut m = vec![0.0f32; d.p_value];
    let mut v = vec![0.0f32; d.p_value];
    let mut t = 0.0f32;
    let state = Mat::rand_init(d.b_train, d.gstate_dim, &mut rng);
    let returns: Vec<f32> = (0..d.b_train).map(|r| state.at(r, 0).tanh()).collect();
    let weight = vec![1.0f32; d.b_train];

    let mut first = None;
    let mut last = 0.0;
    for _ in 0..60 {
        let out = engine.value_train(&params, &m, &v, t, &state.data, &returns, &weight).unwrap();
        params = out.params;
        m = out.m;
        v = out.v;
        t = out.t;
        last = out.loss;
        first.get_or_insert(out.loss);
    }
    let first = first.unwrap();
    assert!(last < first * 0.5, "value loss {first} -> {last}");
}

#[test]
fn bad_shapes_rejected() {
    let Some(engine) = engine_or_skip() else { return };
    let d = dims();
    let params = vec![0.0f32; d.p_policy - 1];
    let obs = vec![0.0f32; d.b_pol * d.obs_dim];
    let mask = vec![1.0f32; d.act_dim];
    assert!(engine.policy_forward(&params, &obs, &mask).is_err());
}
