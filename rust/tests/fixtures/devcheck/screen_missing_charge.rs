// Fixture: trips `ledger-order` exactly once — `screen_batch` (the
// multi-fidelity screening split) with no lexically preceding
// `charge(...)` in the same function: the diverted low-fidelity points
// would bypass the budget ledger. The second function is the compliant
// shape — admit, split, settle the screened remainder — and must NOT be
// flagged.
pub fn rogue_screener(space: &Space, plan: Vec<Point>) {
    let split = screen_batch(space, plan, 0.25);
    submit(split.kept);
}

pub fn honest_screener(ledger: &Ledger, space: &Space, plan: Vec<Point>) {
    let admitted = ledger.charge("arco", "t0", plan.len());
    let split = screen_batch(space, plan, 0.25);
    ledger.charge_screen("arco", "t0", split.rejected.len(), 1e-6);
    submit(split.kept);
}
