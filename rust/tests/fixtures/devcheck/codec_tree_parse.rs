// Fixture: trips `codec-discipline` exactly once — tree `Json::parse`
// on the hot path. The call inside `request_from_line` is the named
// lenient fallback for the proto.rs virtual path and must NOT be
// flagged.
pub fn decode_hot(line: &str) -> Option<Request> {
    Request::from_json(&Json::parse(line).ok()?)
}

pub fn request_from_line(line: &str) -> Option<Request> {
    Request::from_json(&Json::parse(line).ok()?)
}
