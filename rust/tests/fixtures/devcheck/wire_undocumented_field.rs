// Fixture: trips `wire-docs` exactly once — the codec writes a
// `mystery` field that the fixture WIRE.md table does not mention.
// `task` is documented and must NOT be flagged.
pub fn encode(w: &mut StreamWriter) {
    w.key("task");
    w.key("mystery");
}
