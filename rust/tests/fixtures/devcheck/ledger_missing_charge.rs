// Fixture: trips `ledger-order` exactly once — `submit_batch` with no
// lexically preceding `charge(...)` in the same function. The second
// function is the compliant shape and must NOT be flagged.
pub fn rogue_tuner(engine: &Engine, points: &[Point]) {
    let batch = engine.submit_batch(points);
    batch.wait();
}

pub fn honest_tuner(ledger: &Ledger, engine: &Engine, points: &[Point]) {
    ledger.charge("arco", points.len());
    let batch = engine.submit_batch(points);
    ledger.settle("arco", batch.wait());
}
