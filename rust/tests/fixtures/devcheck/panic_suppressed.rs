// Fixture: the same violation as panic_unwrap.rs, but waived with an
// inline suppression — devcheck must report nothing.
pub fn serve_connection(state: &std::sync::Mutex<u32>) -> u32 {
    // A deliberate exception, documented at the site. devcheck:allow(panic-free)
    let guard = state.lock().unwrap();
    *guard
}
