// Fixture: trips `guard-io` exactly once — `write_tune_response_frame`
// runs while the `jobs` guard is live. The second function drops the
// guard first and must NOT be flagged.
pub fn reply_while_locked(shared: &Shared, out: &mut impl Write) {
    let jobs = lock_unpoisoned(&shared.jobs);
    let resp = jobs.status_of(7);
    write_tune_response_frame(out, &resp);
}

pub fn reply_after_unlock(shared: &Shared, out: &mut impl Write) {
    let resp = {
        let jobs = lock_unpoisoned(&shared.jobs);
        jobs.status_of(7)
    };
    write_tune_response_frame(out, &resp);
}
