// Fixture: trips `panic-free` exactly once — the `.unwrap()` below.
// Checked under the virtual path rust/src/eval/server.rs; the panic!
// in the #[cfg(test)] module must NOT be flagged.
pub fn serve_connection(state: &std::sync::Mutex<u32>) -> u32 {
    let guard = state.lock().unwrap();
    *guard
}

#[cfg(test)]
mod tests {
    pub fn helper() {
        panic!("fine here: test code is exempt");
    }
}
