//! Equal-budget protocol end-to-end: the concurrent multi-tenant driver
//! must reproduce the serial driver's results exactly (deterministic
//! backends), the shared ledger must debit every admitted point and never
//! breach the per-task allowance, and both properties must hold when the
//! measurements flow through a loopback two-shard `serve-measure` fleet.

use arco::eval::{
    serve_measure_local, serve_measure_local_with, BackendKind, BackendSpec, Engine,
    EngineConfig, RemoteBackend, ServeOptions, ServerHandle,
};
use arco::tuner::{
    compare_frameworks_opts, compare_frameworks_with, tune_model_concurrent, tune_model_with,
    CompareReport, DriverOptions, Framework, SharedRun, TuneBudget,
};
use arco::workload::model_by_name;
use std::sync::Arc;
use std::time::Duration;

/// The analytical backend keeps these end-to-end runs CI-fast while still
/// exercising the full plan → charge → dispatch → measure → settle path.
fn analytical_engine() -> Engine {
    Engine::new(EngineConfig {
        backend: BackendKind::Analytical.into(),
        workers: 2,
        ..Default::default()
    })
    .unwrap()
}

fn budget() -> TuneBudget {
    TuneBudget { total_measurements: 12, batch: 4, workers: 2, ..Default::default() }
}

/// Spawn a loopback analytical shard.
fn shard() -> ServerHandle {
    serve_measure_local(Arc::new(analytical_engine())).unwrap()
}

fn assert_same_outcomes(serial: &CompareReport, other: &CompareReport, context: &str) {
    assert_eq!(serial.outcomes.len(), other.outcomes.len());
    for (s, o) in serial.outcomes.iter().zip(&other.outcomes) {
        assert_eq!(s.framework, o.framework);
        assert_eq!(s.tasks.len(), o.tasks.len());
        for (st, ot) in s.tasks.iter().zip(&o.tasks) {
            assert_eq!(st.task_id, ot.task_id);
            assert_eq!(
                st.result.best_point, ot.result.best_point,
                "[{context}] {} {}: best point diverged",
                s.framework.name(),
                st.task_id
            );
            assert_eq!(st.result.best.seconds, ot.result.best.seconds);
            assert_eq!(st.result.best.cycles, ot.result.best.cycles);
            assert_eq!(
                st.result.measurements, ot.result.measurements,
                "[{context}] {} {}: measurement count diverged",
                s.framework.name(),
                st.task_id
            );
        }
        assert_eq!(s.inference_secs, o.inference_secs);
    }
}

#[test]
fn concurrent_tune_model_matches_serial_best_points() {
    let model = model_by_name("alexnet").unwrap();

    let serial_engine = analytical_engine();
    let serial = tune_model_with(&serial_engine, Framework::AutoTvm, &model, budget(), true, 9).unwrap();

    let concurrent_engine = analytical_engine();
    let shared = SharedRun::new(&concurrent_engine, &budget(), true);
    let concurrent = tune_model_concurrent(
        &concurrent_engine,
        Framework::AutoTvm,
        &model,
        budget(),
        true,
        9,
        &shared,
    )
    .unwrap();

    assert_eq!(serial.tasks.len(), concurrent.tasks.len());
    for (s, c) in serial.tasks.iter().zip(&concurrent.tasks) {
        assert_eq!(s.task_id, c.task_id);
        assert_eq!(s.result.best_point, c.result.best_point, "task {}", s.task_id);
        assert_eq!(s.result.best.seconds, c.result.best.seconds);
        assert_eq!(s.result.measurements, c.result.measurements);
    }
    assert_eq!(serial.inference_secs, concurrent.inference_secs);
    // Every task job was debited on the shared ledger, exactly what it
    // measured.
    let ledger = shared.ledger().expect("shared-budget run has a ledger");
    for t in &concurrent.tasks {
        let account = ledger.account("autotvm", &t.task_id);
        assert_eq!(account.charged, t.result.measurements);
        assert_eq!(account.settled(), account.charged);
        assert!(account.charged <= budget().total_measurements);
    }
}

#[test]
fn shared_budget_paper_set_over_two_shard_fleet() {
    let model = model_by_name("alexnet").unwrap();
    let frameworks = Framework::paper_set();

    // Reference: the serial in-process driver on a fresh engine.
    let serial = compare_frameworks_with(
        &analytical_engine(),
        &frameworks,
        &model,
        budget(),
        true,
        5,
    )
    .unwrap();

    // The same comparison, concurrent with a shared ledger, measuring
    // through a loopback two-shard fleet.
    let shard_a = shard();
    let shard_b = shard();
    let fleet = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![
            shard_a.addr().to_string(),
            shard_b.addr().to_string(),
        ]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(fleet.concurrent_batch_capacity(), 2, "two alive shards = two batch slots");
    let report = compare_frameworks_opts(
        &fleet,
        &frameworks,
        &model,
        budget(),
        true,
        5,
        DriverOptions { concurrent: true, shared_budget: true },
    )
    .unwrap();

    // Trustworthy numbers: the fleet-concurrent run reproduces the serial
    // in-process run point for point — per (framework, task), the same
    // best configuration and the same measurement count (i.e. every
    // framework is debited identically across the two drivers).
    assert_same_outcomes(&serial, &report, "fleet-concurrent vs serial");

    // Ledger invariants: present, within the allowance, fully settled,
    // and in agreement with the per-framework outcome counts.
    let ledger = report.ledger.as_ref().expect("shared-budget run must carry ledger stats");
    assert_eq!(ledger.per_task_points, budget().total_measurements);
    for t in &ledger.tenants {
        assert!(
            t.account.charged <= ledger.per_task_points,
            "{}/{} breached the budget",
            t.framework,
            t.task
        );
        assert_eq!(t.account.settled(), t.account.charged);
    }
    for o in &report.outcomes {
        let charged: usize = ledger
            .tenants
            .iter()
            .filter(|t| t.framework == o.framework.name())
            .map(|t| t.account.charged)
            .sum();
        assert_eq!(charged, o.measurements, "{} ledger/outcome mismatch", o.framework.name());
        assert_eq!(o.fresh + o.cache_served, o.measurements);
    }
    // The fleet served the run: shard engines saw real simulations, and
    // the shard-side `stats` op answers over the same wire.
    let sims_a = shard_a.engine().stats().simulations;
    let sims_b = shard_b.engine().stats().simulations;
    assert!(sims_a + sims_b > 0, "no shard simulated anything");
    let fleet_stats = fleet.fleet_stats();
    assert_eq!(fleet_stats.len(), 2);
    for (_addr, stats) in &fleet_stats {
        assert!(stats.get("simulations").is_some());
        assert!(stats.get("active_connections").is_some());
    }
    shard_a.shutdown();
    shard_b.shutdown();
}

#[test]
fn capacity_shrinks_on_shard_death_and_regrows_on_revival_without_starving_tenants() {
    use arco::baselines::RandomSearch;
    use arco::eval::{BudgetLedger, Dispatcher};
    use arco::space::ConfigSpace;
    use arco::tuner::{tune_task_tenant, TenantContext};
    use arco::workload::Conv2dTask;

    // Throttled shards (15 ms/point) so the run reliably outlives the
    // mid-run kill below; the sleep dominates, so the timing is stable
    // even on loaded CI machines.
    let throttle =
        ServeOptions { measure_delay: Duration::from_millis(15), ..ServeOptions::default() };
    let shard_a = serve_measure_local_with(Arc::new(analytical_engine()), throttle).unwrap();
    let shard_b = serve_measure_local_with(Arc::new(analytical_engine()), throttle).unwrap();
    let addr_b = shard_b.addr().to_string();

    // The test keeps its own handle to the fleet client (revival probe,
    // liveness asserts) while the engine owns a shared one.
    let fleet = Arc::new(
        RemoteBackend::connect(&[shard_a.addr().to_string(), addr_b.clone()]).unwrap(),
    );
    let engine = Engine::with_backend(Box::new(Arc::clone(&fleet)), 2, true);
    assert_eq!(engine.concurrent_batch_capacity(), 2);

    let budget = TuneBudget { total_measurements: 24, batch: 4, workers: 2, ..Default::default() };
    let ledger = BudgetLedger::new(24);
    let dispatcher = Dispatcher::new(engine.concurrent_batch_capacity());
    let spaces = [
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true),
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 14, 14, 64, 3, 3, 1, 1), true),
    ];
    let task_ids = ["t0", "t1"];

    // Two tenants tune concurrently under --shared-budget semantics while
    // shard B is killed mid-run (each tenant has >= 6 batches x 30 ms of
    // mandated shard sleep, so 100 ms lands well inside the run).
    let run = |idx: usize| {
        let mut strategy = RandomSearch::new(spaces[idx].clone(), 90 + idx as u64);
        let tenant = TenantContext {
            ledger: Some(&ledger),
            dispatcher: &dispatcher,
            framework: "random",
            task_id: task_ids[idx],
            observer: None,
        };
        tune_task_tenant(&engine, &spaces[idx], &mut strategy, budget, Some(&tenant))
    };
    let (out_a, out_b) = std::thread::scope(|scope| {
        let killer = scope.spawn(move || {
            std::thread::sleep(Duration::from_millis(100));
            shard_b.shutdown();
        });
        let h0 = scope.spawn(|| run(0));
        let h1 = scope.spawn(|| run(1));
        killer.join().unwrap();
        (h0.join().unwrap(), h1.join().unwrap())
    });

    // No tenant starves: both complete their full allowance despite the
    // mid-run capacity loss, and the ledger agrees.
    let out_a = out_a.expect("survivor shard must keep the run alive");
    let out_b = out_b.expect("survivor shard must keep the run alive");
    assert_eq!(out_a.measurements, 24, "tenant t0 starved");
    assert_eq!(out_b.measurements, 24, "tenant t1 starved");
    for id in task_ids {
        let account = ledger.account("random", id);
        assert_eq!(account.charged, 24);
        assert_eq!(account.settled(), 24);
    }

    // Capacity shrank: the dead shard was detected by re-dispatch, and the
    // tuning loop's per-batch set_slots pushed the shrink into the
    // dispatcher (FIFO admission kept every permit accounted for).
    assert_eq!(fleet.alive_count(), 1, "shard B must be marked dead");
    assert_eq!(engine.concurrent_batch_capacity(), 1);
    let d = dispatcher.stats();
    assert_eq!(d.slots, 1, "dispatcher must track the shrunken fleet");
    assert_eq!(d.in_flight, 0, "every permit must be released");
    assert_eq!(d.dispatched, 12, "2 tenants x 6 batches, FIFO-admitted exactly once each");

    // Revival: a new shard process on the same address rejoins after a
    // probe, and the next tenant batch regrows dispatcher admission.
    let shard_b2 = arco::eval::serve_measure(&addr_b, Arc::new(analytical_engine())).unwrap();
    fleet.revive_now();
    assert_eq!(fleet.alive_count(), 2, "revived shard must rejoin");
    assert_eq!(engine.concurrent_batch_capacity(), 2);
    let mut strategy = RandomSearch::new(spaces[0].clone(), 777);
    let tenant = TenantContext {
        ledger: None,
        dispatcher: &dispatcher,
        framework: "random",
        task_id: "t2",
        observer: None,
    };
    let small = TuneBudget { total_measurements: 4, batch: 4, workers: 2, ..Default::default() };
    let r = tune_task_tenant(&engine, &spaces[0], &mut strategy, small, Some(&tenant)).unwrap();
    assert_eq!(r.measurements, 4);
    assert_eq!(dispatcher.stats().slots, 2, "revival must regrow dispatcher admission");

    shard_a.shutdown();
    shard_b2.shutdown();
}

#[test]
fn ledger_exhaustion_stops_a_job_mid_batch() {
    // A ledger smaller than the local budget is the binding constraint:
    // with 10 points and batches of 4 the last batch is truncated to 2.
    use arco::eval::{BudgetLedger, Dispatcher};
    use arco::space::ConfigSpace;
    use arco::tuner::{tune_task_tenant, TenantContext};
    use arco::workload::Conv2dTask;

    let space = ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true);
    let engine = analytical_engine();
    let ledger = BudgetLedger::new(10);
    let dispatcher = Dispatcher::new(1);
    let tenant = TenantContext {
        ledger: Some(&ledger),
        dispatcher: &dispatcher,
        framework: "random",
        task_id: "t0",
        observer: None,
    };
    let mut strategy = arco::baselines::RandomSearch::new(space.clone(), 3);
    let big = TuneBudget { total_measurements: 100, batch: 4, workers: 2, ..Default::default() };
    let result = tune_task_tenant(&engine, &space, &mut strategy, big, Some(&tenant)).unwrap();
    assert_eq!(result.measurements, 10, "the shared ledger must cap the job");
    assert_eq!(ledger.account("random", "t0").charged, 10);
    assert_eq!(ledger.remaining("random", "t0"), 0);
    assert_eq!(result.trace.len(), 10);
}
