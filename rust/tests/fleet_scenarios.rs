//! Scenario tests for degraded and heterogeneous measurement fleets:
//!
//! - weighted placement starves an artificially 10×-slower shard of
//!   points (and wall-clock) while producing bit-identical results and
//!   identical ledger charges to uniform placement,
//! - a shard started with `--warm-start` answers previously-journaled
//!   points from its cache (the client ledger sees `fresh = false`),
//! - `arco journal merge` + warm start reproduces an in-process run's
//!   numbers exactly with zero fresh simulator runs, and
//! - a whole-fleet outage surfaces as a typed [`FleetLostError`] through
//!   the engine and the tuning loop instead of a panic.
//!
//! All shards run the analytical backend (CI-fast) with the server's
//! injectable per-point latency hook standing in for genuinely slow
//! hardware.

use arco::baselines::RandomSearch;
use arco::eval::{
    merge_journals, serve_measure_local, serve_measure_local_with, BackendKind, BackendSpec,
    Engine, EngineConfig, FleetLostError, Origin, Placement, PointKey, RemoteBackend,
    ServeOptions, ServerHandle, ShardPlacement,
};
use arco::space::{ConfigSpace, PointConfig};
use arco::tuner::{
    compare_frameworks_opts, tune_task_with, CompareReport, DriverOptions, Framework, TuneBudget,
};
use arco::util::rng::Pcg32;
use arco::workload::{model_by_name, Conv2dTask};
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn space() -> ConfigSpace {
    ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
}

fn analytical_engine() -> Engine {
    Engine::new(EngineConfig {
        backend: BackendKind::Analytical.into(),
        workers: 2,
        ..Default::default()
    })
    .unwrap()
}

/// Loopback analytical shard with an artificial per-point service latency.
fn throttled_shard(delay: Duration) -> ServerHandle {
    serve_measure_local_with(
        Arc::new(analytical_engine()),
        ServeOptions { measure_delay: delay, ..ServeOptions::default() },
    )
    .unwrap()
}

/// `n` points with pairwise-distinct cache identities (so every one of
/// them must cross the wire; cache hits would bypass placement).
fn distinct_points(s: &ConfigSpace, seed: u64, n: usize) -> Vec<PointConfig> {
    let mut rng = Pcg32::seeded(seed);
    let mut seen = std::collections::HashSet::new();
    let mut out = Vec::new();
    while out.len() < n {
        let p = s.random_point(&mut rng);
        if seen.insert(PointKey::of(s, &p)) {
            out.push(p);
        }
    }
    out
}

fn tmp_path(tag: &str) -> PathBuf {
    PathBuf::from("target/tmp").join(format!("fleet_{tag}_{}.jsonl", std::process::id()))
}

fn cleanup(path: &Path) {
    let _ = std::fs::remove_file(path);
    let _ = std::fs::remove_file(PathBuf::from(format!("{}.lock", path.display())));
}

/// Drive `batches` through a fresh two-shard fleet (one 10×-slower) under
/// `placement`; returns (results, per-shard stats of the slow shard,
/// wall-clock).
fn run_hetero_fleet(
    placement: Placement,
    batches: &[Vec<PointConfig>],
    s: &ConfigSpace,
) -> (Vec<arco::eval::MeasureResult>, ShardPlacement, Duration) {
    let fast = throttled_shard(Duration::from_millis(1));
    let slow = throttled_shard(Duration::from_millis(10));
    let slow_addr = slow.addr().to_string();
    let backend = RemoteBackend::connect_with(
        &[fast.addr().to_string(), slow_addr.clone()],
        placement,
    )
    .unwrap();
    let engine = Engine::with_backend(Box::new(backend), 2, true);

    let started = Instant::now();
    let mut results = Vec::new();
    for batch in batches {
        results.extend(engine.measure_batch(s, batch));
    }
    let elapsed = started.elapsed();

    let slow_stats = engine
        .stats()
        .placement
        .into_iter()
        .find(|p| p.addr == slow_addr)
        .expect("slow shard must appear in placement stats");
    fast.shutdown();
    slow.shutdown();
    (results, slow_stats, elapsed)
}

#[test]
fn weighted_placement_starves_slow_shard_with_identical_results() {
    let s = space();
    // Six batches of 36 distinct points each (distinct across batches too,
    // so nothing is answered by the client cache).
    let all = distinct_points(&s, 4242, 216);
    let batches: Vec<Vec<PointConfig>> = all.chunks(36).map(<[PointConfig]>::to_vec).collect();

    let (uniform_results, uniform_slow, uniform_elapsed) =
        run_hetero_fleet(Placement::Uniform, &batches, &s);
    let (weighted_results, weighted_slow, weighted_elapsed) =
        run_hetero_fleet(Placement::Weighted, &batches, &s);

    // Same numbers, bit for bit: placement only decides *where* each
    // deterministic simulation runs.
    assert_eq!(uniform_results, weighted_results, "placement changed measured numbers");

    // Uniform splits evenly: the 10x-slower shard served half the points.
    assert_eq!(uniform_slow.points, 108, "uniform must split the batch evenly");
    // Weighted placement learns the slow shard's service time after the
    // first (uniform-ish) batch and sends it measurably fewer points.
    assert!(
        weighted_slow.points * 2 < uniform_slow.points,
        "slow shard got {} of 216 points under weighted placement (uniform: {})",
        weighted_slow.points,
        uniform_slow.points
    );
    assert!(
        weighted_slow.ewma_secs_per_point.unwrap_or(0.0) > 0.0,
        "weighted placement must have profiled the slow shard"
    );
    // The artificial latency dominates the run (10ms/point on half the
    // batch under uniform), so moving points off the slow shard must show
    // up as wall-clock.
    assert!(
        weighted_elapsed < uniform_elapsed,
        "weighted {weighted_elapsed:?} should beat uniform {uniform_elapsed:?} \
         on a 10x-heterogeneous fleet"
    );
}

/// Compare-level acceptance: on a heterogeneous fleet, `--placement
/// weighted` under `--shared-budget` produces the identical report —
/// best points, measurement counts, and per-tenant ledger charges — as
/// uniform placement.
#[test]
fn weighted_and_uniform_compare_runs_are_identical_including_ledger() {
    fn compare_through(placement: Placement) -> CompareReport {
        let fast = throttled_shard(Duration::ZERO);
        let slow = throttled_shard(Duration::from_millis(2));
        let fleet = Engine::new(EngineConfig {
            backend: BackendSpec::Remote(vec![
                fast.addr().to_string(),
                slow.addr().to_string(),
            ]),
            workers: 2,
            placement,
            ..Default::default()
        })
        .unwrap();
        let model = model_by_name("alexnet").unwrap();
        let budget =
            TuneBudget { total_measurements: 12, batch: 4, workers: 2, ..Default::default() };
        let report = compare_frameworks_opts(
            &fleet,
            &[Framework::Random, Framework::AutoTvm],
            &model,
            budget,
            true,
            5,
            DriverOptions { concurrent: true, shared_budget: true },
        )
        .unwrap();
        fast.shutdown();
        slow.shutdown();
        report
    }

    let uniform = compare_through(Placement::Uniform);
    let weighted = compare_through(Placement::Weighted);

    assert_eq!(uniform.outcomes.len(), weighted.outcomes.len());
    for (u, w) in uniform.outcomes.iter().zip(&weighted.outcomes) {
        assert_eq!(u.framework, w.framework);
        assert_eq!(u.inference_secs, w.inference_secs, "{}: best diverged", u.framework.name());
        assert_eq!(u.measurements, w.measurements);
        for (ut, wt) in u.tasks.iter().zip(&w.tasks) {
            assert_eq!(ut.result.best_point, wt.result.best_point, "task {}", ut.task_id);
            assert_eq!(ut.result.best.seconds, wt.result.best.seconds);
        }
    }
    // Identical ledger charges, tenant by tenant.
    let ul = uniform.ledger.as_ref().unwrap();
    let wl = weighted.ledger.as_ref().unwrap();
    assert_eq!(ul.per_task_points, wl.per_task_points);
    assert_eq!(ul.tenants.len(), wl.tenants.len());
    for (ut, wt) in ul.tenants.iter().zip(&wl.tenants) {
        assert_eq!((&ut.framework, &ut.task), (&wt.framework, &wt.task));
        assert_eq!(ut.account.charged, wt.account.charged, "{}/{}", ut.framework, ut.task);
        assert_eq!(ut.account.settled(), wt.account.settled());
    }
}

#[test]
fn warm_started_shard_answers_journaled_points_from_cache() {
    let s = space();
    let journal = tmp_path("warm_shard");
    cleanup(&journal);
    let points = distinct_points(&s, 77, 20);

    // Build the history in-process, journaled.
    {
        let first = Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            journal: Some(journal.clone()),
            ..Default::default()
        })
        .unwrap();
        first.measure_batch(&s, &points);
        first.flush_journal();
    }

    // A brand-new shard inherits it via --warm-start (read-only).
    let shard_engine = Arc::new(
        Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            warm_start: Some(journal.clone()),
            ..Default::default()
        })
        .unwrap(),
    );
    assert_eq!(shard_engine.preloaded_entries(), 20);
    let server = serve_measure_local(Arc::clone(&shard_engine)).unwrap();

    let client = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![server.addr().to_string()]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    // The handshake reported the inherited coverage to the client.
    let placement = client.stats().placement;
    assert_eq!(placement.len(), 1);
    assert_eq!(placement[0].preloaded, 20, "handshake must carry the warm-start coverage");

    // Replaying the journaled points: the shard answers everything from
    // its warm cache — the client ledger sees fresh=false on every point.
    let traced = client.try_measure_batch_traced(&s, &points).unwrap();
    assert!(
        traced.origins.iter().all(|o| *o == Origin::ShardCached),
        "warm-started shard must answer from cache: {:?}",
        traced.origins.iter().take(5).collect::<Vec<_>>()
    );
    assert_eq!(client.stats().simulations, 0);
    assert_eq!(client.stats().shard_cached, 20);
    assert_eq!(shard_engine.stats().simulations, 0, "the shard must not re-simulate");
    assert!(shard_engine.stats().cache_hits >= 20);

    server.shutdown();
    cleanup(&journal);
}

#[test]
fn journal_merge_then_warm_start_reproduces_in_process_run_exactly() {
    let task_a = Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1);
    let task_b = Conv2dTask::new(1, 64, 14, 14, 64, 3, 3, 1, 1);
    let space_a = ConfigSpace::for_task(&task_a, true);
    let space_b = ConfigSpace::for_task(&task_b, true);
    let j_a = tmp_path("merge_a");
    let j_b = tmp_path("merge_b");
    let merged = tmp_path("merged");
    cleanup(&j_a);
    cleanup(&j_b);
    cleanup(&merged);
    let budget = TuneBudget { total_measurements: 24, batch: 8, workers: 2, ..Default::default() };

    // Two separate in-process runs (think: two fleet shards, each with a
    // local journal).
    let run_local = |space: &ConfigSpace, journal: &PathBuf, seed: u64| {
        let engine = Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            journal: Some(journal.clone()),
            ..Default::default()
        })
        .unwrap();
        let mut strat = RandomSearch::new(space.clone(), seed);
        let out = tune_task_with(&engine, space, &mut strat, budget).unwrap();
        engine.flush_journal();
        out
    };
    let local_a = run_local(&space_a, &j_a, 42);
    let local_b = run_local(&space_b, &j_b, 43);

    // Union the shard journals, warm-start a fresh shard from the union.
    let stats = merge_journals(&merged, &[j_a.clone(), j_b.clone()]).unwrap();
    assert!(stats.added > 0);
    let shard_engine = Arc::new(
        Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            warm_start: Some(merged.clone()),
            ..Default::default()
        })
        .unwrap(),
    );
    let server = serve_measure_local(Arc::clone(&shard_engine)).unwrap();
    let client = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![server.addr().to_string()]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();

    // Same seeds, same spaces, through the warm fleet: identical numbers,
    // zero fresh simulator runs anywhere.
    let mut strat = RandomSearch::new(space_a.clone(), 42);
    let remote_a = tune_task_with(&client, &space_a, &mut strat, budget).unwrap();
    let mut strat = RandomSearch::new(space_b.clone(), 43);
    let remote_b = tune_task_with(&client, &space_b, &mut strat, budget).unwrap();

    for (local, remote) in [(&local_a, &remote_a), (&local_b, &remote_b)] {
        assert_eq!(local.best_point, remote.best_point);
        assert_eq!(local.best.seconds, remote.best.seconds);
        assert_eq!(local.best.cycles, remote.best.cycles);
        assert_eq!(local.measurements, remote.measurements);
        assert_eq!(remote.fresh, 0, "warm fleet must serve the replay entirely from cache");
        assert_eq!(remote.cache_served, remote.measurements);
    }
    assert_eq!(client.stats().simulations, 0);
    assert_eq!(shard_engine.stats().simulations, 0, "zero fresh simulator runs on the shard");

    server.shutdown();
    cleanup(&j_a);
    cleanup(&j_b);
    cleanup(&merged);
}

#[test]
fn whole_fleet_outage_is_a_typed_error_not_a_panic() {
    let s = space();
    let server = throttled_shard(Duration::ZERO);
    let engine = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![server.addr().to_string()]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();

    // Healthy first: the fleet serves a batch.
    let warmup = distinct_points(&s, 9, 4);
    engine.measure_batch(&s, &warmup);
    assert_eq!(engine.concurrent_batch_capacity(), 1);

    // Then the only shard goes away for good. (Filter the new batch
    // against the warmup identities: a cached point would be served
    // locally and shrink the undeliverable count.)
    server.shutdown();
    let warm_keys: std::collections::HashSet<PointKey> =
        warmup.iter().map(|p| PointKey::of(&s, p)).collect();
    let fresh: Vec<PointConfig> = distinct_points(&s, 10, 12)
        .into_iter()
        .filter(|p| !warm_keys.contains(&PointKey::of(&s, p)))
        .take(6)
        .collect();
    assert_eq!(fresh.len(), 6);
    let err = engine.try_measure_batch_traced(&s, &fresh).unwrap_err();
    let fleet_lost = err
        .as_ref()
        .downcast_ref::<FleetLostError>()
        .unwrap_or_else(|| panic!("expected FleetLostError, got: {err}"));
    assert_eq!(fleet_lost.undeliverable, 6);
    assert!(err.to_string().contains("fleet lost"), "unexpected message: {err}");

    // Cached points are still served without touching the dead fleet.
    let replay = engine.try_measure_batch_traced(&s, &warmup).unwrap();
    assert_eq!(replay.results.len(), 4);

    // And the tuning loop fails cleanly end to end (no panic, no partial
    // TaskTuneResult pretending the run succeeded).
    let mut strat = RandomSearch::new(s.clone(), 91);
    let budget = TuneBudget { total_measurements: 16, batch: 8, workers: 2, ..Default::default() };
    let tune_err = tune_task_with(&engine, &s, &mut strat, budget).unwrap_err();
    assert!(
        tune_err.as_ref().downcast_ref::<FleetLostError>().is_some(),
        "tuning loop must propagate the typed fleet error, got: {tune_err}"
    );
}
