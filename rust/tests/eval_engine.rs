//! Integration tests for the eval::Engine measurement layer: cache/dedup
//! semantics, backend parity with the raw oracle, journal persistence, and
//! the cross-framework measurement-sharing guarantee behind `arco compare`.

use arco::baselines::RandomSearch;
use arco::codegen::measure_point;
use arco::eval::{BackendKind, Engine, EngineConfig, Journal, PointKey};
use arco::space::{ConfigSpace, PointConfig};
use arco::tuner::{compare_frameworks_with, tune_task_with, Framework, TuneBudget};
use arco::util::rng::Pcg32;
use arco::workload::{model_by_name, Conv2dTask};
use std::path::PathBuf;

fn space() -> ConfigSpace {
    ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
}

fn tmp_journal(tag: &str) -> PathBuf {
    PathBuf::from("target/tmp").join(format!("eval_engine_{tag}_{}.jsonl", std::process::id()))
}

#[test]
fn same_point_simulated_exactly_once() {
    let s = space();
    let engine = Engine::vta_sim(2);
    let p = s.default_point();
    // Three duplicates in one batch + two more batches of the same point.
    let first = engine.measure_batch(&s, &[p.clone(), p.clone(), p.clone()]);
    let again = engine.measure_one(&s, &p);
    let again2 = engine.measure_one(&s, &p);
    assert_eq!(first[0], first[1]);
    assert_eq!(first[1], first[2]);
    assert_eq!(first[0], again);
    assert_eq!(again, again2);
    let st = engine.stats();
    assert_eq!(st.simulations, 1, "one unique config must cost one simulation");
    assert_eq!(st.batch_dedup, 2);
    assert_eq!(st.cache_hits, 2);
}

#[test]
fn engine_matches_raw_measure_point_on_random_sample() {
    // Backend parity: VtaSimBackend through the engine == legacy
    // measure_point, for valid and invalid points alike, at any worker
    // count, in input order.
    let s = space();
    let mut rng = Pcg32::seeded(17);
    let mut points: Vec<PointConfig> = (0..40).map(|_| s.random_point(&mut rng)).collect();
    points.push(points[3].clone()); // duplicate on purpose
    for workers in [1, 4] {
        let engine = Engine::new(EngineConfig { workers, ..Default::default() }).unwrap();
        let batch = engine.measure_batch(&s, &points);
        assert_eq!(batch.len(), points.len());
        for (p, r) in points.iter().zip(&batch) {
            assert_eq!(*r, measure_point(&s, p), "divergence at {}", s.render(p));
        }
    }
}

#[test]
fn analytical_backend_serves_the_same_interface() {
    let s = space();
    let engine = Engine::new(EngineConfig {
        backend: BackendKind::Analytical.into(),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(engine.backend_name(), "analytical");
    let mut rng = Pcg32::seeded(23);
    let points: Vec<PointConfig> = (0..30).map(|_| s.random_point(&mut rng)).collect();
    let results = engine.measure_batch(&s, &points);
    let valid = results.iter().filter(|r| r.valid).count();
    assert!(valid > 0, "analytical backend should accept some configs");
    for (p, r) in points.iter().zip(&results) {
        if r.valid {
            let (hw, _) = s.decode(p);
            assert!(r.seconds.is_finite() && r.seconds > 0.0);
            assert!(r.gflops <= hw.peak_gops() + 1e-9);
        } else {
            assert_eq!(r.fitness(), 0.0);
        }
    }
}

#[test]
fn journal_reuses_measurements_across_engines() {
    let s = space();
    let path = tmp_journal("reuse");
    let _ = std::fs::remove_file(&path);
    let mut rng = Pcg32::seeded(31);
    let points: Vec<PointConfig> = (0..12).map(|_| s.random_point(&mut rng)).collect();

    // First process: measures and journals everything.
    let first = Engine::new(EngineConfig {
        workers: 2,
        journal: Some(path.clone()),
        ..Default::default()
    })
    .unwrap();
    let results = first.measure_batch(&s, &points);
    let uniques = first.stats().simulations;
    assert!(uniques > 0);
    drop(first);

    // The JSONL journal on disk round-trips (read-only: no writer lock).
    let journal = Journal::open_read_only(&path).unwrap();
    assert_eq!(journal.len(), uniques);
    for e in journal.entries() {
        assert_eq!(e.backend, "vta-sim");
        assert_eq!(e.key.values.len(), s.num_knobs());
    }

    // Second process: seeds its cache from the journal and re-simulates
    // nothing for the same workload.
    let second = Engine::new(EngineConfig {
        workers: 2,
        journal: Some(path.clone()),
        ..Default::default()
    })
    .unwrap();
    assert_eq!(second.stats().journal_seeded, uniques);
    let replay = second.measure_batch(&s, &points);
    assert_eq!(replay, results);
    assert_eq!(second.stats().simulations, 0, "journal must make the rerun free");
    drop(second);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn point_keys_unify_frozen_and_full_spaces() {
    // A software-only framework (frozen hardware knobs) planning the
    // default hardware must share cache entries with the full co-design
    // space.
    let t = Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1);
    let full = ConfigSpace::for_task(&t, true);
    let frozen = ConfigSpace::for_task(&t, false);
    let engine = Engine::vta_sim(1);
    let a = engine.measure_one(&full, &full.default_point());
    let b = engine.measure_one(&frozen, &frozen.default_point());
    assert_eq!(a, b);
    assert_eq!(engine.stats().simulations, 1);
    assert_eq!(
        PointKey::of(&full, &full.default_point()),
        PointKey::of(&frozen, &frozen.default_point())
    );
}

#[test]
fn repeated_tuning_run_is_fully_cache_served() {
    // The acceptance property: within one engine's lifetime (one `arco
    // compare` invocation), re-measuring the same point never re-simulates.
    let s = space();
    let engine = Engine::vta_sim(2);
    let budget = TuneBudget { total_measurements: 64, batch: 16, workers: 2, ..Default::default() };
    let mut r1 = RandomSearch::new(s.clone(), 77);
    let out1 = tune_task_with(&engine, &s, &mut r1, budget).unwrap();
    let sims = engine.stats().simulations;
    assert_eq!(sims, out1.measurements);

    let mut r2 = RandomSearch::new(s.clone(), 77); // same seed → same plan
    let out2 = tune_task_with(&engine, &s, &mut r2, budget).unwrap();
    assert_eq!(out1.best.seconds, out2.best.seconds);
    assert_eq!(engine.stats().simulations, sims, "second identical run must be free");
    assert!(engine.stats().cache_hits >= out2.measurements);
}

#[test]
fn compare_shares_measurements_across_frameworks() {
    // Random planned twice under two Framework entries: the second pass
    // must be answered from the shared cache, not new simulations.
    let model = model_by_name("alexnet").unwrap();
    let budget = TuneBudget { total_measurements: 32, batch: 16, workers: 2, ..Default::default() };
    let engine = Engine::vta_sim(2);
    let report = compare_frameworks_with(
        &engine,
        &[Framework::Random, Framework::Random],
        &model,
        budget,
        true,
        11,
    )
    .unwrap();
    assert_eq!(report.outcomes.len(), 2);
    let st = engine.stats();
    let total: usize = report.outcomes.iter().map(|o| o.measurements).sum();
    assert_eq!(st.simulations, total / 2, "identical second framework must be cache-served");
    assert!(st.cache_hits >= total / 2);
}
