//! Integration tests for the sharded measurement service: the
//! `serve-measure` server + `remote` backend loop, in-flight coalescing
//! under concurrent batches, fingerprint safety on the wire, and recovery
//! when a shard dies mid-batch.

use arco::baselines::RandomSearch;
use arco::eval::proto::{
    read_frame, write_frame, write_request_frame, Request, Response, PROTO_VERSION,
};
use arco::eval::{
    serve_measure_local, serve_measure_local_with, AnalyticalBackend, BackendKind, BackendSpec,
    Engine, EngineConfig, Fingerprint, MeasureBackend, RemoteBackend, ServeOptions,
};
use arco::space::ConfigSpace;
use arco::tuner::{tune_task_with, TuneBudget};
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Barrier};
use std::time::Duration;

fn space() -> ConfigSpace {
    ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
}

fn local_engine(kind: BackendKind, workers: usize) -> Arc<Engine> {
    Arc::new(
        Engine::new(EngineConfig { backend: kind.into(), workers, ..Default::default() })
            .unwrap(),
    )
}

/// A fleet member that answers the handshake with `fp` but drops every
/// connection at the first non-ping request — a shard that dies mid-batch.
fn flaky_shard(fp: Fingerprint) -> std::net::SocketAddr {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap();
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { continue };
            let fp = fp.clone();
            std::thread::spawn(move || {
                let Ok(clone) = stream.try_clone() else { return };
                let mut reader = BufReader::new(clone);
                let mut writer = BufWriter::new(stream);
                while let Ok(Some(frame)) = read_frame(&mut reader) {
                    match Request::from_json(&frame) {
                        Some(Request::Ping) => {
                            let pong = Response::Pong {
                                backend: "vta-sim".to_string(),
                                proto: PROTO_VERSION,
                                fingerprint: fp.clone(),
                                preloaded: 0,
                            };
                            if write_frame(&mut writer, &pong.to_json()).is_err() {
                                return;
                            }
                        }
                        _ => return, // connection dropped without a reply
                    }
                }
            });
        }
    });
    addr
}

#[test]
fn remote_backend_matches_local_engine() {
    let server = serve_measure_local(local_engine(BackendKind::VtaSim, 2)).unwrap();
    let addr = server.addr().to_string();

    let s = space();
    let mut rng = Pcg32::seeded(33);
    let mut points: Vec<_> = (0..20).map(|_| s.random_point(&mut rng)).collect();
    points.push(points[2].clone()); // duplicate crosses the wire once

    let remote = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![addr]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    assert_eq!(remote.backend_name(), "vta-sim");
    let got = remote.measure_batch(&s, &points);
    for (p, r) in points.iter().zip(&got) {
        assert_eq!(*r, arco::codegen::measure_point(&s, p), "remote diverged from oracle");
    }
    // The duplicate was deduplicated client-side...
    assert_eq!(remote.stats().simulations, 20);
    // ...and the server engine simulated exactly the unique points.
    assert_eq!(server.engine().stats().simulations, 20);
    server.shutdown();
}

#[test]
fn remote_tuning_run_matches_in_process() {
    // The acceptance property behind the CI smoke job: the same seeded
    // search through a remote fleet produces the same best point as the
    // in-process backend.
    let server = serve_measure_local(local_engine(BackendKind::VtaSim, 2)).unwrap();
    let addr = server.addr().to_string();
    let s = space();
    let budget = TuneBudget { total_measurements: 32, batch: 8, workers: 2, ..Default::default() };

    let local = Engine::vta_sim(2);
    let mut planner = RandomSearch::new(s.clone(), 99);
    let local_out = tune_task_with(&local, &s, &mut planner, budget).unwrap();

    let remote = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![addr]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let mut planner = RandomSearch::new(s.clone(), 99);
    let remote_out = tune_task_with(&remote, &s, &mut planner, budget).unwrap();

    assert_eq!(local_out.best.seconds, remote_out.best.seconds);
    assert_eq!(local_out.best.cycles, remote_out.best.cycles);
    assert_eq!(local_out.measurements, remote_out.measurements);
    server.shutdown();
}

#[test]
fn shard_death_mid_batch_redispatches_to_survivors() {
    let server = serve_measure_local(local_engine(BackendKind::VtaSim, 2)).unwrap();
    let real = server.addr().to_string();
    let flaky = flaky_shard(Fingerprint::current()).to_string();

    // Both shards pass the handshake; the flaky one dies on its first
    // measure chunk and its points must land on the survivor.
    let backend = RemoteBackend::connect(&[flaky, real]).unwrap();
    assert_eq!(backend.alive_count(), 2);

    let s = space();
    let mut rng = Pcg32::seeded(55);
    let points: Vec<_> = (0..10).map(|_| s.random_point(&mut rng)).collect();
    let got = backend.measure_many(&s, &points, 2);
    for (p, r) in points.iter().zip(&got) {
        assert_eq!(*r, arco::codegen::measure_point(&s, p), "re-dispatch corrupted results");
    }
    assert_eq!(backend.alive_count(), 1, "the dead shard must be marked");
    server.shutdown();
}

#[test]
fn fingerprint_mismatch_is_refused_on_the_wire() {
    let mut fp = Fingerprint::current();
    fp.cycle_model += 1;
    let addr = flaky_shard(fp).to_string();
    let err = RemoteBackend::connect(&[addr]).unwrap_err().to_string();
    assert!(err.contains("different simulator"), "unexpected error: {err}");
}

#[test]
fn protocol_error_paths_answer_instead_of_hanging() {
    let server = serve_measure_local(local_engine(BackendKind::Analytical, 1)).unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);

    // Handshake.
    write_frame(&mut writer, &Request::Ping.to_json()).unwrap();
    let pong = Response::from_json(&read_frame(&mut reader).unwrap().unwrap()).unwrap();
    match pong {
        Response::Pong { backend, proto, fingerprint, preloaded } => {
            assert_eq!(backend, "analytical");
            assert_eq!(proto, PROTO_VERSION);
            assert_eq!(fingerprint, Fingerprint::current());
            assert_eq!(preloaded, 0, "a cold shard must report no inherited coverage");
        }
        other => panic!("expected pong, got {other:?}"),
    }

    // Unknown op → structured error, connection stays usable.
    write_frame(&mut writer, &arco::util::json::Json::parse(r#"{"op":"selfdestruct"}"#).unwrap())
        .unwrap();
    match Response::from_json(&read_frame(&mut reader).unwrap().unwrap()).unwrap() {
        Response::Error(_) => {}
        other => panic!("expected error, got {other:?}"),
    }

    // A measure request with out-of-space values → structured error.
    let s = space();
    let bogus = Request::Measure { task: s.task, points: vec![vec![999; s.num_knobs()]] };
    write_frame(&mut writer, &bogus.to_json()).unwrap();
    match Response::from_json(&read_frame(&mut reader).unwrap().unwrap()).unwrap() {
        Response::Error(e) => assert!(e.contains("skew"), "unexpected message: {e}"),
        other => panic!("expected error, got {other:?}"),
    }

    // Stats op still answers on the same connection.
    write_frame(&mut writer, &Request::Stats.to_json()).unwrap();
    match Response::from_json(&read_frame(&mut reader).unwrap().unwrap()).unwrap() {
        Response::Stats(stats) => assert!(stats.get("batches").is_some()),
        other => panic!("expected stats, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn measure_responses_piggyback_the_shard_queue_depth() {
    // Weighted placement's load signal rides every measure reply as the
    // additive `active_batches` field, so clients do not pay a `stats`
    // round trip per batch (ROADMAP: cut one RTT on high-latency links).
    let server = serve_measure_local(local_engine(BackendKind::Analytical, 1)).unwrap();
    let stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);

    let s = space();
    let key = arco::eval::PointKey::of(&s, &s.default_point());
    let req = Request::Measure { task: s.task, points: vec![key.values] };
    write_frame(&mut writer, &req.to_json()).unwrap();
    match Response::from_json(&read_frame(&mut reader).unwrap().unwrap()).unwrap() {
        Response::Results { results, fresh, active_batches } => {
            assert_eq!(results.len(), 1);
            assert_eq!(fresh, vec![true]);
            // An idle shard reports an empty queue (this request's own
            // batch has already drained from the gauge by reply time).
            assert_eq!(active_batches, Some(0), "shards must piggyback their queue depth");
        }
        other => panic!("expected results, got {other:?}"),
    }
    server.shutdown();
}

#[test]
fn stalled_reader_is_disconnected_by_the_write_timeout() {
    // A client that requests a big batch and then never drains its socket
    // used to pin the connection thread forever once the kernel send
    // buffer filled. With a write deadline armed, the server treats the
    // expiry as a hangup and the connection gauge returns to zero while
    // the stalled client still holds its end open.
    let server = serve_measure_local_with(
        local_engine(BackendKind::Analytical, 1),
        ServeOptions { write_timeout: Duration::from_millis(200), ..ServeOptions::default() },
    )
    .unwrap();

    let stream = TcpStream::connect(server.addr()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = BufWriter::new(stream);

    // Handshake: prove the connection is alive and being served.
    write_frame(&mut writer, &Request::Ping.to_json()).unwrap();
    assert!(read_frame(&mut reader).unwrap().is_some());
    let deadline = std::time::Instant::now() + Duration::from_secs(30);
    while server.active_connections() != 1 {
        assert!(std::time::Instant::now() < deadline, "connection never registered");
        std::thread::sleep(Duration::from_millis(10));
    }

    // Requests whose responses total far more than any loopback socket
    // buffering (the point repeats, so the engine pays for it once) —
    // and never read a byte back. A later request's write fails once the
    // server wedges mid-response and stops reading; the client's own
    // write deadline keeps this loop from blocking forever.
    let s = space();
    let key = arco::eval::PointKey::of(&s, &s.default_point());
    let req = Request::Measure { task: s.task, points: vec![key.values; 200_000] };
    writer.get_ref().set_write_timeout(Some(Duration::from_secs(2))).unwrap();
    for _ in 0..8 {
        if write_request_frame(&mut writer, &req).is_err() {
            break;
        }
    }

    // The server blocks writing tens of MB of responses into a socket
    // nobody drains, hits the 200 ms deadline, and ends the connection
    // cleanly.
    while server.active_connections() != 0 {
        assert!(
            std::time::Instant::now() < deadline,
            "write timeout never released the connection thread"
        );
        std::thread::sleep(Duration::from_millis(20));
    }

    // The stalled client's end is still open; drop it only after the
    // server has already let go.
    drop(writer);
    drop(reader);
    server.shutdown();
}

/// An oracle that counts real measurements (and is slow enough for two
/// batches to overlap).
struct CountingBackend {
    calls: Arc<AtomicUsize>,
}

impl MeasureBackend for CountingBackend {
    fn name(&self) -> &'static str {
        "counting"
    }
    fn measure(
        &self,
        space: &ConfigSpace,
        point: &arco::space::PointConfig,
    ) -> arco::eval::MeasureResult {
        self.calls.fetch_add(1, Ordering::SeqCst);
        std::thread::sleep(Duration::from_millis(3));
        AnalyticalBackend.measure(space, point)
    }
}

#[test]
fn concurrent_batches_coalesce_instead_of_double_measuring() {
    let s = space();
    let mut rng = Pcg32::seeded(77);
    let mut points = Vec::new();
    let mut seen = std::collections::HashSet::new();
    while points.len() < 12 {
        let p = s.random_point(&mut rng);
        if seen.insert(arco::eval::PointKey::of(&s, &p)) {
            points.push(p);
        }
    }

    let calls = Arc::new(AtomicUsize::new(0));
    let engine =
        Engine::with_backend(Box::new(CountingBackend { calls: Arc::clone(&calls) }), 4, true);
    let barrier = Barrier::new(2);
    let (a, b) = std::thread::scope(|scope| {
        let ha = scope.spawn(|| {
            barrier.wait();
            engine.measure_batch(&s, &points)
        });
        let hb = scope.spawn(|| {
            barrier.wait();
            engine.measure_batch(&s, &points)
        });
        (ha.join().unwrap(), hb.join().unwrap())
    });
    assert_eq!(a, b);
    // The at-most-once guarantee under concurrency: 24 requested points,
    // 12 unique — the backend must have been paid exactly 12 times, with
    // the second batch served by coalescing and/or the cache.
    assert_eq!(calls.load(Ordering::SeqCst), 12, "a point was double-measured");
    let st = engine.stats();
    assert_eq!(st.simulations, 12);
    assert_eq!(st.coalesced + st.cache_hits, 12);
    assert_eq!(st.batch_dedup, 0);
}
