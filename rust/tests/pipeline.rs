//! End-to-end pipeline integration tests (native backend — no artifacts
//! needed, so these always run).

use arco::baselines::{AutoTvm, Chameleon};
use arco::baselines::autotvm::AutoTvmParams;
use arco::baselines::chameleon::ChameleonParams;
use arco::marl::strategy::{Arco, ArcoParams};
use arco::marl::Backend;
use arco::runtime::ModelDims;
use arco::space::ConfigSpace;
use arco::tuner::{tune_model, tune_task, Framework, TuneBudget};
use arco::workload::{model_by_name, Conv2dTask};

fn budget(trials: usize, batch: usize) -> TuneBudget {
    TuneBudget { total_measurements: trials, batch, workers: 2, ..Default::default() }
}

fn task() -> Conv2dTask {
    Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1)
}

#[test]
fn all_frameworks_complete_a_model() {
    let model = model_by_name("alexnet").unwrap();
    for f in [
        Framework::AutoTvm,
        Framework::Chameleon,
        Framework::Arco,
        Framework::Random,
    ] {
        let out = tune_model(f, &model, budget(48, 16), true, 5).unwrap();
        assert!(out.inference_secs.is_finite(), "{f:?}");
        assert!(out.inference_secs > 0.0, "{f:?}");
        assert_eq!(out.tasks.len(), model.unique_tasks().len(), "{f:?}");
        assert!(out.compile_secs > 0.0, "{f:?}");
    }
}

#[test]
fn tuning_is_deterministic_per_seed() {
    let model = model_by_name("alexnet").unwrap();
    let a = tune_model(Framework::AutoTvm, &model, budget(64, 16), true, 9).unwrap();
    let b = tune_model(Framework::AutoTvm, &model, budget(64, 16), true, 9).unwrap();
    assert_eq!(a.inference_secs, b.inference_secs);
    assert_eq!(a.measurements, b.measurements);
}

#[test]
fn arco_beats_software_only_arco_on_codesign_space() {
    // The headline co-design claim at small scale.
    let model = model_by_name("alexnet").unwrap();
    let full = tune_model(Framework::Arco, &model, budget(160, 32), true, 13).unwrap();
    let sw = tune_model(Framework::ArcoSwOnly, &model, budget(160, 32), true, 13).unwrap();
    assert!(
        full.inference_secs <= sw.inference_secs * 1.001,
        "co-design {} vs sw-only {}",
        full.inference_secs,
        sw.inference_secs
    );
}

#[test]
fn arco_constraint_awareness_cuts_invalid_measurements() {
    // ARCO pre-filters by the free penalty check; AutoTVM cannot (the
    // paper's invalid-configuration critique). Compare invalid counts on
    // the same hardware-tunable space.
    let t = task();
    let space_hw = ConfigSpace::for_task(&t, true);
    let b = budget(128, 32);

    let mut arco = Arco::with_backend(
        space_hw.clone(),
        ArcoParams::quick(),
        Backend::native(ModelDims::default()),
        3,
    );
    let r_arco = tune_task(&space_hw, &mut arco, b).unwrap();

    struct RawRandom {
        space: ConfigSpace,
        rng: arco::util::rng::Pcg32,
        seen: std::collections::HashSet<usize>,
    }
    impl arco::tuner::Strategy for RawRandom {
        fn name(&self) -> &'static str {
            "raw-random"
        }
        fn plan(&mut self, batch: usize) -> Vec<arco::space::PointConfig> {
            let mut out = Vec::new();
            let mut tries = 0;
            while out.len() < batch && tries < batch * 100 {
                let p = self.space.random_point(&mut self.rng);
                if self.seen.insert(self.space.flat_index(&p)) {
                    out.push(p);
                }
                tries += 1;
            }
            out
        }
        fn observe(&mut self, _r: &[(arco::space::PointConfig, arco::codegen::MeasureResult)]) {}
    }
    let mut raw = RawRandom {
        space: space_hw.clone(),
        rng: arco::util::rng::Pcg32::seeded(3),
        seen: Default::default(),
    };
    let r_raw = tune_task(&space_hw, &mut raw, b).unwrap();

    assert!(
        r_arco.invalid * 2 <= r_raw.invalid.max(2),
        "arco invalid {} should be well below unfiltered random {}",
        r_arco.invalid,
        r_raw.invalid
    );
}

#[test]
fn cost_models_learn_the_landscape() {
    // After a couple of iterations the GBT-driven planners should produce
    // better-than-random batches: compare mean fitness of the last batch
    // against the first. Uses the hardware-tunable space so the budget is a
    // small fraction of the space (a near-exhausted space forces planners
    // to mop up bad leftovers, which would invert the comparison).
    let t = task();
    let space = ConfigSpace::for_task(&t, true);
    let b = budget(160, 32);
    for which in ["autotvm", "chameleon"] {
        let mut strat: Box<dyn arco::tuner::Strategy> = match which {
            "autotvm" => Box::new(AutoTvm::new(space.clone(), AutoTvmParams::quick(), 21)),
            _ => Box::new(Chameleon::new(space.clone(), ChameleonParams::quick(), 21)),
        };
        let r = tune_task(&space, strat.as_mut(), b).unwrap();
        let n = r.trace.len();
        assert!(n >= 64, "{which}: got {n} measurements");
        let first: Vec<f64> = r.trace[..32].iter().map(|e| e.gflops).collect();
        let last: Vec<f64> = r.trace[n - 32..].iter().map(|e| e.gflops).collect();
        let (mf, ml) = (arco::util::stats::mean(&first), arco::util::stats::mean(&last));
        assert!(
            ml >= mf * 0.9,
            "{which}: planner got worse over time ({mf:.1} -> {ml:.1} GFLOPS)"
        );
    }
}

#[test]
fn trace_cumulative_time_is_monotone() {
    let model = model_by_name("alexnet").unwrap();
    let out = tune_model(Framework::Arco, &model, budget(96, 32), true, 2).unwrap();
    for t in &out.tasks {
        for w in t.result.trace.windows(2) {
            assert!(w[1].modeled_cum_secs >= w[0].modeled_cum_secs);
        }
        // Final cumulative equals the task total.
        if let Some(last) = t.result.trace.last() {
            assert!((last.modeled_cum_secs - t.result.modeled_hw_secs).abs() < 1e-9);
        }
    }
}

#[test]
fn search_secs_below_compile_secs() {
    let model = model_by_name("alexnet").unwrap();
    let out = tune_model(Framework::AutoTvm, &model, budget(64, 32), true, 4).unwrap();
    assert!(out.search_secs <= out.compile_secs);
}
