//! Acceptance tests for multi-fidelity tuning (`--fidelity`):
//!
//! - `screen:0.25` reaches a best within 1% of exact mode for every
//!   in-tree strategy at `configs/quick.json` scale, while sending at
//!   least 30% fewer points to the simulator,
//! - the budget ledger stays conserved across tiers (every admitted
//!   candidate settles exactly once — simulated, cache-served, or
//!   screened — and each tier is charged at its own modeled price),
//! - the trace tags tiers honestly (ordinals contiguous across both,
//!   screened entries tagged [`TraceFidelity::Screened`]), and
//! - `--fidelity exact` (the default) stays bit-identical to the classic
//!   loop: no screening state leaks into results, traces, or the ledger,
//!   and a degenerate `screen:1.0:0.0` — keep everything, explore
//!   nothing — reproduces exact mode trace-for-trace.

use arco::eval::{AnalyticalBackend, BudgetLedger, Dispatcher, Engine};
use arco::space::ConfigSpace;
use arco::tuner::{
    tune_task_tenant, tune_task_with, Fidelity, Framework, TaskTuneResult, TenantContext,
    TraceFidelity, TuneBudget,
};
use arco::workload::Conv2dTask;

fn space() -> ConfigSpace {
    ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
}

fn analytical() -> Engine {
    Engine::with_backend(Box::new(AnalyticalBackend), 2, true)
}

/// `configs/quick.json`'s budget (128 points, batches of 32), at the
/// requested fidelity.
fn quick_budget(fidelity: Fidelity) -> TuneBudget {
    TuneBudget { total_measurements: 128, batch: 32, workers: 2, fidelity, ..Default::default() }
}

const ALL_FRAMEWORKS: [Framework; 6] = [
    Framework::AutoTvm,
    Framework::Chameleon,
    Framework::Arco,
    Framework::Random,
    Framework::ArcoNoCs,
    Framework::ArcoSwOnly,
];

/// Run one framework at quick scale under a per-run ledger, so the test
/// can audit cross-tier conservation afterwards.
fn run(fw: Framework, fidelity: Fidelity, seed: u64) -> (TaskTuneResult, BudgetLedger) {
    let s = space();
    let engine = analytical();
    let ledger = BudgetLedger::new(128);
    let dispatcher = Dispatcher::new(1);
    let tenant = TenantContext {
        ledger: Some(&ledger),
        dispatcher: &dispatcher,
        framework: fw.name(),
        task_id: "t0",
        observer: None,
    };
    let mut strategy = fw.build(s.clone(), true, seed);
    let budget = quick_budget(fidelity);
    let out = tune_task_tenant(&engine, &s, strategy.as_mut(), budget, Some(&tenant)).unwrap();
    (out, ledger)
}

/// Everything a trace entry carries except the wall-clock stamp (which no
/// two runs can share bit-for-bit).
type TraceRow = (usize, usize, f64, f64, bool, f64, TraceFidelity);

fn trace_rows(result: &TaskTuneResult) -> Vec<TraceRow> {
    result
        .trace
        .iter()
        .map(|e| {
            (e.ordinal, e.iteration, e.gflops, e.best_gflops, e.valid, e.modeled_cum_secs, e.fidelity)
        })
        .collect()
}

#[test]
fn screening_matches_exact_best_with_fewer_simulations_for_every_strategy() {
    for fw in ALL_FRAMEWORKS {
        let (exact, _) = run(fw, Fidelity::Exact, 17);
        let (screen, ledger) =
            run(fw, Fidelity::Screen { keep: 0.25, explore: 0.1 }, 17);

        // The headline acceptance bar: within 1% of the exact best...
        assert!(
            exact.best.valid && screen.best.valid,
            "{}: both tiers must find a valid best",
            fw.name()
        );
        assert!(
            screen.best.seconds <= exact.best.seconds * 1.01,
            "{}: screened best {:.9}s is more than 1% off exact best {:.9}s",
            fw.name(),
            screen.best.seconds,
            exact.best.seconds,
        );
        // ...with at least 30% fewer simulator measurements for the same
        // candidate budget.
        assert!(
            (screen.measurements as f64) <= 0.7 * exact.measurements as f64,
            "{}: screening sent {} of {} exact-mode points to the simulator \
             (needed <= 70%)",
            fw.name(),
            screen.measurements,
            exact.measurements,
        );
        assert!(screen.screened > 0, "{}: screening never filtered a point", fw.name());
        // The candidate budget bounds *admitted* points at any fidelity: a
        // screened point was planned, admitted and answered — just more
        // cheaply — so the tiers together can never overshoot it.
        assert!(
            screen.measurements + screen.screened <= 128,
            "{}: tiers together overshot the candidate budget ({} + {})",
            fw.name(),
            screen.measurements,
            screen.screened,
        );

        // Honest accounting: every admitted candidate settles exactly
        // once, whichever tier answered it, and the screened tier pays its
        // own (tiny but non-zero) modeled price.
        let account = ledger.account(fw.name(), "t0");
        assert_eq!(account.charged, screen.measurements + screen.screened);
        assert_eq!(account.settled(), account.charged, "{}: unsettled charges", fw.name());
        assert_eq!(account.fresh + account.cache_served, screen.measurements);
        assert_eq!(account.screened, screen.screened);
        assert!(account.screened_secs > 0.0);
        assert!(
            account.screened_secs < account.modeled_hw_secs,
            "{}: screening must be charged far below simulator price",
            fw.name()
        );

        // The trace covers both tiers with contiguous ordinals and honest
        // tags — Fig. 6 style plots rely on the tag to chart
        // simulator-seconds only.
        assert_eq!(screen.trace.len(), screen.measurements + screen.screened);
        for (i, e) in screen.trace.iter().enumerate() {
            assert_eq!(e.ordinal, i + 1, "{}: trace ordinals must stay contiguous", fw.name());
        }
        let tagged = screen.trace.iter().filter(|e| e.fidelity == TraceFidelity::Screened).count();
        assert_eq!(tagged, screen.screened, "{}: screened-entry tags must match", fw.name());
    }
}

#[test]
fn exact_mode_runs_are_deterministic_and_carry_no_screening_state() {
    for fw in ALL_FRAMEWORKS {
        let (a, ledger_a) = run(fw, Fidelity::Exact, 29);
        let (b, _) = run(fw, Fidelity::Exact, 29);

        // Exact is the default and must look exactly like the classic
        // loop: no screened points, no exploration hits, no screened
        // trace tags, no screening debits on the ledger.
        assert_eq!(a.screened, 0, "{}", fw.name());
        assert_eq!(a.explore_hits, 0, "{}", fw.name());
        assert!(a.trace.iter().all(|e| e.fidelity == TraceFidelity::Exact), "{}", fw.name());
        let account = ledger_a.account(fw.name(), "t0");
        assert_eq!(account.screened, 0);
        assert_eq!(account.screened_secs, 0.0);
        assert_eq!(account.settled(), account.charged);

        // And it is bit-reproducible run to run.
        assert_eq!(a.best_point, b.best_point, "{}", fw.name());
        assert_eq!(a.best.seconds, b.best.seconds, "{}", fw.name());
        assert_eq!(trace_rows(&a), trace_rows(&b), "{}", fw.name());
    }
}

#[test]
fn degenerate_screen_keep_all_reproduces_exact_mode_bit_for_bit() {
    // `screen:1.0:0.0` ranks the batch and then keeps every point: no
    // candidate is diverted, the strategy observes exactly the exact-mode
    // stream, and the whole run must reproduce exact mode trace-for-trace
    // (modeled costs included). This pins the screening stage as a pure
    // *filter*: with the filter wide open, the loop is the classic one.
    let s = space();
    let mut strat = Framework::AutoTvm.build(s.clone(), true, 41);
    let exact =
        tune_task_with(&analytical(), &s, strat.as_mut(), quick_budget(Fidelity::Exact)).unwrap();
    let mut strat = Framework::AutoTvm.build(s.clone(), true, 41);
    let wide_open = tune_task_with(
        &analytical(),
        &s,
        strat.as_mut(),
        quick_budget(Fidelity::Screen { keep: 1.0, explore: 0.0 }),
    )
    .unwrap();

    assert_eq!(wide_open.screened, 0);
    assert_eq!(wide_open.measurements, exact.measurements);
    assert_eq!(wide_open.best_point, exact.best_point);
    assert_eq!(wide_open.best.seconds, exact.best.seconds);
    assert_eq!(trace_rows(&wide_open), trace_rows(&exact));
}

#[test]
fn screening_respects_a_shared_ledger_cap_across_tiers() {
    // A 40-point allowance admits 40 *candidates*, not 40 simulations:
    // with screen:0.25 the job must stop at 40 charged points split
    // between the tiers — the low-fidelity tier cannot be used to sneak
    // extra candidates past an equal-budget comparison.
    let s = space();
    let engine = analytical();
    let ledger = BudgetLedger::new(40);
    let dispatcher = Dispatcher::new(1);
    let tenant = TenantContext {
        ledger: Some(&ledger),
        dispatcher: &dispatcher,
        framework: "random",
        task_id: "t0",
        observer: None,
    };
    let mut strategy = Framework::Random.build(s.clone(), true, 7);
    let budget = TuneBudget {
        total_measurements: 128,
        batch: 16,
        workers: 2,
        fidelity: Fidelity::Screen { keep: 0.25, explore: 0.1 },
        ..Default::default()
    };
    let out = tune_task_tenant(&engine, &s, strategy.as_mut(), budget, Some(&tenant)).unwrap();
    assert_eq!(out.measurements + out.screened, 40, "the ledger must cap candidates, not sims");
    assert!(out.screened > 0);
    let account = ledger.account("random", "t0");
    assert_eq!(account.charged, 40);
    assert_eq!(account.settled(), 40);
    assert_eq!(ledger.remaining("random", "t0"), 0);
}
