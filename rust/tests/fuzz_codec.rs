//! Fuzz-corpus replay for the wire and journal decoders.
//!
//! Not a coverage-guided fuzzer (no harness in-tree), but the next best
//! thing that runs under plain `cargo test`: a seed corpus of real record
//! lines, wire frames and hand-picked pathological documents, expanded by
//! a deterministic mutation engine (truncation, byte flips, splices,
//! insertions). Every mutant is fed to every decoder entry point. Passing
//! means: no panic, no stack overflow — decoders may reject (`None`/`Err`)
//! but must never die, because one torn journal line or malicious peer
//! must not take down a shard.

use arco::eval::proto::{
    record_from_line, record_identity_from_line, record_to_json, request_from_line,
    response_from_line, write_record_line, write_request_frame, write_response_frame, Request,
    Response,
};
use arco::eval::{MeasureResult, PointKey};
use arco::space::ConfigSpace;
use arco::util::json::stream::Reader;
use arco::util::json::Json;
use arco::util::rng::Pcg32;
use arco::workload::Conv2dTask;

/// Real journal lines + wire frames: the corpus the decoders must accept,
/// and the raw material mutations start from.
fn seed_corpus() -> Vec<String> {
    let space = ConfigSpace::for_task(&Conv2dTask::new(1, 16, 14, 14, 64, 3, 3, 1, 1), true);
    let mut rng = Pcg32::seeded(0xF0);
    let mut corpus = Vec::new();
    for i in 0..8 {
        let key = PointKey::of(&space, &space.random_point(&mut rng));
        let valid = i % 3 != 0;
        let result = MeasureResult {
            seconds: if valid { 1.25e-3 * (i + 1) as f64 } else { f64::INFINITY },
            cycles: if valid { rng.next_u64() } else { 0 },
            gflops: 42.5,
            area_mm2: 3.25,
            occupancy: 0.75,
            valid,
        };
        let mut buf = Vec::new();
        write_record_line(&mut buf, "vta-sim", &key, &result).unwrap();
        corpus.push(String::from_utf8(buf).unwrap().trim_end().to_string());
        // The tree spelling of the same record is equally load-bearing.
        corpus.push(record_to_json("analytical", &key, &result).dump());
    }
    let points: Vec<Vec<usize>> =
        (0..4).map(|_| PointKey::of(&space, &space.random_point(&mut rng)).values).collect();
    let mut buf = Vec::new();
    write_request_frame(&mut buf, &Request::Measure { task: space.task, points }).unwrap();
    corpus.push(String::from_utf8(buf).unwrap().trim_end().to_string());
    for req in [Request::Ping, Request::Stats] {
        let mut buf = Vec::new();
        write_request_frame(&mut buf, &req).unwrap();
        corpus.push(String::from_utf8(buf).unwrap().trim_end().to_string());
    }
    let resp = Response::Results {
        results: vec![MeasureResult {
            seconds: 0.5,
            cycles: (1 << 60) + 7,
            gflops: 1.0,
            area_mm2: 1.0,
            occupancy: 1.0,
            valid: true,
        }],
        fresh: vec![true],
        active_batches: Some(2),
    };
    let mut buf = Vec::new();
    write_response_frame(&mut buf, &resp).unwrap();
    corpus.push(String::from_utf8(buf).unwrap().trim_end().to_string());
    // Journal header line.
    corpus.push(r#"{"format":"arco-journal","version":2,"fingerprint":"abc123"}"#.to_string());
    // Pathological hand-picked seeds: broken escapes, lone surrogates,
    // absurd exponents, wrong types in right places, torn tails.
    for s in [
        r#"{"backend":"vta-sim","task":"#,
        r#"{"backend":123,"task":{},"values":[],"valid":true}"#,
        r#""\ud800""#,
        r#""\udc00\ud800""#,
        r#""\u12"#,
        r#""\x41""#,
        "1e999",
        "-1e-999",
        "00",
        "[1,2,",
        "{\"a\":}",
        "nul",
        "\u{0}\u{1}\u{2}",
        r#"{"ok":true,"results":[{"valid":true,"seconds":"fast"}],"fresh":[true]}"#,
        r#"{"op":"measure","task":{"n":-1},"points":[[0]]}"#,
    ] {
        corpus.push(s.to_string());
    }
    corpus
}

/// Everything a peer or a journal file can reach, called on one input.
/// The only acceptable outcomes are a value or a rejection.
fn exercise(input: &str) {
    let _ = record_from_line(input);
    let _ = record_identity_from_line(input);
    let _ = request_from_line(input);
    let _ = response_from_line(input);
    if let Ok(v) = Json::parse(input) {
        // Round-trip fixpoint: anything we accept must re-serialize to a
        // form we accept again, identically.
        let dump = v.dump();
        let again = Json::parse(&dump).expect("re-parse of our own dump failed");
        assert_eq!(again.dump(), dump, "dump is not a fixpoint for {input:?}");
    }
    // Raw token stream, to the bitter end.
    let mut r = Reader::new(input);
    while let Ok(Some(_)) = r.next() {}
}

fn mutate(line: &str, rng: &mut Pcg32) -> String {
    let mut bytes = line.as_bytes().to_vec();
    match rng.gen_range(5) {
        // Torn line: the crash-mid-append case the journal must survive.
        0 => {
            let cut = rng.gen_range(bytes.len().max(1));
            bytes.truncate(cut);
        }
        // Bit flip anywhere, including into invalid UTF-8.
        1 => {
            if !bytes.is_empty() {
                let i = rng.gen_range(bytes.len());
                bytes[i] ^= 1 << rng.gen_range(8);
            }
        }
        // Splice two prefixes/suffixes of itself.
        2 => {
            let a = rng.gen_range(bytes.len().max(1));
            let b = rng.gen_range(bytes.len().max(1));
            let mut out = bytes[..a].to_vec();
            out.extend_from_slice(&bytes[b..]);
            bytes = out;
        }
        // Insert structural noise.
        3 => {
            const NOISE: [&[u8]; 6] = [b"{", b"]", b"\\u", b"\"", b",,", b"\xff\xfe"];
            let i = rng.gen_range(bytes.len() + 1);
            bytes.splice(i..i, NOISE[rng.gen_range(6)].iter().copied());
        }
        // Duplicate the whole line (two values on one line is invalid).
        _ => {
            let dup = bytes.clone();
            bytes.extend_from_slice(&dup);
        }
    }
    String::from_utf8_lossy(&bytes).into_owned()
}

#[test]
fn decoders_never_panic_on_corpus_or_mutants() {
    let corpus = seed_corpus();
    for line in &corpus {
        exercise(line);
    }
    let mut rng = Pcg32::seeded(0xFACADE);
    for round in 0..400 {
        let base = &corpus[round % corpus.len()];
        let mut mutant = base.clone();
        for _ in 0..=rng.gen_range(3) {
            mutant = mutate(&mutant, &mut rng);
        }
        exercise(&mutant);
    }
}

#[test]
fn deep_nesting_is_an_error_not_a_stack_overflow() {
    let deep_arr = "[".repeat(100_000);
    assert!(Json::parse(&deep_arr).is_err());
    let deep_obj = "{\"a\":".repeat(100_000);
    assert!(Json::parse(&deep_obj).is_err());
    // The depth guard must also cover the skipping path used by lazy
    // journal identity extraction.
    let mut r = Reader::new(&deep_arr);
    assert!(r.skip_value().is_err());
    let buried = format!("{}{}{}", "[".repeat(600), "1", "]".repeat(600));
    assert!(Json::parse(&buried).is_err(), "over MAX_DEPTH must reject, not recurse");
    let shallow = format!("{}{}{}", "[".repeat(100), "1", "]".repeat(100));
    assert!(Json::parse(&shallow).is_ok());
}

#[test]
fn valid_lines_keep_decoding_after_hostile_neighbours() {
    // A decoder must be stateless across lines: hostile input on one line
    // cannot poison the next (each line gets a fresh Reader, but this
    // pins the contract).
    let corpus = seed_corpus();
    let good = &corpus[0];
    let (b1, k1) = record_identity_from_line(good).expect("seed line must decode");
    exercise("\u{0}\u{feff}{{{{{{{{");
    exercise(r#""\ud800\ud800\ud800"#);
    let (b2, k2) = record_identity_from_line(good).expect("seed line must still decode");
    assert_eq!(b1, b2);
    assert_eq!(k1, k2);
}
