//! Acceptance tests for `arco serve-tune` — tuning-as-a-service:
//!
//! - a depth-1 single-client job reproduces the in-process `arco compare`
//!   driver bit-identically (best point, trace, measurement counts),
//! - the per-(client, task) quota refuses exhausted accounts at the door
//!   and the ledger conserves every charge (charged == settled),
//! - a repeat job from a second client is served from the daemon's shared
//!   cache with zero fresh measurements,
//! - cancellation stops a queued job immediately and a running job at its
//!   next batch boundary, keeping partial results,
//! - every documented refusal (`unintelligible request`, unknown job,
//!   unintelligible/stale cursors) comes back as a structured error with
//!   the exact text the runbook promises, and
//! - the soak: a dozen concurrent clients against a churning two-shard
//!   loopback fleet (one shard killed and revived mid-run) — no
//!   starvation, gap-free monotone paginated traces, exact ledger
//!   conservation, bounded submit → first-result latency.

use arco::eval::{
    serve_measure, serve_measure_local_with, spawn_tune_local, BackendKind, BackendSpec, Cursor,
    CursorKind, Engine, EngineConfig, JobSpec, JobState, PointKey, ServeOptions, ServerHandle,
    TuneClient, TuneServeOptions,
};
use arco::space::ConfigSpace;
use arco::tuner::{tune_model_with, Fidelity, Framework, TraceEntry, TuneBudget};
use arco::workload::{model_by_name, Conv2dTask};
use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn analytical_engine() -> Engine {
    Engine::new(EngineConfig {
        backend: BackendKind::Analytical.into(),
        workers: 2,
        ..Default::default()
    })
    .unwrap()
}

/// Loopback analytical measure shard with injected per-point latency.
fn throttled_shard(delay: Duration) -> ServerHandle {
    serve_measure_local_with(
        Arc::new(analytical_engine()),
        ServeOptions { measure_delay: delay, ..ServeOptions::default() },
    )
    .unwrap()
}

/// Everything a trace entry carries except the wall-clock stamp.
type TraceRow = (usize, usize, f64, f64, bool, f64);

fn rows(trace: &[TraceEntry]) -> Vec<TraceRow> {
    trace
        .iter()
        .map(|e| (e.ordinal, e.iteration, e.gflops, e.best_gflops, e.valid, e.modeled_cum_secs))
        .collect()
}

fn spec(client: &str, framework: Framework, task: Conv2dTask, trials: usize, seed: u64) -> JobSpec {
    JobSpec {
        client: client.to_string(),
        framework,
        task,
        trials,
        batch: 8,
        pipeline_depth: 1,
        seed,
        quick: true,
        fidelity: Fidelity::Exact,
    }
}

#[test]
fn depth_1_job_is_bit_identical_to_the_in_process_driver() {
    let model = model_by_name("alexnet").unwrap();
    let budget = TuneBudget { total_measurements: 24, batch: 8, workers: 2, ..Default::default() };
    let seed = 9u64;

    // Reference: the in-process compare driver (AutoTVM replans from every
    // observation, so any ordering drift in the service path would change
    // its plans and show up here).
    let local =
        tune_model_with(&analytical_engine(), Framework::AutoTvm, &model, budget, true, seed)
            .unwrap();

    let handle =
        spawn_tune_local(Arc::new(analytical_engine()), TuneServeOptions::default()).unwrap();
    let addr = handle.addr().to_string();
    let mut client = TuneClient::connect(&addr, "parity").unwrap();
    assert_eq!(client.backend(), "analytical");

    let uniq = model.unique_tasks();
    assert_eq!(local.tasks.len(), uniq.len());
    let mut jobs = Vec::new();
    for (i, (task, _)) in uniq.iter().enumerate() {
        // Same per-task seed derivation as the in-process driver.
        let s = spec("parity", Framework::AutoTvm, *task, 24, seed ^ (i as u64) << 32);
        let (id, _) = client.submit(s).unwrap();
        jobs.push(id);
    }
    for (i, id) in jobs.iter().enumerate() {
        let done = client.wait(*id, 7, Duration::from_millis(5)).unwrap();
        assert_eq!(done.status.state, JobState::Done, "job {id}: {:?}", done.status.error);
        let outcome = done.outcome.expect("done job must carry an outcome");
        let reference = &local.tasks[i].result;
        assert_eq!(outcome.measurements, reference.measurements, "task {i}");
        assert_eq!(outcome.best.seconds, reference.best.seconds, "task {i}: best diverged");
        assert_eq!(outcome.best.cycles, reference.best.cycles);
        let space = ConfigSpace::for_task(&uniq[i].0, Framework::AutoTvm.tunes_hardware());
        let ref_values = reference.best_point.as_ref().map(|p| PointKey::of(&space, p).values);
        assert_eq!(outcome.best_values, ref_values, "task {i}: best point diverged");
        assert_eq!(rows(&done.trace), rows(&reference.trace), "task {i}: trace diverged");
    }
    handle.shutdown();
}

#[test]
fn quota_admission_refuses_exhausted_accounts_and_the_ledger_conserves() {
    let opts = TuneServeOptions { quota: 10, ..Default::default() };
    let handle = spawn_tune_local(Arc::new(analytical_engine()), opts).unwrap();
    let addr = handle.addr().to_string();
    let task = Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1);

    let mut alice = TuneClient::connect(&addr, "alice").unwrap();
    assert_eq!(alice.quota(), 10);
    // The job asks for 100 points; the 10-point account is binding.
    let (id, _) = alice.submit(spec("alice", Framework::Random, task, 100, 3)).unwrap();
    let done = alice.wait(id, 4, Duration::from_millis(5)).unwrap();
    assert_eq!(done.status.state, JobState::Done);
    let outcome = done.outcome.unwrap();
    assert_eq!(outcome.measurements, 10, "the quota must cap the job");
    assert_eq!(outcome.fresh + outcome.cache_served, outcome.measurements);
    assert_eq!(done.trace.len(), 10);

    // The spent account is refused at the door with the documented text.
    let err = alice.submit(spec("alice", Framework::Random, task, 10, 4)).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("quota exhausted: client alice"), "unexpected refusal: {msg}");

    // Quotas are per (client, task): a different client still gets in, and
    // the repeat of the same points is served from the daemon's shared
    // cache — zero fresh measurements (measure once, charge everyone).
    let mut bob = TuneClient::connect(&addr, "bob").unwrap();
    let (id, _) = bob.submit(spec("bob", Framework::Random, task, 10, 3)).unwrap();
    let done = bob.wait(id, 4, Duration::from_millis(5)).unwrap();
    let outcome = done.outcome.unwrap();
    assert_eq!(outcome.measurements, 10);
    assert_eq!(outcome.fresh, 0, "repeat job must be cache-served");
    assert_eq!(outcome.cache_served, 10);

    // Exact conservation, account by account: everything charged settled.
    let stats = handle.ledger_stats();
    assert_eq!(stats.per_task_points, 10);
    assert_eq!(stats.tenants.len(), 2);
    for t in &stats.tenants {
        assert_eq!(t.account.charged, 10, "{}/{}", t.framework, t.task);
        assert_eq!(t.account.settled(), 10, "{}/{}", t.framework, t.task);
    }
    handle.shutdown();
}

#[test]
fn cancel_stops_queued_jobs_immediately_and_running_jobs_at_a_batch_boundary() {
    // One runner and a throttled fleet: job 1 occupies the runner while
    // job 2 waits in the queue.
    let shard = throttled_shard(Duration::from_millis(5));
    let engine = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![shard.addr().to_string()]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let opts = TuneServeOptions { runners: 1, ..Default::default() };
    let handle = spawn_tune_local(Arc::new(engine), opts).unwrap();
    let addr = handle.addr().to_string();
    let task = Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1);

    let mut client = TuneClient::connect(&addr, "cli").unwrap();
    let (running, _) = client.submit(spec("cli", Framework::Random, task, 400, 11)).unwrap();
    let (queued, _) = client.submit(spec("cli", Framework::Random, task, 400, 12)).unwrap();

    // The queued job dies right where it stands: no runner ever picks it
    // up, its trace stays empty, it carries no outcome.
    assert_eq!(client.cancel(queued).unwrap(), JobState::Cancelled);
    let done = client.wait(queued, 8, Duration::from_millis(5)).unwrap();
    assert_eq!(done.status.state, JobState::Cancelled);
    assert!(done.trace.is_empty());
    assert!(done.outcome.is_none());

    // The running job: wait for real progress, then cancel. It stops at
    // the next batch boundary, keeping the partial trace and an outcome.
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let status = client.status(running).unwrap();
        if status.measured > 0 {
            break;
        }
        assert!(Instant::now() < deadline, "job {running} never made progress");
        std::thread::sleep(Duration::from_millis(5));
    }
    let state = client.cancel(running).unwrap();
    assert!(state == JobState::Running || state == JobState::Cancelled);
    let done = client.wait(running, 64, Duration::from_millis(5)).unwrap();
    assert_eq!(done.status.state, JobState::Cancelled);
    let outcome = done.outcome.expect("a cancelled running job keeps its partial outcome");
    assert!(outcome.measurements > 0);
    assert!(outcome.measurements < 400, "cancel must stop the job early");
    assert_eq!(done.trace.len(), outcome.measurements);

    handle.shutdown();
    shard.shutdown();
}

#[test]
fn refusals_carry_the_documented_error_text() {
    let opts = TuneServeOptions { trace_cap: 8, ..Default::default() };
    let handle = spawn_tune_local(Arc::new(analytical_engine()), opts).unwrap();
    let addr = handle.addr().to_string();
    let task = Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1);
    let mut client = TuneClient::connect(&addr, "cli").unwrap();

    // Unknown job, all three job-addressed ops.
    for err in [
        client.status(99).unwrap_err(),
        client.trace_page(99, None, 4).unwrap_err(),
        client.cancel(99).unwrap_err(),
    ] {
        assert!(err.to_string().contains("unknown job 99"), "unexpected: {err}");
    }

    // A finished 32-point job on a trace_cap=8 daemon retains 25..=32.
    let (id, _) = client.submit(spec("cli", Framework::Random, task, 32, 5)).unwrap();
    let deadline = Instant::now() + Duration::from_secs(30);
    while client.status(id).unwrap().state != JobState::Done {
        assert!(Instant::now() < deadline, "job never finished");
        std::thread::sleep(Duration::from_millis(5));
    }

    // A cursor of the wrong kind (or for the wrong job) is unintelligible.
    let jobs_cursor = Cursor::jobs_start().encode();
    let err = client.trace_page(id, Some(jobs_cursor), 4).unwrap_err();
    assert!(err.to_string().contains("unintelligible cursor"), "unexpected: {err}");
    let foreign = Cursor { kind: CursorKind::Trace, job: id + 1, last: 0 }.encode();
    let err = client.trace_page(id, Some(foreign), 4).unwrap_err();
    assert!(err.to_string().contains("unintelligible cursor"), "unexpected: {err}");

    // A cursor pointing into the compacted-away prefix is stale.
    let stale = Cursor { kind: CursorKind::Trace, job: id, last: 2 }.encode();
    let err = client.trace_page(id, Some(stale), 4).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("stale cursor"), "unexpected: {msg}");
    assert!(msg.contains("oldest retained entry is 25"), "unexpected: {msg}");

    // Resuming exactly at the window start still works, gap-free.
    let resume = Cursor { kind: CursorKind::Trace, job: id, last: 24 }.encode();
    let page = client.trace_page(id, Some(resume), 100).unwrap();
    assert_eq!(page.entries.first().unwrap().ordinal, 25);
    assert_eq!(page.entries.len(), 8);
    assert!(page.done);

    // A frame that is not a tune request at all gets the measure wire's
    // classic structured refusal, not a dropped connection.
    let mut raw = TcpStream::connect(&addr).unwrap();
    raw.write_all(b"this is not json\n").unwrap();
    raw.flush().unwrap();
    let mut line = String::new();
    BufReader::new(raw.try_clone().unwrap()).read_line(&mut line).unwrap();
    assert!(line.contains("unintelligible request"), "unexpected reply: {line}");

    handle.shutdown();
}

#[test]
fn soak_concurrent_clients_on_a_churning_fleet() {
    // Two loopback measure shards behind the daemon; shard B is killed
    // mid-soak and revived at the same address.
    let shard_a = throttled_shard(Duration::from_millis(1));
    let shard_b = throttled_shard(Duration::from_millis(1));
    let addr_b = shard_b.addr().to_string();
    let engine = Engine::new(EngineConfig {
        backend: BackendSpec::Remote(vec![shard_a.addr().to_string(), addr_b.clone()]),
        workers: 2,
        ..Default::default()
    })
    .unwrap();
    let opts = TuneServeOptions { runners: 4, ..Default::default() };
    let handle = spawn_tune_local(Arc::new(engine), opts).unwrap();
    let daemon_addr = handle.addr().to_string();

    let tasks = [
        Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1),
        Conv2dTask::new(1, 64, 14, 14, 64, 3, 3, 1, 1),
    ];
    let clients = 12usize;
    let trials = 24usize;

    // Churn: kill shard B mid-run, then bring a fresh shard up on the same
    // address (the fleet re-pings dead shards and revives them).
    let churn = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(150));
        shard_b.shutdown();
        std::thread::sleep(Duration::from_millis(150));
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            match serve_measure(&addr_b, Arc::new(analytical_engine())) {
                Ok(handle) => break handle,
                Err(_) if Instant::now() < deadline => {
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) => panic!("could not revive shard at {addr_b}: {e}"),
            }
        }
    });

    // Each client submits one job per task, then streams both with small
    // pages, checking that pagination is gap-free and monotone however the
    // fleet churns underneath.
    let workers: Vec<_> = (0..clients)
        .map(|c| {
            let daemon_addr = daemon_addr.clone();
            std::thread::spawn(move || -> anyhow::Result<()> {
                let name = format!("client{c}");
                let mut client = TuneClient::connect(&daemon_addr, &name)?;
                let mut jobs = Vec::new();
                for (t, task) in tasks.iter().enumerate() {
                    let mut s =
                        spec(&name, Framework::Random, *task, trials, (c as u64) << 8 | t as u64);
                    s.batch = 6;
                    s.pipeline_depth = 2;
                    let (id, _) = client.submit(s)?;
                    jobs.push(id);
                }
                for id in jobs {
                    let done = client.wait(id, 5, Duration::from_millis(10))?;
                    anyhow::ensure!(
                        done.status.state == JobState::Done,
                        "job {id} ended {} ({:?})",
                        done.status.state.name(),
                        done.status.error
                    );
                    let outcome = done.outcome.expect("done job must carry an outcome");
                    anyhow::ensure!(outcome.measurements == trials);
                    anyhow::ensure!(
                        outcome.fresh + outcome.cache_served == outcome.measurements,
                        "provenance must partition the measurements"
                    );
                    // Gap-free, monotone stream: dense ordinals, monotone
                    // running best.
                    anyhow::ensure!(done.trace.len() == trials);
                    let mut best = 0.0f64;
                    for (i, e) in done.trace.iter().enumerate() {
                        anyhow::ensure!(e.ordinal == i + 1, "gap at ordinal {}", e.ordinal);
                        anyhow::ensure!(e.best_gflops >= best, "running best went backwards");
                        best = e.best_gflops;
                    }
                    // Bounded submit → first-result latency (loose: CI).
                    let first = done.status.first_result_secs.unwrap_or(f64::INFINITY);
                    anyhow::ensure!(first < 60.0, "first result took {first:.1}s");
                }
                Ok(())
            })
        })
        .collect();

    for (c, worker) in workers.into_iter().enumerate() {
        worker.join().unwrap().unwrap_or_else(|e| panic!("client{c}: {e:#}"));
    }
    let revived = churn.join().unwrap();

    // No starvation: every job the daemon ever held is Done.
    let statuses = handle.job_statuses();
    assert_eq!(statuses.len(), clients * tasks.len());
    for s in &statuses {
        assert_eq!(s.state, JobState::Done, "job {} ({}/{})", s.id, s.client, s.task_id);
    }

    // Exact conservation on every (client, task) account: the loop charges
    // exactly what it submits and everything submitted was observed.
    let stats = handle.ledger_stats();
    assert_eq!(stats.tenants.len(), clients * tasks.len());
    for t in &stats.tenants {
        assert_eq!(t.account.charged, trials, "{}/{}", t.framework, t.task);
        assert_eq!(t.account.settled(), trials, "{}/{}", t.framework, t.task);
        assert_eq!(t.account.fresh + t.account.cache_served, trials);
    }

    handle.shutdown();
    shard_a.shutdown();
    revived.shutdown();
}
