//! Cursor/keyset pagination primitives for the `serve-tune` daemon.
//!
//! Two pieces, both deliberately tiny and wire-agnostic:
//!
//! - [`Cursor`] — an opaque resumption token a client hands back verbatim
//!   to fetch the next page. It is *keyset* state (the last-seen trace
//!   ordinal or job id), not an offset, so it stays correct while the
//!   underlying sequence keeps growing: a page fetched after 10k more
//!   appends continues exactly where the previous one ended, gap-free.
//!   The encoding is checksummed so a corrupted or hand-edited token is
//!   rejected instead of silently serving the wrong page.
//! - [`PagedTrace`] — a bounded append-only window over a monotone
//!   sequence. Appends are O(1); when a capacity is set, the oldest
//!   entries are evicted (compacted away) and a cursor pointing before
//!   the window is reported as [`PageError::Stale`] — the client must
//!   restart rather than silently skip a gap.
//!
//! Neither piece buffers the whole sequence per client: the daemon holds
//! one window per job and every client carries its own position in its
//! cursor.

use std::collections::VecDeque;
use std::fmt;

/// What a cursor paginates over. Encoded into the token so a trace cursor
/// replayed against a job listing (or vice versa) is rejected.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CursorKind {
    /// Pages over one job's trace entries; `last` is a trace ordinal.
    Trace,
    /// Pages over the daemon's job table; `last` is a job id.
    Jobs,
}

impl CursorKind {
    fn tag(self) -> &'static str {
        match self {
            CursorKind::Trace => "t",
            CursorKind::Jobs => "j",
        }
    }

    fn from_tag(tag: &str) -> Option<CursorKind> {
        match tag {
            "t" => Some(CursorKind::Trace),
            "j" => Some(CursorKind::Jobs),
            _ => None,
        }
    }
}

/// Opaque pagination token: "everything up to and including `last` has
/// been delivered". Clients treat the encoded form as a black box.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Cursor {
    /// What the token paginates over.
    pub kind: CursorKind,
    /// Job the token belongs to (0 for job listings, which span jobs).
    pub job: u64,
    /// Last-seen key: trace ordinal ([`CursorKind::Trace`]) or job id
    /// ([`CursorKind::Jobs`]). 0 means "from the beginning".
    pub last: u64,
}

/// FNV-1a over the payload — not cryptographic, just enough to catch
/// truncation, concatenation and hand-editing of tokens.
fn checksum(payload: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in payload.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

impl Cursor {
    /// First-page cursor for one job's trace.
    pub fn trace_start(job: u64) -> Cursor {
        Cursor { kind: CursorKind::Trace, job, last: 0 }
    }

    /// First-page cursor for the job listing.
    pub fn jobs_start() -> Cursor {
        Cursor { kind: CursorKind::Jobs, job: 0, last: 0 }
    }

    /// Serialize to the opaque wire form (`c1.<kind>.<job>.<last>.<sum>`).
    pub fn encode(&self) -> String {
        let payload = format!("{}.{}.{}", self.kind.tag(), self.job, self.last);
        format!("c1.{payload}.{:016x}", checksum(&payload))
    }

    /// Parse a token a client handed back. `None` for anything that is
    /// not a well-formed, checksum-intact cursor of a known version.
    pub fn decode(token: &str) -> Option<Cursor> {
        let rest = token.strip_prefix("c1.")?;
        let (payload, sum_hex) = rest.rsplit_once('.')?;
        let sum = u64::from_str_radix(sum_hex, 16).ok()?;
        if sum_hex.len() != 16 || sum != checksum(payload) {
            return None;
        }
        let mut parts = payload.split('.');
        let kind = CursorKind::from_tag(parts.next()?)?;
        let job = parts.next()?.parse().ok()?;
        let last = parts.next()?.parse().ok()?;
        if parts.next().is_some() {
            return None;
        }
        Some(Cursor { kind, job, last })
    }
}

/// Why a page could not be served.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum PageError {
    /// The cursor points at entries the bounded window has already
    /// evicted: resuming would silently skip `missing` entries, so the
    /// caller must restart from the current window instead.
    Stale {
        /// Position the cursor asked to resume after.
        after: u64,
        /// Oldest key still held by the window.
        oldest_kept: u64,
    },
}

impl fmt::Display for PageError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PageError::Stale { after, oldest_kept } => write!(
                f,
                "stale cursor: position {after} compacted away (oldest retained entry is {oldest_kept})"
            ),
        }
    }
}

impl std::error::Error for PageError {}

/// A bounded window over an append-only monotone sequence, keyed by the
/// 1-based position of each entry. With `cap == 0` the window is
/// unbounded (every entry retained); otherwise appends beyond `cap`
/// evict from the front and cursors pointing before the window are
/// rejected as stale.
#[derive(Debug)]
pub struct PagedTrace<T> {
    window: VecDeque<T>,
    /// Entries evicted from the front — the first retained entry has
    /// 1-based key `dropped + 1`.
    dropped: u64,
    cap: usize,
}

impl<T: Clone> PagedTrace<T> {
    /// `cap == 0`: unbounded. Otherwise at most `cap` entries retained.
    pub fn new(cap: usize) -> PagedTrace<T> {
        PagedTrace { window: VecDeque::new(), dropped: 0, cap }
    }

    /// Append one entry (its key is `self.total() + 1` at call time).
    pub fn push(&mut self, entry: T) {
        self.window.push_back(entry);
        if self.cap != 0 {
            while self.window.len() > self.cap {
                self.window.pop_front();
                self.dropped += 1;
            }
        }
    }

    /// Total entries ever appended (retained + evicted).
    pub fn total(&self) -> u64 {
        self.dropped + self.window.len() as u64
    }

    /// Entries currently retained.
    pub fn retained(&self) -> usize {
        self.window.len()
    }

    /// Serve up to `limit` entries with keys strictly greater than
    /// `after`, each tagged with its key. An empty page means the caller
    /// has caught up (page again later, or stop if the producer is done).
    /// `Err(Stale)` means `after` precedes the retained window.
    pub fn page(&self, after: u64, limit: usize) -> Result<Vec<(u64, T)>, PageError> {
        if after < self.dropped {
            return Err(PageError::Stale { after, oldest_kept: self.dropped + 1 });
        }
        let skip = (after - self.dropped) as usize;
        Ok(self
            .window
            .iter()
            .enumerate()
            .skip(skip)
            .take(limit)
            .map(|(i, e)| (self.dropped + i as u64 + 1, e.clone()))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Mutex};

    #[test]
    fn cursor_round_trip() {
        for c in [
            Cursor::trace_start(7),
            Cursor::jobs_start(),
            Cursor { kind: CursorKind::Trace, job: u64::MAX, last: 123_456 },
            Cursor { kind: CursorKind::Jobs, job: 0, last: u64::MAX },
        ] {
            let token = c.encode();
            assert_eq!(Cursor::decode(&token), Some(c), "token {token}");
        }
    }

    #[test]
    fn tampered_or_malformed_cursors_are_rejected() {
        let good = Cursor { kind: CursorKind::Trace, job: 3, last: 41 }.encode();
        assert!(Cursor::decode(&good).is_some());
        // Flip the payload without fixing the checksum.
        let tampered = good.replace(".41.", ".42.");
        assert_ne!(tampered, good);
        assert_eq!(Cursor::decode(&tampered), None);
        // Truncation, garbage, wrong version, empty.
        assert_eq!(Cursor::decode(&good[..good.len() - 2]), None);
        assert_eq!(Cursor::decode("not a cursor"), None);
        assert_eq!(Cursor::decode(""), None);
        assert_eq!(Cursor::decode(&good.replacen("c1.", "c9.", 1)), None);
        // A jobs cursor is not a trace cursor even with a valid checksum.
        let jobs = Cursor { kind: CursorKind::Jobs, job: 0, last: 41 }.encode();
        assert_eq!(Cursor::decode(&jobs).unwrap().kind, CursorKind::Jobs);
    }

    #[test]
    fn pages_are_gap_free_and_terminate_on_empty() {
        let mut t = PagedTrace::new(0);
        for i in 1..=25u64 {
            t.push(i * 10);
        }
        let mut after = 0u64;
        let mut seen = Vec::new();
        loop {
            let page = t.page(after, 4).unwrap();
            if page.is_empty() {
                break; // empty page is the termination signal
            }
            for (key, v) in page {
                assert_eq!(key, after + 1, "keys must be dense and monotone");
                assert_eq!(v, key * 10);
                after = key;
                seen.push(v);
            }
        }
        assert_eq!(seen.len(), 25);
        // Caught up: paging again stays empty until a new append.
        assert!(t.page(after, 4).unwrap().is_empty());
        t.push(260);
        assert_eq!(t.page(after, 4).unwrap(), vec![(26, 260)]);
    }

    #[test]
    fn pagination_is_stable_under_concurrent_append() {
        // A writer keeps appending while a reader pages: every page must
        // resume exactly where the previous ended, with no gap and no
        // duplicate, whatever interleaving occurs.
        let t = Arc::new(Mutex::new(PagedTrace::new(0)));
        let total = 2_000u64;
        let writer = {
            let t = Arc::clone(&t);
            std::thread::spawn(move || {
                for i in 1..=total {
                    t.lock().unwrap().push(i);
                    if i % 64 == 0 {
                        std::thread::yield_now();
                    }
                }
            })
        };
        let mut after = 0u64;
        let mut got = Vec::new();
        while after < total {
            let page = t.lock().unwrap().page(after, 7).unwrap();
            if page.is_empty() {
                std::thread::yield_now();
                continue;
            }
            for (key, v) in page {
                assert_eq!(key, after + 1, "gap or duplicate under concurrent append");
                assert_eq!(v, key);
                after = key;
                got.push(v);
            }
        }
        writer.join().unwrap();
        assert_eq!(got.len() as u64, total);
    }

    #[test]
    fn stale_cursor_on_compacted_window_is_rejected() {
        let mut t = PagedTrace::new(10);
        for i in 1..=30u64 {
            t.push(i);
        }
        assert_eq!(t.total(), 30);
        assert_eq!(t.retained(), 10);
        // Entries 1..=20 are gone; resuming "after 5" would skip 15..=20.
        let err = t.page(5, 4).unwrap_err();
        assert_eq!(err, PageError::Stale { after: 5, oldest_kept: 21 });
        assert!(err.to_string().contains("stale cursor"));
        // The boundary: "after 20" is exactly the window start — fine.
        let page = t.page(20, 4).unwrap();
        assert_eq!(page.first().unwrap().0, 21);
        // And a fully caught-up cursor still terminates with empty pages.
        assert!(t.page(30, 4).unwrap().is_empty());
    }
}
