//! Shared measurement-budget accounting for the paper's equal-budget
//! protocol, plus the queue-aware dispatcher that keeps concurrent tuning
//! jobs from monopolizing a measurement fleet.
//!
//! The paper's comparisons (Figs. 5–7, Table 6) are only meaningful when
//! every framework spends the *same* per-task measurement budget. The
//! [`BudgetLedger`] sits between the tuning loop and the
//! [`Engine`](super::Engine) and makes that protocol enforceable:
//!
//! - Before a job measures a batch it must [`charge`](BudgetLedger::charge)
//!   its (framework, task) account; the ledger admits at most the remaining
//!   allowance, so an over-planning strategy can never breach the budget.
//! - After the batch returns, [`settle`](BudgetLedger::settle) records the
//!   per-point [`Origin`] provenance: *fresh* points paid simulator time
//!   somewhere, *cache-served* points were answered from shared state a
//!   competing tenant (or an earlier batch) already paid for. Both are
//!   debited identically — "measure once, charge everyone" — so budgets
//!   stay comparable across frameworks while the run's wall-clock cost
//!   collapses to the unique-point frontier. The modeled hardware cost of
//!   a point is a pure function of its (deterministic) measurement result,
//!   so every tenant that plans the same point is debited the same modeled
//!   seconds regardless of who measured it first.
//!
//! The [`Dispatcher`] is the scheduling half: it admits at most
//! `slots` measurement batches to the engine at once and serves waiting
//! tenants strictly first-come-first-served. Permits are held per
//! *in-flight batch* — a pipelining tenant (`--pipeline-depth >= 2`)
//! checks out one ticket per submitted batch and releases each slot the
//! moment that batch's measurement returns — and a tenant that just
//! measured re-queues behind every waiting competitor, so concurrent
//! (framework, task) jobs interleave batch-by-batch instead of one
//! framework monopolizing the shards. The slot count tracks
//! [`Engine::concurrent_batch_capacity`](super::Engine::concurrent_batch_capacity)
//! — for a remote fleet, the number of alive `serve-measure` shards — so
//! shard death shrinks admission and revival grows it again.

use super::proto::Origin;
use crate::util::json::Json;
use std::collections::{BTreeMap, VecDeque};
use std::sync::{Condvar, Mutex};

/// One (framework, task) account inside a [`BudgetLedger`].
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Account {
    /// Measurement points debited (admitted by [`BudgetLedger::charge`]).
    pub charged: usize,
    /// Settled points whose simulation actually ran for this tenant.
    pub fresh: usize,
    /// Settled points answered from shared state (engine cache, in-batch
    /// dedup, coalescing, fleet shard caches).
    pub cache_served: usize,
    /// Modeled hardware-measurement seconds debited. Identical for every
    /// tenant that plans the same point, fresh or cache-served.
    pub modeled_hw_secs: f64,
    /// Admitted points resolved at *screening* fidelity (scored by the
    /// calibrated analytical model, never simulated) under
    /// `--fidelity screen:<keep>`. Zero in exact mode.
    pub screened: usize,
    /// Modeled seconds debited for the screened points, at the screening
    /// tier's own (tiny) per-point cost — honest equal-cost accounting:
    /// every fidelity is charged at its modeled price.
    pub screened_secs: f64,
}

impl Account {
    /// Points settled so far (equals `charged` once every admitted batch
    /// has been measured — or screened out — and settled).
    pub fn settled(&self) -> usize {
        self.fresh + self.cache_served + self.screened
    }
}

/// Per-tenant debit snapshot inside [`LedgerStats`].
#[derive(Debug, Clone)]
pub struct TenantStats {
    pub framework: String,
    pub task: String,
    pub account: Account,
}

/// Snapshot of every account, in deterministic (framework, task) order.
#[derive(Debug, Clone)]
pub struct LedgerStats {
    /// The per-(framework, task) allowance the ledger enforces.
    pub per_task_points: usize,
    pub tenants: Vec<TenantStats>,
}

impl LedgerStats {
    pub fn total_charged(&self) -> usize {
        self.tenants.iter().map(|t| t.account.charged).sum()
    }

    pub fn total_fresh(&self) -> usize {
        self.tenants.iter().map(|t| t.account.fresh).sum()
    }

    pub fn total_cache_served(&self) -> usize {
        self.tenants.iter().map(|t| t.account.cache_served).sum()
    }

    pub fn total_screened(&self) -> usize {
        self.tenants.iter().map(|t| t.account.screened).sum()
    }

    /// One-line rendering for logs and CLI output. The `screened=` token
    /// only appears when some account actually screened — an exact-mode
    /// run's summary is byte-identical to the pre-multi-fidelity one.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "budget={}/task tenants={} charged={} fresh={} cache_served={}",
            self.per_task_points,
            self.tenants.len(),
            self.total_charged(),
            self.total_fresh(),
            self.total_cache_served()
        );
        let screened = self.total_screened();
        if screened > 0 {
            s.push_str(&format!(" screened={screened}"));
        }
        s
    }

    /// Machine-readable rendering (reports, `compare.json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("per_task_points", Json::num(self.per_task_points as f64)),
            (
                "tenants",
                Json::Arr(
                    self.tenants
                        .iter()
                        .map(|t| {
                            let mut o = Json::obj(vec![
                                ("framework", Json::str(t.framework.clone())),
                                ("task", Json::str(t.task.clone())),
                                ("charged", Json::num(t.account.charged as f64)),
                                ("fresh", Json::num(t.account.fresh as f64)),
                                ("cache_served", Json::num(t.account.cache_served as f64)),
                                ("modeled_hw_secs", Json::num(t.account.modeled_hw_secs)),
                            ]);
                            // Additive fields: only rendered when the run
                            // actually screened, so exact-mode reports stay
                            // bit-identical.
                            if t.account.screened > 0 {
                                o.set("screened", Json::num(t.account.screened as f64));
                                o.set("screened_secs", Json::num(t.account.screened_secs));
                            }
                            o
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Thread-safe shared budget: every (framework, task) tenant holds an
/// account capped at `per_task_points` admitted measurements.
pub struct BudgetLedger {
    per_task_points: usize,
    accounts: Mutex<BTreeMap<(String, String), Account>>,
}

impl BudgetLedger {
    /// A ledger allowing each (framework, task) tenant `per_task_points`
    /// measurements — the paper's Σb (Table 4/5).
    pub fn new(per_task_points: usize) -> BudgetLedger {
        BudgetLedger { per_task_points, accounts: Mutex::new(BTreeMap::new()) }
    }

    pub fn per_task_points(&self) -> usize {
        self.per_task_points
    }

    /// Admit up to `points` measurements against (framework, task),
    /// debiting the account. Returns how many were admitted: fewer than
    /// requested when the allowance is nearly spent, zero once exhausted.
    pub fn charge(&self, framework: &str, task: &str, points: usize) -> usize {
        let mut accounts = super::sync::lock_unpoisoned(&self.accounts);
        let account = accounts
            .entry((framework.to_string(), task.to_string()))
            .or_default();
        let admitted = points.min(self.per_task_points.saturating_sub(account.charged));
        account.charged += admitted;
        admitted
    }

    /// Measurements (framework, task) may still admit.
    pub fn remaining(&self, framework: &str, task: &str) -> usize {
        self.per_task_points.saturating_sub(self.account(framework, task).charged)
    }

    /// Settle `points` already-admitted candidates at *screening* fidelity:
    /// they were scored by the calibrated analytical model instead of the
    /// simulator, and are debited `secs_per_point` modeled seconds each —
    /// the screening tier's own price. The points must have been admitted
    /// by a preceding [`charge`](Self::charge) (the screening split happens
    /// after admission), so this never consumes extra allowance; it records
    /// how the allowance was spent.
    pub fn charge_screen(&self, framework: &str, task: &str, points: usize, secs_per_point: f64) {
        if points == 0 {
            return;
        }
        let mut accounts = super::sync::lock_unpoisoned(&self.accounts);
        let account = accounts
            .entry((framework.to_string(), task.to_string()))
            .or_default();
        account.screened += points;
        account.screened_secs += points as f64 * secs_per_point;
    }

    /// Record the provenance and modeled hardware cost of one measured
    /// batch. `origins` must cover exactly the points admitted by the
    /// matching [`charge`](Self::charge) call; `modeled_hw_secs` is the
    /// batch's modeled testbed time — a pure function of the results, so
    /// every tenant planning the same points is debited identically.
    pub fn settle(&self, framework: &str, task: &str, origins: &[Origin], modeled_hw_secs: f64) {
        let fresh = origins.iter().filter(|o| o.is_fresh()).count();
        let mut accounts = super::sync::lock_unpoisoned(&self.accounts);
        let account = accounts
            .entry((framework.to_string(), task.to_string()))
            .or_default();
        account.fresh += fresh;
        account.cache_served += origins.len() - fresh;
        account.modeled_hw_secs += modeled_hw_secs;
    }

    /// Snapshot of one tenant's account (zeroed when it never charged).
    pub fn account(&self, framework: &str, task: &str) -> Account {
        super::sync::lock_unpoisoned(&self.accounts)
            .get(&(framework.to_string(), task.to_string()))
            .copied()
            .unwrap_or_default()
    }

    /// Snapshot of every account, in deterministic (framework, task) order.
    pub fn stats(&self) -> LedgerStats {
        let accounts = super::sync::lock_unpoisoned(&self.accounts);
        LedgerStats {
            per_task_points: self.per_task_points,
            tenants: accounts
                .iter()
                .map(|((framework, task), account)| TenantStats {
                    framework: framework.clone(),
                    task: task.clone(),
                    account: *account,
                })
                .collect(),
        }
    }
}

/// State behind the dispatcher's lock.
#[derive(Debug, Default)]
struct DispatchState {
    slots: usize,
    in_flight: usize,
    /// Tickets waiting for admission, front = next to be served.
    queue: VecDeque<u64>,
    next_ticket: u64,
    dispatched: usize,
    waited: usize,
    peak_queue: usize,
}

/// Dispatcher counters (see [`Dispatcher::stats`]).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct DispatchStats {
    /// Current admission slots (tracks fleet capacity).
    pub slots: usize,
    /// Batches being measured right now.
    pub in_flight: usize,
    /// Permits granted over the dispatcher's lifetime.
    pub dispatched: usize,
    /// Checkouts that had to queue behind a competitor or a full fleet.
    pub waited: usize,
    /// Deepest the waiting queue ever got.
    pub peak_queue: usize,
}

/// FIFO admission of measurement batches: at most `slots` in flight, the
/// longest-waiting tenant always goes next. See the module docs for how
/// this interleaves competing tuning jobs over a shared fleet.
pub struct Dispatcher {
    state: Mutex<DispatchState>,
    ready: Condvar,
}

impl Dispatcher {
    /// A dispatcher admitting `slots` concurrent batches (clamped to ≥ 1).
    pub fn new(slots: usize) -> Dispatcher {
        Dispatcher {
            state: Mutex::new(DispatchState { slots: slots.max(1), ..Default::default() }),
            ready: Condvar::new(),
        }
    }

    /// Track capacity changes between batches (shard death/revival). Safe
    /// to call from any tenant at any time; shrinking never cancels
    /// permits already in flight, it only gates new admissions.
    pub fn set_slots(&self, slots: usize) {
        let mut state = super::sync::lock_unpoisoned(&self.state);
        let slots = slots.max(1);
        if state.slots != slots {
            state.slots = slots;
            self.ready.notify_all();
        }
    }

    /// Acquire an admission permit, blocking until it is this caller's
    /// turn (strict FIFO) *and* a slot is free. Dropping the permit
    /// releases the slot and wakes the next tenant in line.
    pub fn checkout(&self) -> DispatchPermit<'_> {
        let mut state = super::sync::lock_unpoisoned(&self.state);
        let ticket = state.next_ticket;
        state.next_ticket += 1;
        state.queue.push_back(ticket);
        state.peak_queue = state.peak_queue.max(state.queue.len());
        let mut counted_wait = false;
        loop {
            if state.queue.front() == Some(&ticket) && state.in_flight < state.slots {
                state.queue.pop_front();
                state.in_flight += 1;
                state.dispatched += 1;
                if state.in_flight < state.slots {
                    // Capacity remains: wake the next tenant in line.
                    self.ready.notify_all();
                }
                return DispatchPermit { dispatcher: self };
            }
            if !counted_wait {
                state.waited += 1;
                counted_wait = true;
            }
            state = super::sync::wait_unpoisoned(&self.ready, state);
        }
    }

    fn release(&self) {
        let mut state = super::sync::lock_unpoisoned(&self.state);
        state.in_flight -= 1;
        drop(state);
        self.ready.notify_all();
    }

    pub fn stats(&self) -> DispatchStats {
        let state = super::sync::lock_unpoisoned(&self.state);
        DispatchStats {
            slots: state.slots,
            in_flight: state.in_flight,
            dispatched: state.dispatched,
            waited: state.waited,
            peak_queue: state.peak_queue,
        }
    }
}

/// An admission permit for one measurement batch; releases on drop.
pub struct DispatchPermit<'a> {
    dispatcher: &'a Dispatcher,
}

impl Drop for DispatchPermit<'_> {
    fn drop(&mut self) {
        self.dispatcher.release();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Barrier;

    #[test]
    fn charge_caps_at_the_per_task_budget() {
        let ledger = BudgetLedger::new(10);
        assert_eq!(ledger.charge("arco", "t0", 6), 6);
        assert_eq!(ledger.remaining("arco", "t0"), 4);
        // Exhaustion mid-batch: a 6-point plan gets only the 4 remaining.
        assert_eq!(ledger.charge("arco", "t0", 6), 4);
        assert_eq!(ledger.charge("arco", "t0", 1), 0);
        assert_eq!(ledger.account("arco", "t0").charged, 10);
        // Other tenants are unaffected.
        assert_eq!(ledger.charge("arco", "t1", 6), 6);
        assert_eq!(ledger.charge("autotvm", "t0", 6), 6);
    }

    #[test]
    fn settle_splits_fresh_from_cache_served() {
        let ledger = BudgetLedger::new(100);
        // First framework measures three points fresh...
        assert_eq!(ledger.charge("a", "t", 3), 3);
        ledger.settle("a", "t", &[Origin::Fresh, Origin::Fresh, Origin::Fresh], 3.0);
        // ...the second plans the same points and is served from the cache,
        // but is debited the identical count and modeled cost.
        assert_eq!(ledger.charge("b", "t", 3), 3);
        ledger.settle("b", "t", &[Origin::Cached, Origin::Cached, Origin::ShardCached], 3.0);
        let a = ledger.account("a", "t");
        let b = ledger.account("b", "t");
        assert_eq!(a.charged, b.charged);
        assert_eq!(a.modeled_hw_secs, b.modeled_hw_secs);
        assert_eq!((a.fresh, a.cache_served), (3, 0));
        assert_eq!((b.fresh, b.cache_served), (0, 3));
        assert_eq!(a.settled(), 3);
        assert_eq!(b.settled(), 3);
        let stats = ledger.stats();
        assert_eq!(stats.total_charged(), 6);
        assert_eq!(stats.total_fresh(), 3);
        assert_eq!(stats.total_cache_served(), 3);
        assert!(stats.summary().contains("charged=6"));
        assert!(stats.to_json().dump().contains("cache_served"));
    }

    #[test]
    fn screened_points_settle_against_the_same_allowance() {
        let ledger = BudgetLedger::new(32);
        // A screened batch: 8 candidates admitted, 2 kept for the
        // simulator, 6 resolved at screening fidelity.
        assert_eq!(ledger.charge("arco", "t", 8), 8);
        ledger.charge_screen("arco", "t", 6, 1e-6);
        ledger.settle("arco", "t", &[Origin::Fresh, Origin::Fresh], 2.0);
        let a = ledger.account("arco", "t");
        assert_eq!(a.charged, 8);
        assert_eq!(a.screened, 6);
        assert_eq!((a.fresh, a.cache_served), (2, 0));
        assert_eq!(a.settled(), a.charged, "screened points settle the allowance too");
        assert!((a.screened_secs - 6e-6).abs() < 1e-12);
        // Screening consumed allowance via the preceding charge: only 24
        // candidates remain for this tenant.
        assert_eq!(ledger.remaining("arco", "t"), 24);
        let stats = ledger.stats();
        assert_eq!(stats.total_screened(), 6);
        assert!(stats.summary().ends_with(" screened=6"));
        assert!(stats.to_json().dump().contains("screened_secs"));
        // Zero-screen accounts render exactly as before multi-fidelity.
        let exact = BudgetLedger::new(32);
        exact.charge("a", "t", 4);
        exact.settle("a", "t", &[Origin::Fresh; 4], 1.0);
        let s = exact.stats().summary();
        assert!(!s.contains("screened"), "exact-mode summary must be unchanged: {s}");
        assert!(!exact.stats().to_json().dump().contains("screened"));
        // Zero-point screen settles are a no-op, not an account creation.
        exact.charge_screen("ghost", "t", 0, 1e-6);
        assert_eq!(exact.stats().tenants.len(), 1);
    }

    #[test]
    fn concurrent_charging_never_over_admits() {
        let ledger = BudgetLedger::new(64);
        let admitted = AtomicUsize::new(0);
        let barrier = Barrier::new(8);
        std::thread::scope(|scope| {
            for _ in 0..8 {
                scope.spawn(|| {
                    barrier.wait();
                    // 8 threads × 4 batches × 3 points = 96 requested > 64.
                    for _ in 0..4 {
                        admitted.fetch_add(ledger.charge("f", "t", 3), Ordering::SeqCst);
                    }
                });
            }
        });
        assert_eq!(admitted.load(Ordering::SeqCst), 64, "budget must be admitted exactly once");
        assert_eq!(ledger.account("f", "t").charged, 64);
        assert_eq!(ledger.remaining("f", "t"), 0);
    }

    #[test]
    fn stats_order_is_deterministic() {
        let ledger = BudgetLedger::new(8);
        ledger.charge("z", "t1", 1);
        ledger.charge("a", "t2", 1);
        ledger.charge("a", "t1", 1);
        let names: Vec<(String, String)> = ledger
            .stats()
            .tenants
            .iter()
            .map(|t| (t.framework.clone(), t.task.clone()))
            .collect();
        assert_eq!(
            names,
            vec![
                ("a".to_string(), "t1".to_string()),
                ("a".to_string(), "t2".to_string()),
                ("z".to_string(), "t1".to_string()),
            ]
        );
    }

    #[test]
    fn dispatcher_bounds_in_flight_batches() {
        let dispatcher = Dispatcher::new(2);
        let active = AtomicUsize::new(0);
        let peak = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            for _ in 0..6 {
                scope.spawn(|| {
                    for _ in 0..5 {
                        let _permit = dispatcher.checkout();
                        let now = active.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(std::time::Duration::from_millis(1));
                        active.fetch_sub(1, Ordering::SeqCst);
                    }
                });
            }
        });
        assert!(peak.load(Ordering::SeqCst) <= 2, "admission exceeded the slot bound");
        let stats = dispatcher.stats();
        assert_eq!(stats.dispatched, 30);
        assert_eq!(stats.in_flight, 0, "every permit must be released");
        assert!(stats.waited > 0, "6 tenants on 2 slots must have queued");
        assert!(stats.peak_queue >= 1);
    }

    #[test]
    fn growing_slots_unblocks_waiters() {
        let dispatcher = Dispatcher::new(1);
        let first = dispatcher.checkout();
        let entered = AtomicUsize::new(0);
        std::thread::scope(|scope| {
            let handle = scope.spawn(|| {
                let _permit = dispatcher.checkout();
                entered.fetch_add(1, Ordering::SeqCst);
            });
            // The second tenant is stuck behind the single slot...
            std::thread::sleep(std::time::Duration::from_millis(20));
            assert_eq!(entered.load(Ordering::SeqCst), 0);
            // ...until a shard revival grows the fleet.
            dispatcher.set_slots(2);
            handle.join().unwrap();
            assert_eq!(entered.load(Ordering::SeqCst), 1);
        });
        drop(first);
        assert_eq!(dispatcher.stats().in_flight, 0);
    }
}
