//! Online calibration of the analytical roofline proxy.
//!
//! The analytical model charges `serial + (1 - overlap) * overlapped`
//! cycles per point ([`super::backend::analytical_terms`]). Historically
//! `overlap` was a pair of hard-coded constants ([`SEED_OVERLAP`]); for
//! multi-fidelity screening the model must track the *cycle model* it is
//! standing in for, so a [`Calibration`] refits the overlap coefficient
//! per task and per vthread class against every fresh cycle-model point
//! the engine observes.
//!
//! The fit is an incremental one-parameter ridge regression. With
//! `x = overlap_cycles` and `y = measured_cycles - serial_cycles`, the
//! model is `y = a·x` where `a = 1 - overlap`; the estimate shrinks
//! toward the seed coefficient with a scale-free pseudo-observation
//! weight, so a task with three observations screens barely differently
//! from the seeds while a task with hundreds follows the simulator.
//!
//! Calibration state persists as a JSON sidecar next to the measurement
//! journal ([`Calibration::sidecar_path`]) and is gated on the full
//! measurement [`Fingerprint`]: a `CYCLE_MODEL_VERSION` (or analytical
//! version, or hardware-default) bump makes old coefficients describe a
//! simulator that no longer exists, so loading discards them and restarts
//! from the seeds.

use super::backend::{AnalyticalTerms, SEED_OVERLAP};
use super::proto::Fingerprint;
use crate::util::json::{read_json_file, write_json_file, Json};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use super::sync::lock_unpoisoned;
use std::sync::Mutex;

/// Observations required in a class before the fitted coefficient is
/// trusted over the seed at all.
const MIN_OBSERVATIONS: u64 = 3;

/// Pseudo-observation weight of the seed coefficient in the ridge fit
/// (scale-free: multiplied by the mean `x²`, so it acts like this many
/// typical observations that agree with the seed).
const RIDGE_PSEUDO_OBS: f64 = 8.0;

/// Incremental sufficient statistics of `y = a·x` for one vthread class.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
struct ClassFit {
    sum_xx: f64,
    sum_xy: f64,
    n: u64,
}

impl ClassFit {
    fn observe(&mut self, x: f64, y: f64) {
        self.sum_xx += x * x;
        self.sum_xy += x * y;
        self.n += 1;
    }

    /// Ridge estimate of `a = 1 - overlap`, shrunk toward the seed `a0`.
    /// Clamped to `[0, 1]`: outside that range the "overlap" reading is
    /// meaningless and the residual is model error, not overlap.
    fn coeff(&self, a0: f64) -> f64 {
        if self.n < MIN_OBSERVATIONS || self.sum_xx <= 0.0 {
            return a0;
        }
        let mean_xx = self.sum_xx / self.n as f64;
        let lambda = RIDGE_PSEUDO_OBS * mean_xx;
        ((self.sum_xy + lambda * a0) / (self.sum_xx + lambda)).clamp(0.0, 1.0)
    }

    fn to_json(self) -> Json {
        Json::obj(vec![
            ("sum_xx", Json::num(self.sum_xx)),
            ("sum_xy", Json::num(self.sum_xy)),
            ("n", Json::num(self.n as f64)),
        ])
    }

    fn from_json(v: &Json) -> Option<ClassFit> {
        Some(ClassFit {
            sum_xx: v.get_f64("sum_xx")?,
            sum_xy: v.get_f64("sum_xy")?,
            n: v.get_f64("n")? as u64,
        })
    }
}

/// Per-task fit: one [`ClassFit`] per vthread class (single, dual).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
struct TaskFit {
    class: [ClassFit; 2],
}

struct CalibState {
    fingerprint: Fingerprint,
    tasks: BTreeMap<String, TaskFit>,
    observations: u64,
}

/// Shared, thread-safe calibration of the analytical overlap coefficients.
/// One lives on the measurement [`super::Engine`] when a run screens
/// (`--fidelity screen:...`); every fresh cycle-model point the engine
/// publishes feeds it, and the tuning loop reads fitted
/// [`overlaps`](Calibration::overlaps) per task when scoring candidates.
pub struct Calibration {
    state: Mutex<CalibState>,
}

impl Calibration {
    /// Fresh calibration at the seed coefficients, bound to a fingerprint.
    pub fn new(fingerprint: Fingerprint) -> Calibration {
        Calibration {
            state: Mutex::new(CalibState {
                fingerprint,
                tasks: BTreeMap::new(),
                observations: 0,
            }),
        }
    }

    /// Feed one fresh oracle observation: the analytical decomposition of
    /// the point and the cycles the oracle actually charged. Invalid
    /// points and degenerate terms are ignored — the model has nothing to
    /// learn from them.
    pub fn observe(&self, task_id: &str, terms: &AnalyticalTerms, measured_cycles: u64) {
        if !terms.valid || measured_cycles == 0 || terms.overlap_cycles <= 0.0 {
            return;
        }
        let x = terms.overlap_cycles;
        let y = measured_cycles as f64 - terms.serial_cycles;
        let mut st = lock_unpoisoned(&self.state);
        let fit = st.tasks.entry(task_id.to_string()).or_default();
        fit.class[terms.class()].observe(x, y);
        st.observations += 1;
    }

    /// Fitted overlap coefficients (`[single, dual]`) for one task.
    /// Unobserved tasks/classes answer the seeds, so screening before the
    /// first oracle batch behaves exactly like the uncalibrated backend.
    pub fn overlaps(&self, task_id: &str) -> [f64; 2] {
        let st = lock_unpoisoned(&self.state);
        let fit = st.tasks.get(task_id).copied().unwrap_or_default();
        let mut out = [0.0; 2];
        for (c, slot) in out.iter_mut().enumerate() {
            let a0 = 1.0 - SEED_OVERLAP[c];
            *slot = 1.0 - fit.class[c].coeff(a0);
        }
        out
    }

    /// Total observations absorbed (diagnostics).
    pub fn observations(&self) -> u64 {
        lock_unpoisoned(&self.state).observations
    }

    /// The fingerprint this calibration was fitted under.
    pub fn fingerprint(&self) -> Fingerprint {
        lock_unpoisoned(&self.state).fingerprint.clone()
    }

    /// Sidecar path for a journal: calibration journals alongside the
    /// measurements that produced it (`foo.jsonl` → `foo.jsonl.calib.json`).
    pub fn sidecar_path(journal: &Path) -> PathBuf {
        let mut os = journal.as_os_str().to_os_string();
        os.push(".calib.json");
        PathBuf::from(os)
    }

    pub fn to_json(&self) -> Json {
        let st = lock_unpoisoned(&self.state);
        let tasks: Vec<(String, Json)> = st
            .tasks
            .iter()
            .map(|(id, fit)| {
                (
                    id.clone(),
                    Json::obj(vec![
                        ("single", fit.class[0].to_json()),
                        ("dual", fit.class[1].to_json()),
                    ]),
                )
            })
            .collect();
        Json::obj(vec![
            ("fingerprint", st.fingerprint.to_json()),
            ("observations", Json::num(st.observations as f64)),
            ("tasks", Json::Obj(tasks)),
        ])
    }

    /// Decode a persisted calibration. `None` when the document is
    /// malformed or was fitted under a *different* fingerprint — the
    /// caller restarts from the seeds in both cases.
    pub fn from_json(v: &Json, expected: &Fingerprint) -> Option<Calibration> {
        let fp = Fingerprint::from_json(v.get("fingerprint")?)?;
        if &fp != expected {
            return None;
        }
        let mut tasks = BTreeMap::new();
        if let Json::Obj(fields) = v.get("tasks")? {
            for (id, fit) in fields {
                let task = TaskFit {
                    class: [
                        ClassFit::from_json(fit.get("single")?)?,
                        ClassFit::from_json(fit.get("dual")?)?,
                    ],
                };
                tasks.insert(id.clone(), task);
            }
        }
        let observations = v.get_f64("observations").unwrap_or(0.0) as u64;
        Some(Calibration {
            state: Mutex::new(CalibState { fingerprint: fp, tasks, observations }),
        })
    }

    /// Persist to a sidecar file.
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        write_json_file(path, &self.to_json())
    }

    /// Load from a sidecar, restarting from the seeds when the file is
    /// missing, unreadable, or fingerprint-gated out (a cycle-model bump
    /// invalidates coefficients fitted against the old simulator).
    pub fn load_or_new(path: &Path, expected: &Fingerprint) -> Calibration {
        match read_json_file(path) {
            Ok(v) => match Calibration::from_json(&v, expected) {
                Some(c) => {
                    crate::log_info!(
                        "calib",
                        "{}: resumed calibration ({} observations)",
                        path.display(),
                        c.observations()
                    );
                    c
                }
                None => {
                    crate::log_info!(
                        "calib",
                        "{}: calibration is stale or malformed (fingerprint mismatch?) — \
                         restarting from seed coefficients",
                        path.display()
                    );
                    Calibration::new(expected.clone())
                }
            },
            Err(_) => Calibration::new(expected.clone()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn terms(x: f64, serial: f64, vthreads: usize) -> AnalyticalTerms {
        AnalyticalTerms {
            serial_cycles: serial,
            overlap_cycles: x,
            vthreads,
            area_mm2: 1.0,
            occupancy: 0.5,
            cycle_time: 1e-9,
            flops: 1e9,
            valid: true,
        }
    }

    #[test]
    fn unobserved_calibration_answers_the_seeds() {
        let c = Calibration::new(Fingerprint::current());
        assert_eq!(c.overlaps("anything"), SEED_OVERLAP);
        assert_eq!(c.observations(), 0);
    }

    #[test]
    fn fit_converges_to_synthetic_ground_truth() {
        // Synthetic oracle with known overlaps: dual threads hide 92% of
        // the smaller term, a single thread only 40%.
        let truth = [0.40, 0.92];
        let c = Calibration::new(Fingerprint::current());
        let mut rng = Pcg32::seeded(9);
        for _ in 0..500 {
            let vthreads = 1 + rng.gen_range(2);
            let class = usize::from(vthreads >= 2);
            let x = 1e5 + rng.gen_f64() * 1e7;
            let serial = x * (1.0 + rng.gen_f64());
            let measured = serial + (1.0 - truth[class]) * x;
            c.observe("t", &terms(x, serial, vthreads), measured as u64);
        }
        let got = c.overlaps("t");
        for class in 0..2 {
            assert!(
                (got[class] - truth[class]).abs() < 0.02,
                "class {class}: fitted {} vs truth {}",
                got[class],
                truth[class]
            );
        }
        // A task nobody observed still answers the seeds.
        assert_eq!(c.overlaps("other"), SEED_OVERLAP);
    }

    #[test]
    fn few_observations_stay_near_the_seeds() {
        // One wild observation must not yank the coefficient: below
        // MIN_OBSERVATIONS the seed answers verbatim.
        let c = Calibration::new(Fingerprint::current());
        c.observe("t", &terms(1e6, 2e6, 2), (2e6 + 1e6) as u64); // implies overlap 0
        assert_eq!(c.overlaps("t"), SEED_OVERLAP);
        // Even past the floor, the ridge prior keeps early estimates
        // between the seed and the data.
        c.observe("t", &terms(1e6, 2e6, 2), (2e6 + 1e6) as u64);
        c.observe("t", &terms(1e6, 2e6, 2), (2e6 + 1e6) as u64);
        let got = c.overlaps("t")[1];
        assert!(got < SEED_OVERLAP[1] && got > 0.0, "shrunk estimate: {got}");
    }

    #[test]
    fn invalid_and_degenerate_observations_are_ignored() {
        let c = Calibration::new(Fingerprint::current());
        let mut bad = terms(1e6, 2e6, 2);
        bad.valid = false;
        c.observe("t", &bad, 1_000_000);
        c.observe("t", &terms(0.0, 2e6, 2), 1_000_000); // no overlapped term
        c.observe("t", &terms(1e6, 2e6, 2), 0); // empty measurement
        assert_eq!(c.observations(), 0);
        assert_eq!(c.overlaps("t"), SEED_OVERLAP);
    }

    #[test]
    fn calibration_state_survives_a_save_load_replay() {
        let c = Calibration::new(Fingerprint::current());
        for i in 0..40u64 {
            let x = 1e6 + i as f64 * 1e4;
            let serial = 3e6;
            c.observe("c3x28x28-32k3s1p1", &terms(x, serial, 2), (serial + 0.2 * x) as u64);
            c.observe("c3x28x28-32k3s1p1", &terms(x, serial, 1), (serial + 0.7 * x) as u64);
        }
        let dir = std::env::temp_dir().join(format!("arco_calib_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let journal = dir.join("measure.jsonl");
        let path = Calibration::sidecar_path(&journal);
        assert!(path.to_string_lossy().ends_with("measure.jsonl.calib.json"));
        c.save(&path).unwrap();

        let replayed = Calibration::load_or_new(&path, &Fingerprint::current());
        assert_eq!(replayed.observations(), c.observations());
        assert_eq!(replayed.overlaps("c3x28x28-32k3s1p1"), c.overlaps("c3x28x28-32k3s1p1"));
        assert_eq!(replayed.overlaps("unseen"), SEED_OVERLAP);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fingerprint_bump_discards_stale_calibration() {
        let c = Calibration::new(Fingerprint::current());
        for _ in 0..20 {
            c.observe("t", &terms(1e6, 3e6, 2), (3e6 + 0.05 * 1e6) as u64);
        }
        assert_ne!(c.overlaps("t"), SEED_OVERLAP);
        let dir = std::env::temp_dir().join(format!("arco_calib_fp_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("j.jsonl.calib.json");
        c.save(&path).unwrap();

        // Same fingerprint: coefficients come back.
        let same = Calibration::load_or_new(&path, &Fingerprint::current());
        assert_eq!(same.overlaps("t"), c.overlaps("t"));

        // Bumped cycle model: the sidecar is refused and the seeds return.
        let mut bumped = Fingerprint::current();
        bumped.cycle_model += 1;
        assert!(Calibration::from_json(&c.to_json(), &bumped).is_none());
        let reset = Calibration::load_or_new(&path, &bumped);
        assert_eq!(reset.overlaps("t"), SEED_OVERLAP);
        assert_eq!(reset.observations(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn malformed_sidecar_restarts_from_seeds() {
        let dir = std::env::temp_dir().join(format!("arco_calib_bad_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.calib.json");
        std::fs::write(&path, "{not json").unwrap();
        let c = Calibration::load_or_new(&path, &Fingerprint::current());
        assert_eq!(c.overlaps("t"), SEED_OVERLAP);
        // Missing file: also a clean start.
        let missing = Calibration::load_or_new(&dir.join("absent.json"), &Fingerprint::current());
        assert_eq!(missing.observations(), 0);
        std::fs::remove_dir_all(&dir).ok();
    }
}
