//! Wire schema for the tuning service (`arco serve-tune`).
//!
//! Same transport and rules as the measurement protocol in
//! [`super::proto`]: newline-delimited JSON frames (one request → one
//! response per line), a version handshake plus simulator
//! [`Fingerprint`] refusal, and the **additive-field compatibility
//! rule** — new optional fields may be added without a version bump as
//! long as a reader treats their absence as a safe default; removing or
//! re-typing a field bumps [`TUNE_PROTO_VERSION`]. The hot frame (a
//! `results` page streaming trace entries) is serialized straight into
//! the socket writer via the zero-copy streaming codec
//! ([`crate::util::json::stream`]) with a strict streaming decode on the
//! client and a lenient tree fallback, mirroring `proto.rs`.
//!
//! `docs/WIRE.md` is the field-by-field reference for every frame here;
//! keep the two in sync.

use super::proto::{result_from_json, result_to_json, values_from_json, values_to_json};
use super::proto::{write_frame, Fingerprint};
use crate::codegen::MeasureResult;
use crate::tuner::{Fidelity, Framework, TraceEntry, TraceFidelity};
use crate::util::json::stream::{Reader, StreamWriter, Token};
use crate::util::json::Json;
use crate::workload::Conv2dTask;
use std::io::Write;

/// Version of the tune-ops wire protocol (independent of the measure
/// protocol's `PROTO_VERSION`; both ride the same framing).
pub const TUNE_PROTO_VERSION: u64 = 1;

/// One tuning job as submitted over the wire: which task to tune, with
/// which framework, under what budget. The server rebuilds the exact
/// in-process tuning run from this — a depth-1 spec reproduces the
/// `arco compare` driver bit for bit on the same seed.
#[derive(Debug, Clone, PartialEq)]
pub struct JobSpec {
    /// Client identity: the ledger's quota account key (first half).
    pub client: String,
    /// Search framework to run (by wire name, see [`Framework::name`]).
    pub framework: Framework,
    /// The conv2d task to tune.
    pub task: Conv2dTask,
    /// Total measurement budget (`TuneBudget::total_measurements`).
    pub trials: usize,
    /// Points per planning batch (`TuneBudget::batch`).
    pub batch: usize,
    /// In-flight measurement batches (`TuneBudget::pipeline_depth`);
    /// 1 = the serial, bit-reproducible loop.
    pub pipeline_depth: usize,
    /// Strategy RNG seed. (Tree-encoded via f64: exact below 2^53,
    /// which covers every seed the CLI derives.)
    pub seed: u64,
    /// Quick-mode strategy parameters (smaller models, CI-sized runs).
    pub quick: bool,
    /// Evaluation fidelity (`TuneBudget::fidelity`), wire-encoded via
    /// [`Fidelity::describe`]. Additive: omitted on the wire for the
    /// default `exact`, and absence reads as `exact`.
    pub fidelity: Fidelity,
}

impl JobSpec {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("client", Json::str(self.client.clone())),
            ("framework", Json::str(self.framework.name())),
            ("task", self.task.to_json()),
            ("trials", Json::num(self.trials as f64)),
            ("batch", Json::num(self.batch as f64)),
            ("pipeline_depth", Json::num(self.pipeline_depth as f64)),
            ("seed", Json::num(self.seed as f64)),
            ("quick", Json::Bool(self.quick)),
        ];
        if self.fidelity != Fidelity::Exact {
            fields.push(("fidelity", Json::str(self.fidelity.describe())));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Option<JobSpec> {
        Some(JobSpec {
            client: v.get_str("client")?.to_string(),
            framework: Framework::from_name(v.get_str("framework")?)?,
            task: Conv2dTask::from_json(v.get("task")?)?,
            trials: v.get_usize("trials")?,
            // Additive fields: absent reads as the CLI defaults.
            batch: v.get_usize("batch").unwrap_or(64),
            pipeline_depth: v.get_usize("pipeline_depth").unwrap_or(1),
            seed: v.get_f64("seed").unwrap_or(0.0) as u64,
            quick: v.get_bool("quick").unwrap_or(false),
            fidelity: v.get_str("fidelity").and_then(Fidelity::parse).unwrap_or_default(),
        })
    }
}

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobState {
    /// Accepted and waiting for a runner slot.
    Queued,
    /// A runner thread is tuning it now.
    Running,
    /// Finished; the outcome rides the final results page.
    Done,
    /// The tuning loop failed (e.g. whole-fleet loss); see `error`.
    Failed,
    /// Cancelled by the client; partial results remain queryable.
    Cancelled,
}

impl JobState {
    pub fn name(self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    pub fn from_name(s: &str) -> Option<JobState> {
        Some(match s {
            "queued" => JobState::Queued,
            "running" => JobState::Running,
            "done" => JobState::Done,
            "failed" => JobState::Failed,
            "cancelled" => JobState::Cancelled,
            _ => return None,
        })
    }

    /// Terminal states never change again — a client can stop polling.
    pub fn is_terminal(self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// Point-in-time view of one job (the `status` reply).
#[derive(Debug, Clone, PartialEq)]
pub struct JobStatus {
    pub id: u64,
    pub client: String,
    /// Framework wire name.
    pub framework: String,
    /// `Conv2dTask::short_id()` — the ledger's quota account key
    /// (second half).
    pub task_id: String,
    pub state: JobState,
    /// Points measured (observed) so far.
    pub measured: usize,
    /// Points charged against the client's quota so far.
    pub charged: usize,
    /// Running best (0 until something valid lands).
    pub best_gflops: f64,
    /// Seconds from submit to the first trace entry (None until then) —
    /// the latency the soak test bounds.
    pub first_result_secs: Option<f64>,
    /// Failure cause, for [`JobState::Failed`].
    pub error: Option<String>,
}

impl JobStatus {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("id", Json::num(self.id as f64)),
            ("client", Json::str(self.client.clone())),
            ("framework", Json::str(self.framework.clone())),
            ("task_id", Json::str(self.task_id.clone())),
            ("state", Json::str(self.state.name())),
            ("measured", Json::num(self.measured as f64)),
            ("charged", Json::num(self.charged as f64)),
            ("best_gflops", Json::num(self.best_gflops)),
        ];
        if let Some(secs) = self.first_result_secs {
            fields.push(("first_result_secs", Json::num(secs)));
        }
        if let Some(e) = &self.error {
            fields.push(("error", Json::str(e.clone())));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Option<JobStatus> {
        Some(JobStatus {
            id: v.get_f64("id")? as u64,
            client: v.get_str("client")?.to_string(),
            framework: v.get_str("framework")?.to_string(),
            task_id: v.get_str("task_id")?.to_string(),
            state: JobState::from_name(v.get_str("state")?)?,
            measured: v.get_usize("measured").unwrap_or(0),
            charged: v.get_usize("charged").unwrap_or(0),
            best_gflops: v.get_f64("best_gflops").unwrap_or(0.0),
            first_result_secs: v.get_f64("first_result_secs"),
            error: v.get_str("error").map(str::to_string),
        })
    }
}

/// Final outcome of a finished job — the wire form of
/// [`crate::tuner::TaskTuneResult`] minus the full trace (which pages
/// separately).
#[derive(Debug, Clone, PartialEq)]
pub struct JobOutcome {
    /// Decoded knob values of the best point (None if nothing valid);
    /// map back with [`super::proto::point_from_values`].
    pub best_values: Option<Vec<usize>>,
    pub best: MeasureResult,
    pub measurements: usize,
    pub fresh: usize,
    pub cache_served: usize,
    pub invalid: usize,
    pub modeled_hw_secs: f64,
    pub wall_secs: f64,
    /// Candidates the screening stage answered analytically instead of
    /// measuring (0 in exact mode; additive on the wire).
    pub screened: usize,
}

impl JobOutcome {
    pub fn to_json(&self) -> Json {
        let mut fields = vec![("best", result_to_json(&self.best))];
        if let Some(values) = &self.best_values {
            fields.push(("best_values", values_to_json(values)));
        }
        fields.push(("measurements", Json::num(self.measurements as f64)));
        fields.push(("fresh", Json::num(self.fresh as f64)));
        fields.push(("cache_served", Json::num(self.cache_served as f64)));
        fields.push(("invalid", Json::num(self.invalid as f64)));
        fields.push(("modeled_hw_secs", Json::num(self.modeled_hw_secs)));
        fields.push(("wall_secs", Json::num(self.wall_secs)));
        if self.screened > 0 {
            fields.push(("screened", Json::num(self.screened as f64)));
        }
        Json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Option<JobOutcome> {
        Some(JobOutcome {
            best_values: v.get("best_values").and_then(values_from_json),
            best: result_from_json(v.get("best")?)?,
            measurements: v.get_usize("measurements").unwrap_or(0),
            fresh: v.get_usize("fresh").unwrap_or(0),
            cache_served: v.get_usize("cache_served").unwrap_or(0),
            invalid: v.get_usize("invalid").unwrap_or(0),
            modeled_hw_secs: v.get_f64("modeled_hw_secs").unwrap_or(0.0),
            wall_secs: v.get_f64("wall_secs").unwrap_or(0.0),
            screened: v.get_usize("screened").unwrap_or(0),
        })
    }
}

/// Tree encoding of one trace entry (pages also have a streaming twin,
/// [`write_trace_entry_stream`], byte-identical for finite values).
pub fn trace_to_json(e: &TraceEntry) -> Json {
    let mut fields = vec![
        ("ordinal", Json::num(e.ordinal as f64)),
        ("iteration", Json::num(e.iteration as f64)),
        ("at_secs", Json::num(e.at_secs)),
        ("gflops", Json::num(e.gflops)),
        ("best_gflops", Json::num(e.best_gflops)),
        ("valid", Json::Bool(e.valid)),
        ("modeled_cum_secs", Json::num(e.modeled_cum_secs)),
    ];
    // Additive: only screened entries carry the tag; absence reads as
    // the exact tier, so exact-mode frames are byte-identical to old ones.
    if e.fidelity == TraceFidelity::Screened {
        fields.push(("fidelity", Json::str("screen")));
    }
    Json::obj(fields)
}

pub fn trace_from_json(v: &Json) -> Option<TraceEntry> {
    Some(TraceEntry {
        ordinal: v.get_usize("ordinal")?,
        iteration: v.get_usize("iteration").unwrap_or(0),
        at_secs: v.get_f64("at_secs").unwrap_or(0.0),
        gflops: v.get_f64("gflops").unwrap_or(0.0),
        best_gflops: v.get_f64("best_gflops").unwrap_or(0.0),
        valid: v.get_bool("valid").unwrap_or(true),
        modeled_cum_secs: v.get_f64("modeled_cum_secs").unwrap_or(0.0),
        fidelity: match v.get_str("fidelity") {
            Some("screen") => TraceFidelity::Screened,
            _ => TraceFidelity::Exact,
        },
    })
}

/// One client → daemon message.
#[derive(Debug, Clone, PartialEq)]
pub enum TuneRequest {
    /// Handshake: protocol version + simulator fingerprint must match the
    /// daemon or the connection is refused (numbers from different
    /// simulators must never mix, exactly as on the measure wire).
    Hello { client: String, proto: u64, fingerprint: Fingerprint },
    /// Submit one tuning job; admission-controlled by the quota ledger.
    Submit(JobSpec),
    /// `job: Some(id)` — one job's status. `job: None` — page through
    /// the daemon's job table (keyset on job id via `cursor`).
    Status { job: Option<u64>, cursor: Option<String>, limit: usize },
    /// Page through one job's trace: `cursor` is the opaque resumption
    /// token from the previous page (None = from the start), `limit`
    /// caps entries per page so a 100k-point trace streams in bounded
    /// frames without the daemon buffering it per client.
    Results { job: u64, cursor: Option<String>, limit: usize },
    /// Request cooperative cancellation; partial results stay queryable.
    Cancel { job: u64 },
}

impl TuneRequest {
    pub fn to_json(&self) -> Json {
        match self {
            TuneRequest::Hello { client, proto, fingerprint } => Json::obj(vec![
                ("op", Json::str("hello")),
                ("client", Json::str(client.clone())),
                ("proto", Json::num(*proto as f64)),
                ("fingerprint", fingerprint.to_json()),
            ]),
            TuneRequest::Submit(spec) => {
                let mut v = spec.to_json();
                v.set("op", Json::str("submit"));
                v
            }
            TuneRequest::Status { job, cursor, limit } => {
                let mut fields = vec![("op", Json::str("status"))];
                if let Some(id) = job {
                    fields.push(("job", Json::num(*id as f64)));
                }
                if let Some(c) = cursor {
                    fields.push(("cursor", Json::str(c.clone())));
                }
                fields.push(("limit", Json::num(*limit as f64)));
                Json::obj(fields)
            }
            TuneRequest::Results { job, cursor, limit } => {
                let mut fields =
                    vec![("op", Json::str("results")), ("job", Json::num(*job as f64))];
                if let Some(c) = cursor {
                    fields.push(("cursor", Json::str(c.clone())));
                }
                fields.push(("limit", Json::num(*limit as f64)));
                Json::obj(fields)
            }
            TuneRequest::Cancel { job } => Json::obj(vec![
                ("op", Json::str("cancel")),
                ("job", Json::num(*job as f64)),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Option<TuneRequest> {
        match v.get_str("op")? {
            "hello" => Some(TuneRequest::Hello {
                client: v.get_str("client").unwrap_or("anonymous").to_string(),
                proto: v.get_f64("proto")? as u64,
                fingerprint: Fingerprint::from_json(v.get("fingerprint")?)?,
            }),
            "submit" => Some(TuneRequest::Submit(JobSpec::from_json(v)?)),
            "status" => Some(TuneRequest::Status {
                job: v.get_f64("job").map(|x| x as u64),
                cursor: v.get_str("cursor").map(str::to_string),
                limit: v.get_usize("limit").unwrap_or(DEFAULT_PAGE_LIMIT),
            }),
            "results" => Some(TuneRequest::Results {
                job: v.get_f64("job")? as u64,
                cursor: v.get_str("cursor").map(str::to_string),
                limit: v.get_usize("limit").unwrap_or(DEFAULT_PAGE_LIMIT),
            }),
            "cancel" => Some(TuneRequest::Cancel { job: v.get_f64("job")? as u64 }),
            _ => None,
        }
    }
}

/// Page size a peer gets when it does not ask for one.
pub const DEFAULT_PAGE_LIMIT: usize = 256;

/// One daemon → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum TuneResponse {
    /// Handshake accepted. `quota` is the per-(client, task) point
    /// allowance this daemon admits; `jobs` the jobs it currently holds.
    Hello { proto: u64, backend: String, fingerprint: Fingerprint, quota: usize, jobs: usize },
    /// Job accepted. `position` is its place in the run queue at submit
    /// time (0 = a runner picks it up next).
    Submitted { job: u64, position: usize },
    /// Single-job status.
    Status(Box<JobStatus>),
    /// One page of the job table (`status` with no `job`), keyset-ordered
    /// by id. An empty `jobs` page means the listing is exhausted.
    Jobs { jobs: Vec<JobStatus>, cursor: String },
    /// One page of a job's trace, in ordinal order. `cursor` resumes
    /// after the last entry of this page; an empty page + `done: false`
    /// means "caught up with a live job, poll again"; `done: true` means
    /// the job is terminal and `outcome` (on Done/Cancelled) is final.
    Page {
        job: u64,
        entries: Vec<TraceEntry>,
        cursor: String,
        done: bool,
        outcome: Option<JobOutcome>,
    },
    /// Cancellation acknowledged; `state` is the job's state afterwards
    /// (an already-finished job stays finished).
    Cancelled { job: u64, state: JobState },
    /// The request could not be served (`docs/WIRE.md` lists the shapes:
    /// `unintelligible request`, quota-exhausted, unknown-job, stale
    /// cursor, foreign fingerprint).
    Error(String),
}

impl TuneResponse {
    pub fn to_json(&self) -> Json {
        match self {
            TuneResponse::Hello { proto, backend, fingerprint, quota, jobs } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("proto", Json::num(*proto as f64)),
                ("backend", Json::str(backend.clone())),
                ("fingerprint", fingerprint.to_json()),
                ("quota", Json::num(*quota as f64)),
                ("jobs", Json::num(*jobs as f64)),
            ]),
            TuneResponse::Submitted { job, position } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("submitted", Json::num(*job as f64)),
                ("position", Json::num(*position as f64)),
            ]),
            TuneResponse::Status(status) => {
                Json::obj(vec![("ok", Json::Bool(true)), ("status", status.to_json())])
            }
            TuneResponse::Jobs { jobs, cursor } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("listing", Json::Arr(jobs.iter().map(JobStatus::to_json).collect())),
                ("cursor", Json::str(cursor.clone())),
            ]),
            TuneResponse::Page { job, entries, cursor, done, outcome } => {
                let mut fields = vec![
                    ("ok", Json::Bool(true)),
                    ("job", Json::num(*job as f64)),
                    ("entries", Json::Arr(entries.iter().map(trace_to_json).collect())),
                    ("cursor", Json::str(cursor.clone())),
                    ("done", Json::Bool(*done)),
                ];
                if let Some(o) = outcome {
                    fields.push(("outcome", o.to_json()));
                }
                Json::obj(fields)
            }
            TuneResponse::Cancelled { job, state } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("cancelled", Json::num(*job as f64)),
                ("state", Json::str(state.name())),
            ]),
            TuneResponse::Error(msg) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(msg.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Option<TuneResponse> {
        if !v.get_bool("ok")? {
            return Some(TuneResponse::Error(
                v.get_str("error").unwrap_or("unspecified").to_string(),
            ));
        }
        if let Some(entries) = v.get("entries") {
            let entries =
                entries.as_arr()?.iter().map(trace_from_json).collect::<Option<Vec<_>>>()?;
            return Some(TuneResponse::Page {
                job: v.get_f64("job")? as u64,
                entries,
                cursor: v.get_str("cursor")?.to_string(),
                done: v.get_bool("done").unwrap_or(false),
                outcome: v.get("outcome").and_then(JobOutcome::from_json),
            });
        }
        if let Some(listing) = v.get("listing") {
            let jobs =
                listing.as_arr()?.iter().map(JobStatus::from_json).collect::<Option<Vec<_>>>()?;
            return Some(TuneResponse::Jobs { jobs, cursor: v.get_str("cursor")?.to_string() });
        }
        if let Some(status) = v.get("status") {
            return Some(TuneResponse::Status(Box::new(JobStatus::from_json(status)?)));
        }
        if let Some(job) = v.get_f64("submitted") {
            return Some(TuneResponse::Submitted {
                job: job as u64,
                position: v.get_usize("position").unwrap_or(0),
            });
        }
        if let Some(job) = v.get_f64("cancelled") {
            return Some(TuneResponse::Cancelled {
                job: job as u64,
                state: JobState::from_name(v.get_str("state")?)?,
            });
        }
        if let Some(backend) = v.get_str("backend") {
            return Some(TuneResponse::Hello {
                proto: v.get_f64("proto")? as u64,
                backend: backend.to_string(),
                fingerprint: Fingerprint::from_json(v.get("fingerprint")?)?,
                quota: v.get_usize("quota").unwrap_or(usize::MAX),
                jobs: v.get_usize("jobs").unwrap_or(0),
            });
        }
        None
    }
}

/// Streaming twin of [`trace_to_json`], byte-identical for finite values.
fn write_trace_entry_stream<W: Write>(
    sw: &mut StreamWriter<W>,
    e: &TraceEntry,
) -> std::io::Result<()> {
    sw.begin_obj()?;
    sw.key("ordinal")?;
    sw.usize_val(e.ordinal)?;
    sw.key("iteration")?;
    sw.usize_val(e.iteration)?;
    sw.key("at_secs")?;
    sw.f64_val(e.at_secs)?;
    sw.key("gflops")?;
    sw.f64_val(e.gflops)?;
    sw.key("best_gflops")?;
    sw.f64_val(e.best_gflops)?;
    sw.key("valid")?;
    sw.bool_val(e.valid)?;
    sw.key("modeled_cum_secs")?;
    sw.f64_val(e.modeled_cum_secs)?;
    if e.fidelity == TraceFidelity::Screened {
        sw.key("fidelity")?;
        sw.str_val("screen")?;
    }
    sw.end_obj()
}

/// Serialize a request as one frame. Requests are small and rare (one
/// per page, not per point) — the tree writer is fine for all of them.
pub fn write_tune_request_frame<W: Write>(
    w: &mut W,
    req: &TuneRequest,
) -> std::io::Result<()> {
    write_frame(w, &req.to_json())
}

/// Decode one request line ([`super::proto::read_frame_line`] strips the
/// newline). `None` means not a tune request.
pub fn tune_request_from_line(line: &str) -> Option<TuneRequest> {
    TuneRequest::from_json(&Json::parse(line).ok()?)
}

/// Serialize a response as one frame straight into the socket writer.
/// The hot `results` page (potentially thousands of trace entries per
/// frame) streams via the zero-copy writer and never builds a tree;
/// byte-identical to `write_frame(w, &resp.to_json())` for finite values.
pub fn write_tune_response_frame<W: Write>(
    w: &mut W,
    resp: &TuneResponse,
) -> std::io::Result<()> {
    match resp {
        TuneResponse::Page { job, entries, cursor, done, outcome } => {
            let mut sw = StreamWriter::new(&mut *w);
            sw.begin_obj()?;
            sw.key("ok")?;
            sw.bool_val(true)?;
            sw.key("job")?;
            sw.u64_val(*job)?;
            sw.key("entries")?;
            sw.begin_arr()?;
            for e in entries {
                write_trace_entry_stream(&mut sw, e)?;
            }
            sw.end_arr()?;
            sw.key("cursor")?;
            sw.str_val(cursor)?;
            sw.key("done")?;
            sw.bool_val(*done)?;
            if let Some(o) = outcome {
                sw.key("outcome")?;
                o.to_json().write_stream(&mut sw)?;
            }
            sw.end_obj()?;
            w.write_all(b"\n")?;
            w.flush()
        }
        _ => write_frame(w, &resp.to_json()),
    }
}

/// Zero-copy response decode: strict streaming fast path for the hot
/// trace page, tree fallback for every other frame (and any unusual
/// spelling). `None` means not a tune response either way.
pub fn tune_response_from_line(line: &str) -> Option<TuneResponse> {
    if let Some(resp) = page_response_from_line(line) {
        return Some(resp);
    }
    TuneResponse::from_json(&Json::parse(line).ok()?)
}

fn trace_entry_rest_from_stream(r: &mut Reader<'_>) -> Option<TraceEntry> {
    let mut ordinal: Option<usize> = None;
    let mut iteration = 0usize;
    let mut at_secs = 0.0f64;
    let mut gflops = 0.0f64;
    let mut best_gflops = 0.0f64;
    let mut valid = true;
    let mut modeled_cum_secs = 0.0f64;
    let mut fidelity = TraceFidelity::Exact;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "ordinal" => match r.next_token()? {
                    Token::Num(n) => ordinal = n.as_usize(),
                    _ => return None,
                },
                "iteration" => match r.next_token()? {
                    Token::Num(n) => iteration = n.as_usize()?,
                    _ => return None,
                },
                "at_secs" => match r.next_token()? {
                    Token::Num(n) => at_secs = n.as_f64(),
                    _ => return None,
                },
                "gflops" => match r.next_token()? {
                    Token::Num(n) => gflops = n.as_f64(),
                    _ => return None,
                },
                "best_gflops" => match r.next_token()? {
                    Token::Num(n) => best_gflops = n.as_f64(),
                    _ => return None,
                },
                "valid" => match r.next_token()? {
                    Token::Bool(b) => valid = b,
                    _ => return None,
                },
                "modeled_cum_secs" => match r.next_token()? {
                    Token::Num(n) => modeled_cum_secs = n.as_f64(),
                    _ => return None,
                },
                "fidelity" => match r.next_token()? {
                    Token::Str(s) => {
                        fidelity = if s.as_ref() == "screen" {
                            TraceFidelity::Screened
                        } else {
                            TraceFidelity::Exact
                        }
                    }
                    _ => return None,
                },
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    Some(TraceEntry {
        ordinal: ordinal?,
        iteration,
        at_secs,
        gflops,
        best_gflops,
        valid,
        modeled_cum_secs,
        fidelity,
    })
}

fn page_response_from_line(line: &str) -> Option<TuneResponse> {
    let mut r = Reader::new(line);
    if !matches!(r.next_token()?, Token::ObjStart) {
        return None;
    }
    let mut ok: Option<bool> = None;
    let mut job: Option<u64> = None;
    let mut entries: Option<Vec<TraceEntry>> = None;
    let mut cursor: Option<String> = None;
    let mut done = false;
    let mut outcome: Option<JobOutcome> = None;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "ok" => match r.next_token()? {
                    Token::Bool(b) => ok = Some(b),
                    _ => return None,
                },
                "job" => match r.next_token()? {
                    Token::Num(n) => job = n.as_u64(),
                    _ => return None,
                },
                "entries" => {
                    if !matches!(r.next_token()?, Token::ArrStart) {
                        return None;
                    }
                    let mut es = Vec::new();
                    loop {
                        match r.next_token()? {
                            Token::ArrEnd => break,
                            Token::ObjStart => es.push(trace_entry_rest_from_stream(&mut r)?),
                            _ => return None,
                        }
                    }
                    entries = Some(es);
                }
                "cursor" => match r.next_token()? {
                    Token::Str(s) => cursor = Some(s.into_owned()),
                    _ => return None,
                },
                "done" => match r.next_token()? {
                    Token::Bool(b) => done = b,
                    _ => return None,
                },
                "outcome" => {
                    // The outcome rides at most one frame per job:
                    // materialize the subtree and reuse the tree decoder.
                    let v = Json::from_reader(&mut r).ok()?;
                    outcome = JobOutcome::from_json(&v);
                }
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    if !r.at_end() || !ok? {
        return None;
    }
    Some(TuneResponse::Page { job: job?, entries: entries?, cursor: cursor?, done, outcome })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::proto::read_frame_line;

    fn spec() -> JobSpec {
        JobSpec {
            client: "tester".to_string(),
            framework: Framework::Arco,
            task: Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1),
            trials: 96,
            batch: 16,
            pipeline_depth: 2,
            seed: 0x1234_5678,
            quick: true,
            fidelity: Fidelity::Screen { keep: 0.25, explore: 0.1 },
        }
    }

    fn entry(ordinal: usize) -> TraceEntry {
        TraceEntry {
            ordinal,
            iteration: ordinal / 4,
            at_secs: ordinal as f64 * 0.25,
            gflops: 1.5 * ordinal as f64,
            best_gflops: 2.0 * ordinal as f64,
            valid: ordinal % 3 != 0,
            modeled_cum_secs: 0.125 * ordinal as f64,
            // Mixed-tier pages exercise the conditional tag end to end.
            fidelity: if ordinal % 4 == 0 { TraceFidelity::Screened } else { TraceFidelity::Exact },
        }
    }

    fn round_trip_request(req: &TuneRequest) -> TuneRequest {
        let mut buf = Vec::new();
        write_tune_request_frame(&mut buf, req).unwrap();
        let mut r = std::io::BufReader::new(buf.as_slice());
        let line = read_frame_line(&mut r).unwrap().unwrap();
        tune_request_from_line(&line).unwrap()
    }

    fn round_trip_response(resp: &TuneResponse) -> TuneResponse {
        let mut buf = Vec::new();
        write_tune_response_frame(&mut buf, resp).unwrap();
        let mut r = std::io::BufReader::new(buf.as_slice());
        let line = read_frame_line(&mut r).unwrap().unwrap();
        tune_response_from_line(&line).unwrap()
    }

    #[test]
    fn requests_round_trip() {
        for req in [
            TuneRequest::Hello {
                client: "c0".to_string(),
                proto: TUNE_PROTO_VERSION,
                fingerprint: Fingerprint::current(),
            },
            TuneRequest::Submit(spec()),
            TuneRequest::Status { job: Some(7), cursor: None, limit: 32 },
            TuneRequest::Status { job: None, cursor: Some("c1.j.0.5.x".to_string()), limit: 8 },
            TuneRequest::Results { job: 3, cursor: Some("tok".to_string()), limit: 100 },
            TuneRequest::Cancel { job: 9 },
        ] {
            assert_eq!(round_trip_request(&req), req);
        }
    }

    #[test]
    fn responses_round_trip() {
        let status = JobStatus {
            id: 4,
            client: "c0".to_string(),
            framework: "arco".to_string(),
            task_id: "c32x28x28-32k3s1p1".to_string(),
            state: JobState::Running,
            measured: 48,
            charged: 64,
            best_gflops: 17.5,
            first_result_secs: Some(0.75),
            error: None,
        };
        let outcome = JobOutcome {
            best_values: Some(vec![4, 8, 1, 2]),
            best: MeasureResult {
                seconds: 0.001,
                cycles: 123_456,
                gflops: 21.0,
                area_mm2: 2.5,
                occupancy: 0.8,
                valid: true,
            },
            measurements: 96,
            fresh: 80,
            cache_served: 16,
            invalid: 3,
            modeled_hw_secs: 12.5,
            wall_secs: 2.25,
            screened: 24,
        };
        for resp in [
            TuneResponse::Hello {
                proto: TUNE_PROTO_VERSION,
                backend: "vta-sim".to_string(),
                fingerprint: Fingerprint::current(),
                quota: 1000,
                jobs: 3,
            },
            TuneResponse::Submitted { job: 11, position: 2 },
            TuneResponse::Status(Box::new(status.clone())),
            TuneResponse::Jobs {
                jobs: vec![
                    status.clone(),
                    JobStatus {
                        id: 5,
                        state: JobState::Failed,
                        error: Some("boom".to_string()),
                        ..status
                    },
                ],
                cursor: "tok".to_string(),
            },
            TuneResponse::Page {
                job: 4,
                entries: (1..=10).map(entry).collect(),
                cursor: "tok2".to_string(),
                done: true,
                outcome: Some(outcome),
            },
            TuneResponse::Page {
                job: 4,
                entries: Vec::new(),
                cursor: "tok3".to_string(),
                done: false,
                outcome: None,
            },
            TuneResponse::Cancelled { job: 4, state: JobState::Cancelled },
            TuneResponse::Error("quota exhausted".to_string()),
        ] {
            assert_eq!(round_trip_response(&resp), resp);
        }
    }

    #[test]
    fn page_streaming_encoding_matches_the_tree() {
        // The streaming fast path must stay byte-identical to the tree
        // writer — the compatibility contract that lets either end fall
        // back to the tree codec.
        let page = TuneResponse::Page {
            job: 7,
            entries: (1..=25).map(entry).collect(),
            cursor: "cur".to_string(),
            done: false,
            outcome: None,
        };
        let mut streamed = Vec::new();
        write_tune_response_frame(&mut streamed, &page).unwrap();
        let mut tree = page.to_json().dump();
        tree.push('\n');
        assert_eq!(String::from_utf8(streamed).unwrap(), tree);
    }

    #[test]
    fn additive_fields_read_as_defaults() {
        // A peer that omits optional fields (older writer) must decode
        // with safe defaults, per the additive-field rule.
        let line = r#"{"op":"submit","client":"c0","framework":"arco","task":{"n":1,"ci":32,"h":28,"w":28,"co":32,"kh":3,"kw":3,"stride":1,"pad":1},"trials":64}"#;
        match tune_request_from_line(line) {
            Some(TuneRequest::Submit(s)) => {
                assert_eq!(s.batch, 64);
                assert_eq!(s.pipeline_depth, 1);
                assert_eq!(s.seed, 0);
                assert!(!s.quick);
                assert_eq!(s.fidelity, Fidelity::Exact, "absent fidelity reads as exact");
            }
            other => panic!("expected submit, got {other:?}"),
        }
        // Unknown fields are skipped, not fatal (forward compatibility).
        let page = r#"{"ok":true,"job":1,"entries":[{"ordinal":1,"gflops":2.0,"future_field":[1,2]}],"cursor":"t","done":false,"novel":"ignored"}"#;
        match tune_response_from_line(page) {
            Some(TuneResponse::Page { entries, .. }) => {
                assert_eq!(entries.len(), 1);
                assert_eq!(entries[0].ordinal, 1);
                assert_eq!(entries[0].gflops, 2.0);
                assert!(entries[0].valid, "absent valid reads as true");
                assert_eq!(entries[0].fidelity, TraceFidelity::Exact, "absent tag = exact tier");
            }
            other => panic!("expected page, got {other:?}"),
        }
    }

    #[test]
    fn malformed_frames_are_rejected() {
        assert_eq!(tune_request_from_line("not json"), None);
        assert_eq!(tune_request_from_line(r#"{"op":"warp"}"#), None);
        assert_eq!(tune_response_from_line("{"), None);
        // ok:false always decodes as an error reply.
        match tune_response_from_line(r#"{"ok":false,"error":"unknown job 9"}"#) {
            Some(TuneResponse::Error(e)) => assert_eq!(e, "unknown job 9"),
            other => panic!("expected error, got {other:?}"),
        }
    }
}
