//! `arco serve-tune`: tuning-as-a-service over the JSONL wire.
//!
//! Where [`super::server`] exposes raw *measurement* to the network, this
//! daemon exposes whole *tuning jobs*: a client submits a
//! [`JobSpec`](super::tune_proto::JobSpec) (task + framework + budget +
//! seed), runner threads drive [`crate::tuner::tune_task_tenant`] against
//! the daemon's shared [`Engine`], and the client streams status, trace
//! pages and the final outcome back over the same connection. The pieces
//! PRs 3–5 built in-process become the service's control plane:
//!
//! - the [`BudgetLedger`] is **per-client quota/admission control** — every
//!   job is charged against its `(client, task)` account before each batch
//!   (charge-before-submit), and a submit against an exhausted account is
//!   refused at the door;
//! - the FIFO [`Dispatcher`] is the **fleet-wide fair scheduler** — every
//!   running job checks out one permit per in-flight batch, so dozens of
//!   concurrent jobs interleave batch-by-batch instead of any one
//!   monopolizing the fleet (slots are sized from the engine's concurrent
//!   batch capacity at startup);
//! - traces stream through **cursor pagination**
//!   ([`super::cursor`]) — the daemon holds one bounded
//!   [`PagedTrace`] per job and each client carries its own position in an
//!   opaque cursor, so a 100k-point trace is never buffered per client.
//!
//! Lifecycle mirrors `serve-measure`: [`spawn_tune`] binds and returns a
//! [`TuneServerHandle`]; `shutdown()` cancels live jobs, joins the accept
//! loop and runners, and flushes the engine journal.

use super::cursor::{Cursor, CursorKind, PagedTrace};
use super::engine::Engine;
use super::ledger::{BudgetLedger, Dispatcher, LedgerStats};
use super::proto::{read_frame_line, Fingerprint};
use super::sync::{lock_unpoisoned, wait_unpoisoned};
use super::tune_proto::{
    tune_request_from_line, write_tune_response_frame, JobOutcome, JobSpec, JobState, JobStatus,
    TuneRequest, TuneResponse, TUNE_PROTO_VERSION,
};
use super::cache::PointKey;
use crate::space::ConfigSpace;
use crate::tuner::{tune_task_tenant, TenantContext, TraceEntry, TuneBudget, TuneObserver};
use std::collections::{BTreeMap, VecDeque};
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

/// Daemon behaviour knobs beyond the engine's own configuration.
#[derive(Debug, Clone, Copy)]
pub struct TuneServeOptions {
    /// Measurement points each `(client, task)` account may admit over the
    /// daemon's lifetime (`--quota`). `usize::MAX` = unmetered.
    pub quota: usize,
    /// Concurrent job-runner threads (`--jobs`): how many tuning loops run
    /// at once. Queued jobs beyond this wait FIFO.
    pub runners: usize,
    /// Trace entries retained per job (`--trace-cap`); `0` = unbounded.
    /// A bounded window keeps a long-lived daemon's memory flat; clients
    /// that fall further behind than the window see a stale-cursor error
    /// and must restart their stream.
    pub trace_cap: usize,
}

impl Default for TuneServeOptions {
    fn default() -> Self {
        TuneServeOptions { quota: usize::MAX, runners: 2, trace_cap: 0 }
    }
}

/// Mutable half of one job, behind its lock.
struct JobInner {
    state: JobState,
    trace: PagedTrace<TraceEntry>,
    outcome: Option<JobOutcome>,
    error: Option<String>,
    measured: usize,
    best_gflops: f64,
    /// Submit → first trace entry (the latency the soak test bounds).
    first_result_secs: Option<f64>,
}

/// One submitted job: immutable spec + supervised mutable progress.
struct JobRecord {
    id: u64,
    spec: JobSpec,
    /// `spec.task.short_id()` — the ledger account's second key.
    task_id: String,
    submitted: Instant,
    cancel: AtomicBool,
    inner: Mutex<JobInner>,
}

impl JobRecord {
    fn status(&self, ledger: &BudgetLedger) -> JobStatus {
        let inner = lock_unpoisoned(&self.inner);
        JobStatus {
            id: self.id,
            client: self.spec.client.clone(),
            framework: self.spec.framework.name().to_string(),
            task_id: self.task_id.clone(),
            state: inner.state,
            measured: inner.measured,
            charged: ledger.account(&self.spec.client, &self.task_id).charged,
            best_gflops: inner.best_gflops,
            first_result_secs: inner.first_result_secs,
            error: inner.error.clone(),
        }
    }
}

/// The tuning loop's live hooks, wired into the job record: every trace
/// entry lands in the job's paged window the moment it exists (in ordinal
/// order, so pagination keys are dense), and the cancel flag is polled
/// between batches.
struct JobObserver<'a> {
    job: &'a JobRecord,
}

impl TuneObserver for JobObserver<'_> {
    fn on_trace(&self, entry: &TraceEntry) {
        let mut inner = lock_unpoisoned(&self.job.inner);
        if inner.first_result_secs.is_none() {
            inner.first_result_secs = Some(self.job.submitted.elapsed().as_secs_f64());
        }
        inner.measured = entry.ordinal;
        inner.best_gflops = entry.best_gflops;
        inner.trace.push(entry.clone());
    }

    fn cancelled(&self) -> bool {
        self.job.cancel.load(Ordering::Relaxed)
    }
}

/// Everything connection threads and runner threads share.
struct TuneShared {
    engine: Arc<Engine>,
    /// Per-(client, task) quota — admission control at submit, then
    /// charge-before-submit inside the tuning loop.
    ledger: BudgetLedger,
    /// Fleet-wide FIFO fair scheduler across all running jobs.
    dispatcher: Dispatcher,
    /// Every job ever submitted, by id (keyset pagination's index).
    jobs: Mutex<BTreeMap<u64, Arc<JobRecord>>>,
    /// Jobs waiting for a runner, FIFO.
    queue: Mutex<VecDeque<Arc<JobRecord>>>,
    ready: Condvar,
    next_job: AtomicU64,
    stop: AtomicBool,
    opts: TuneServeOptions,
}

/// A running tuning daemon.
pub struct TuneServerHandle {
    addr: std::net::SocketAddr,
    shared: Arc<TuneShared>,
    accept: Option<JoinHandle<()>>,
    runners: Vec<JoinHandle<()>>,
}

impl TuneServerHandle {
    /// The actually-bound address (resolves `:0` to the chosen port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The engine every job measures through.
    pub fn engine(&self) -> &Arc<Engine> {
        &self.shared.engine
    }

    /// Snapshot of the quota ledger — per-(client, task) charged/settled
    /// accounts (the soak test's conservation oracle).
    pub fn ledger_stats(&self) -> LedgerStats {
        self.shared.ledger.stats()
    }

    /// Status of every job the daemon holds, in id order.
    pub fn job_statuses(&self) -> Vec<JobStatus> {
        let jobs = lock_unpoisoned(&self.shared.jobs);
        jobs.values().map(|j| j.status(&self.shared.ledger)).collect()
    }

    /// Block until the accept loop exits (the CLI's serve-forever mode).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, cancel live jobs, join every thread, flush the
    /// engine journal. Queued jobs end Cancelled; running jobs drain
    /// their in-flight batches and keep their partial results.
    pub fn shutdown(mut self) {
        self.shared.stop.store(true, Ordering::SeqCst);
        {
            let jobs = lock_unpoisoned(&self.shared.jobs);
            for job in jobs.values() {
                job.cancel.store(true, Ordering::Relaxed);
            }
        }
        self.shared.ready.notify_all();
        // Nudge the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        for h in std::mem::take(&mut self.runners) {
            let _ = h.join();
        }
        self.shared.engine.flush_journal();
    }
}

/// Bind `addr` and serve tuning jobs over `engine` until shut down.
pub fn spawn_tune(
    addr: &str,
    engine: Arc<Engine>,
    opts: TuneServeOptions,
) -> anyhow::Result<TuneServerHandle> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("binding tune server to {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    let shared = Arc::new(TuneShared {
        dispatcher: Dispatcher::new(engine.concurrent_batch_capacity()),
        engine,
        ledger: BudgetLedger::new(opts.quota),
        jobs: Mutex::new(BTreeMap::new()),
        queue: Mutex::new(VecDeque::new()),
        ready: Condvar::new(),
        next_job: AtomicU64::new(0),
        stop: AtomicBool::new(false),
        opts,
    });
    let runners = (0..opts.runners.max(1))
        .map(|_| {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || runner_loop(&shared))
        })
        .collect();
    let accept = {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || accept_loop(listener, shared))
    };
    Ok(TuneServerHandle { addr: bound, shared, accept: Some(accept), runners })
}

/// [`spawn_tune`] on a loopback port picked by the OS (tests, embedding).
pub fn spawn_tune_local(
    engine: Arc<Engine>,
    opts: TuneServeOptions,
) -> anyhow::Result<TuneServerHandle> {
    spawn_tune("127.0.0.1:0", engine, opts)
}

fn accept_loop(listener: TcpListener, shared: Arc<TuneShared>) {
    for conn in listener.incoming() {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let shared = Arc::clone(&shared);
                std::thread::spawn(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".to_string());
                    if let Err(e) = serve_connection(stream, &shared) {
                        crate::log_debug!("eval", "tune connection {peer} ended: {e}");
                    }
                });
            }
            Err(e) => crate::log_warn!("eval", "tune accept failed: {e}"),
        }
    }
}

/// One request → one response per line until the client hangs up.
fn serve_connection(stream: TcpStream, shared: &TuneShared) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let Some(line) = read_frame_line(&mut reader)? else {
            return Ok(());
        };
        // A frame that is not a tune request gets a structured Error reply
        // (the client sees *why* instead of a dropped connection), exactly
        // like the measure wire.
        let response = match tune_request_from_line(&line) {
            Some(req) => handle(shared, req),
            None => TuneResponse::Error("unintelligible request".to_string()),
        };
        write_tune_response_frame(&mut writer, &response)?;
    }
}

fn handle(shared: &TuneShared, req: TuneRequest) -> TuneResponse {
    match req {
        TuneRequest::Hello { client, proto, fingerprint } => {
            if proto != TUNE_PROTO_VERSION {
                return TuneResponse::Error(format!(
                    "client {client} speaks tune-protocol v{proto}, this daemon v{TUNE_PROTO_VERSION}"
                ));
            }
            let local = Fingerprint::current();
            if fingerprint != local {
                // Same refusal rule as the measure wire: results from
                // different simulators must never mix.
                return TuneResponse::Error(format!(
                    "foreign fingerprint: client {} vs daemon {}",
                    fingerprint.describe(),
                    local.describe()
                ));
            }
            TuneResponse::Hello {
                proto: TUNE_PROTO_VERSION,
                backend: shared.engine.backend_name().to_string(),
                fingerprint: local,
                quota: shared.opts.quota,
                jobs: lock_unpoisoned(&shared.jobs).len(),
            }
        }
        TuneRequest::Submit(spec) => submit(shared, spec),
        TuneRequest::Status { job: Some(id), .. } => match lookup(shared, id) {
            Some(job) => TuneResponse::Status(Box::new(job.status(&shared.ledger))),
            None => TuneResponse::Error(format!("unknown job {id}")),
        },
        TuneRequest::Status { job: None, cursor, limit } => list_jobs(shared, cursor, limit),
        TuneRequest::Results { job: id, cursor, limit } => match lookup(shared, id) {
            Some(job) => trace_page(shared, &job, cursor, limit),
            None => TuneResponse::Error(format!("unknown job {id}")),
        },
        TuneRequest::Cancel { job: id } => match lookup(shared, id) {
            Some(job) => {
                job.cancel.store(true, Ordering::Relaxed);
                let mut inner = lock_unpoisoned(&job.inner);
                // A job still waiting for a runner dies right here; the
                // runner that eventually pops it will skip it. Running
                // jobs stop cooperatively at their next batch boundary;
                // finished jobs stay finished.
                if inner.state == JobState::Queued {
                    inner.state = JobState::Cancelled;
                }
                TuneResponse::Cancelled { job: id, state: inner.state }
            }
            None => TuneResponse::Error(format!("unknown job {id}")),
        },
    }
}

fn lookup(shared: &TuneShared, id: u64) -> Option<Arc<JobRecord>> {
    lock_unpoisoned(&shared.jobs).get(&id).cloned()
}

fn submit(shared: &TuneShared, spec: JobSpec) -> TuneResponse {
    let task_id = spec.task.short_id();
    // Admission control at the door: a client whose (client, task) quota
    // account is already spent gets a refusal, not a job that would sit
    // at measured=0 forever. The tuning loop's own charge-before-submit
    // enforces the cap batch-by-batch after admission.
    if shared.ledger.remaining(&spec.client, &task_id) == 0 {
        return TuneResponse::Error(format!(
            "quota exhausted: client {} has spent its {} points for task {task_id}",
            spec.client, shared.opts.quota
        ));
    }
    let id = shared.next_job.fetch_add(1, Ordering::SeqCst) + 1;
    let job = Arc::new(JobRecord {
        id,
        task_id,
        submitted: Instant::now(),
        cancel: AtomicBool::new(false),
        inner: Mutex::new(JobInner {
            state: JobState::Queued,
            trace: PagedTrace::new(shared.opts.trace_cap),
            outcome: None,
            error: None,
            measured: 0,
            best_gflops: 0.0,
            first_result_secs: None,
        }),
        spec,
    });
    lock_unpoisoned(&shared.jobs).insert(id, Arc::clone(&job));
    let position = {
        let mut queue = lock_unpoisoned(&shared.queue);
        queue.push_back(job);
        queue.len() - 1
    };
    shared.ready.notify_all();
    TuneResponse::Submitted { job: id, position }
}

/// Keyset page over the job table: ids strictly greater than the cursor's
/// `last`, in order. Stable under concurrent submits — new jobs get
/// higher ids and land in later pages.
fn list_jobs(shared: &TuneShared, cursor: Option<String>, limit: usize) -> TuneResponse {
    let after = match cursor {
        None => 0,
        Some(token) => match Cursor::decode(&token) {
            Some(c) if c.kind == CursorKind::Jobs => c.last,
            _ => return TuneResponse::Error("unintelligible cursor".to_string()),
        },
    };
    let jobs_map = lock_unpoisoned(&shared.jobs);
    let jobs: Vec<JobStatus> = jobs_map
        .range(after.saturating_add(1)..)
        .take(limit.max(1))
        .map(|(_, j)| j.status(&shared.ledger))
        .collect();
    let last = jobs.last().map_or(after, |s| s.id);
    drop(jobs_map);
    TuneResponse::Jobs { jobs, cursor: Cursor { kind: CursorKind::Jobs, job: 0, last }.encode() }
}

/// One page of a job's trace. The cursor is the client's own position —
/// the daemon holds no per-client state, so any number of clients can
/// stream the same 100k-point trace concurrently at their own pace.
fn trace_page(
    shared: &TuneShared,
    job: &JobRecord,
    cursor: Option<String>,
    limit: usize,
) -> TuneResponse {
    let after = match cursor {
        None => 0,
        Some(token) => match Cursor::decode(&token) {
            Some(c) if c.kind == CursorKind::Trace && c.job == job.id => c.last,
            _ => return TuneResponse::Error("unintelligible cursor".to_string()),
        },
    };
    let inner = lock_unpoisoned(&job.inner);
    let entries = match inner.trace.page(after, limit.max(1)) {
        Ok(page) => page,
        Err(stale) => return TuneResponse::Error(stale.to_string()),
    };
    let last = entries.last().map_or(after, |(key, _)| *key);
    // `done` only once the client has drained a *terminal* job's full
    // trace: a live job's empty page means "caught up, poll again".
    let done = inner.state.is_terminal() && last == inner.trace.total();
    let outcome = if done { inner.outcome.clone() } else { None };
    TuneResponse::Page {
        job: job.id,
        entries: entries.into_iter().map(|(_, e)| e).collect(),
        cursor: Cursor { kind: CursorKind::Trace, job: job.id, last }.encode(),
        done,
        outcome,
    }
}

fn runner_loop(shared: &TuneShared) {
    loop {
        let job = {
            let mut queue = lock_unpoisoned(&shared.queue);
            loop {
                if shared.stop.load(Ordering::SeqCst) {
                    return;
                }
                if let Some(job) = queue.pop_front() {
                    break job;
                }
                queue = wait_unpoisoned(&shared.ready, queue);
            }
        };
        {
            let mut inner = lock_unpoisoned(&job.inner);
            if inner.state != JobState::Queued {
                // Cancelled while waiting for a runner.
                continue;
            }
            inner.state = JobState::Running;
        }
        run_one(shared, &job);
    }
}

/// Drive one job through the same code path as the in-process `arco
/// compare` driver: identical space construction, strategy build and
/// tenant loop, so a depth-1 job on the same seed is bit-identical to a
/// local run.
fn run_one(shared: &TuneShared, job: &JobRecord) {
    let spec = &job.spec;
    let space = ConfigSpace::for_task(&spec.task, spec.framework.tunes_hardware());
    let mut strategy = spec.framework.build(space.clone(), spec.quick, spec.seed);
    let budget = TuneBudget {
        total_measurements: spec.trials,
        batch: spec.batch,
        pipeline_depth: spec.pipeline_depth,
        fidelity: spec.fidelity,
        ..Default::default()
    };
    let observer = JobObserver { job };
    let tenant = TenantContext {
        ledger: Some(&shared.ledger),
        dispatcher: &shared.dispatcher,
        framework: &spec.client,
        task_id: &job.task_id,
        observer: Some(&observer),
    };
    let result = tune_task_tenant(&shared.engine, &space, strategy.as_mut(), budget, Some(&tenant));
    let mut inner = lock_unpoisoned(&job.inner);
    match result {
        Ok(r) => {
            inner.measured = r.measurements;
            inner.best_gflops = r.best.gflops;
            inner.outcome = Some(JobOutcome {
                best_values: r.best_point.as_ref().map(|p| PointKey::of(&space, p).values),
                best: r.best,
                measurements: r.measurements,
                fresh: r.fresh,
                cache_served: r.cache_served,
                invalid: r.invalid,
                modeled_hw_secs: r.modeled_hw_secs,
                wall_secs: r.wall_secs,
                screened: r.screened,
            });
            inner.state = if job.cancel.load(Ordering::Relaxed) {
                JobState::Cancelled
            } else {
                JobState::Done
            };
        }
        Err(e) => {
            // A lost fleet fails the job, not the daemon: the error text
            // is queryable via `status`, the partial trace stays paged,
            // and charged-but-unsettled points stay visible on the ledger
            // (honest accounting — nobody got numbers for them).
            inner.error = Some(format!("{e:#}"));
            inner.state = JobState::Failed;
        }
    }
    crate::log_info!(
        "eval",
        "tune job {} ({} {} for {}): {} after {} measurements",
        job.id,
        spec.framework.name(),
        job.task_id,
        spec.client,
        inner.state.name(),
        inner.measured
    );
}
