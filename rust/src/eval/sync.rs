//! Poison-tolerant locking for the daemon and wire modules.
//!
//! The serve daemons must not die because one worker thread panicked
//! while holding a lock: the state those locks guard (job tables, trace
//! windows, liveness maps) is plain data that is consistent at every
//! point a guard can be dropped, so recovering the guard is always
//! sound here. Routing every daemon-path lock through these helpers
//! keeps `unwrap()` out of connection handlers — `arco devcheck`
//! rule `panic-free` designates these functions as the only place the
//! daemon/wire modules may touch lock poisoning.

use std::sync::{Condvar, Mutex, MutexGuard, PoisonError};

/// Lock `m`, recovering the data if a previous holder panicked.
pub(crate) fn lock_unpoisoned<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Wait on `cv`, recovering the guard if a peer panicked mid-hold.
pub(crate) fn wait_unpoisoned<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(PoisonError::into_inner)
}

/// Abort the calling thread with `e` — the one sanctioned escape hatch
/// for *deliberately infallible facades*: trait methods with no error
/// channel (e.g. [`super::backend::MeasureBackend::measure_many`])
/// whose fallible implementation hit an unrecoverable error. Keeping the
/// panic here, next to the lock-poisoning recovery it forces callers to
/// survive, is what lets `arco devcheck` ban ad-hoc `panic!` everywhere
/// else in the daemon modules.
pub(crate) fn raise(e: anyhow::Error) -> ! {
    panic!("{e}")
}
