//! Fleet-wide shared measurement store: "measure once, *ever*".
//!
//! The engine's cache dedups within a process and the journal replays
//! history into one engine — but concurrent tenants in *different
//! processes* still re-measure identical points. The store is the tier
//! above both: a directory of fingerprinted journal *segments* shared by
//! every shard pointed at it (`serve-measure --store <dir>`). Any shard
//! answers any point any other shard ever measured, and store-served
//! answers ride the `fresh=false` wire path so client budget accounting
//! stays honest.
//!
//! Layout — `<dir>/seg-NNNNNN.jsonl`, each segment a standard v2
//! [`Journal`] file (same header, same fingerprint refusals, same
//! `<path>.lock` single-writer sentinel):
//!
//! - **One writer per segment.** Each process claims its own segment by
//!   taking the first unlocked, non-full segment (or creating the next
//!   index). Concurrent shards therefore never interleave records within
//!   a segment; readers see other shards' work by tailing their segments.
//! - **Rotation.** When the active segment reaches the configured size
//!   threshold it is closed, compacted in place ([`compact_journal`] —
//!   duplicates and torn lines dropped), the store is pruned to its byte
//!   budget, and the next segment index is claimed.
//! - **Pruning.** Oldest (lowest-index) segments are deleted until the
//!   directory fits the byte budget. The newest segment and any segment
//!   held by a live writer are never deleted — pruning bounds disk, it
//!   must not rip a file out from under a writing shard.
//! - **Fingerprint.** A segment stamped by a different simulator is
//!   refused at open exactly like a journal would be; the numbers of two
//!   cycle models never mix.
//!
//! Reads are incremental: the store remembers how many bytes of each
//! segment it has consumed and tails only the new complete lines on a
//! lookup miss, so cross-process visibility costs O(new records), not
//! O(store).

use super::cache::PointKey;
use super::journal::{self, compact_journal, HeaderCheck, Journal};
use super::proto::record_from_line;
use crate::codegen::MeasureResult;
use std::collections::{HashMap, HashSet};
use std::io::{BufRead, Seek, SeekFrom};
use std::path::{Path, PathBuf};

/// Where the store lives and when it rotates and prunes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StoreConfig {
    /// Directory holding the segments (created if missing).
    pub dir: PathBuf,
    /// Rotation threshold: a flushed active segment at or above this many
    /// bytes is closed, compacted, and succeeded by a fresh segment.
    pub segment_bytes: u64,
    /// Byte budget for the whole directory; rotation prunes oldest
    /// segments down to it (`arco store prune` does the same on demand).
    pub budget_bytes: u64,
}

impl StoreConfig {
    /// Default rotation threshold (8 MiB per segment).
    pub const DEFAULT_SEGMENT_BYTES: u64 = 8 * 1024 * 1024;
    /// Default directory byte budget (256 MiB).
    pub const DEFAULT_BUDGET_BYTES: u64 = 256 * 1024 * 1024;

    pub fn new(dir: PathBuf) -> StoreConfig {
        StoreConfig {
            dir,
            segment_bytes: Self::DEFAULT_SEGMENT_BYTES,
            budget_bytes: Self::DEFAULT_BUDGET_BYTES,
        }
    }
}

/// Read-only shape of a store directory (`arco store stat`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StoreStats {
    /// Segment files present.
    pub segments: usize,
    /// Total bytes across the segments.
    pub bytes: u64,
    /// Distinct `(backend, task, decoded knob values)` identities.
    pub identities: usize,
    /// Segments currently held by a live writer.
    pub locked: usize,
}

/// Outcome of a [`prune_store`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PruneStats {
    /// Segments present before pruning.
    pub segments_before: usize,
    /// Segments deleted.
    pub deleted: usize,
    /// Directory bytes before pruning.
    pub bytes_before: u64,
    /// Directory bytes after pruning.
    pub bytes_after: u64,
    /// Over-budget segments kept because a live writer holds them.
    pub locked_kept: usize,
}

/// `<dir>/seg-NNNNNN.jsonl`.
fn segment_path(dir: &Path, idx: usize) -> PathBuf {
    dir.join(format!("seg-{idx:06}.jsonl"))
}

/// Parse a segment index out of a file name, `None` for foreign files.
fn segment_index(name: &str) -> Option<usize> {
    name.strip_prefix("seg-")?.strip_suffix(".jsonl")?.parse().ok()
}

/// Segment files under `dir`, sorted oldest (lowest index) first. Files
/// that do not match the segment naming scheme are ignored — the store
/// only manages what it created.
fn list_segments(dir: &Path) -> anyhow::Result<Vec<(usize, PathBuf)>> {
    let mut out: Vec<(usize, PathBuf)> = Vec::new();
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(out),
        Err(e) => anyhow::bail!("store {}: cannot list segments: {e}", dir.display()),
    };
    for entry in entries {
        let entry = entry.map_err(|e| {
            anyhow::anyhow!("store {}: cannot list segments: {e}", dir.display())
        })?;
        let name = entry.file_name();
        if let Some(idx) = name.to_str().and_then(segment_index) {
            out.push((idx, entry.path()));
        }
    }
    out.sort();
    Ok(out)
}

/// Is the segment's `.lock` sentinel held by a live (or unverifiable)
/// writer? A sentinel whose recorded pid is provably dead does not count.
fn segment_locked(path: &Path) -> bool {
    let lock = journal::sibling(path, ".lock");
    if !lock.exists() {
        return false;
    }
    let holder =
        std::fs::read_to_string(&lock).map(|s| s.trim().to_string()).unwrap_or_default();
    !journal::holder_is_dead(&holder)
}

/// The refusal wrapper every per-segment error goes through, so operators
/// can grep one prefix for any store trouble.
fn refuse_segment(dir: &Path, seg: &Path, e: &anyhow::Error) -> anyhow::Error {
    anyhow::anyhow!("store {}: segment {} refused: {e}", dir.display(), seg.display())
}

/// One process's handle on a shared store directory: an in-memory index
/// over every segment, plus this process's claimed writer segment.
pub struct MeasureStore {
    dir: PathBuf,
    segment_bytes: u64,
    budget_bytes: u64,
    /// Everything this process has read or written, across all segments.
    index: HashMap<(String, PointKey), MeasureResult>,
    /// Bytes of each segment already consumed, so a refresh tails only
    /// the new complete lines.
    offsets: HashMap<PathBuf, u64>,
    /// Segments found unreadable after open — warned once, then skipped.
    quarantined: HashSet<PathBuf>,
    /// The segment this process appends to. `None` after a failed claim:
    /// the store degrades to a read-only tier (lookups still work).
    active: Option<Journal>,
}

impl MeasureStore {
    /// Records buffered in the active segment before an automatic flush —
    /// bounds both memory and how stale other shards' view of us can be.
    const FLUSH_SLAB: usize = 512;

    /// Open (creating if necessary) the store at `config.dir`: strictly
    /// ingest every existing segment — a foreign-fingerprint or v1
    /// segment is refused exactly like opening it as a journal would —
    /// then claim a writer segment for this process.
    pub fn open(config: &StoreConfig) -> anyhow::Result<MeasureStore> {
        std::fs::create_dir_all(&config.dir).map_err(|e| {
            anyhow::anyhow!("store {}: cannot create directory: {e}", config.dir.display())
        })?;
        let mut store = MeasureStore {
            dir: config.dir.clone(),
            segment_bytes: config.segment_bytes.max(1),
            budget_bytes: config.budget_bytes.max(1),
            index: HashMap::new(),
            offsets: HashMap::new(),
            quarantined: HashSet::new(),
            active: None,
        };
        for (_, path) in list_segments(&config.dir)? {
            store.ingest_segment(&path)?;
        }
        store.claim_active()?;
        Ok(store)
    }

    /// The directory this store manages.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// The segment this process appends to (`None`: degraded read-only).
    pub fn active_segment(&self) -> Option<&Path> {
        self.active.as_ref().map(Journal::path)
    }

    /// Distinct identities currently visible to this process.
    pub fn identities(&self) -> usize {
        self.index.len()
    }

    fn get(&self, backend: &str, key: &PointKey) -> Option<MeasureResult> {
        self.index.get(&(backend.to_string(), key.clone())).copied()
    }

    /// Answer a batch from the store. Misses trigger one incremental
    /// refresh (tail every segment other shards are writing), so a point
    /// another process measured and flushed is visible here. Returns one
    /// slot per key, `None` where the store has never seen the point.
    pub fn lookup_many(&mut self, backend: &str, keys: &[PointKey]) -> Vec<Option<MeasureResult>> {
        let mut out: Vec<Option<MeasureResult>> =
            keys.iter().map(|k| self.get(backend, k)).collect();
        if out.iter().any(Option::is_none) && self.refresh() > 0 {
            for (slot, key) in out.iter_mut().zip(keys) {
                if slot.is_none() {
                    *slot = self.get(backend, key);
                }
            }
        }
        out
    }

    /// Add one measurement to the store (persisted at the next flush; the
    /// active segment auto-flushes every [`Self::FLUSH_SLAB`] records).
    /// Returns whether the identity was new to this process's view.
    pub fn record(&mut self, backend: &str, key: &PointKey, result: &MeasureResult) -> bool {
        let id = (backend.to_string(), key.clone());
        if self.index.contains_key(&id) {
            return false;
        }
        self.index.insert(id, *result);
        let pending = match self.active.as_mut() {
            Some(journal) => {
                journal.record(backend, key, result);
                journal.len()
            }
            None => return true, // degraded: remembered in memory only
        };
        if pending >= Self::FLUSH_SLAB {
            if let Err(e) = self.flush() {
                crate::log_warn!("eval", "store flush failed: {e}");
            }
        }
        true
    }

    /// Persist pending records and rotate the active segment if it has
    /// reached the size threshold (rotation compacts the closed segment
    /// and prunes the directory to its byte budget).
    pub fn flush(&mut self) -> anyhow::Result<()> {
        let Some(journal) = self.active.as_mut() else { return Ok(()) };
        journal.flush()?;
        let len = std::fs::metadata(journal.path()).map(|m| m.len()).unwrap_or(0);
        if len >= self.segment_bytes {
            self.rotate()?;
        }
        Ok(())
    }

    /// Close the active segment, compact it, prune the store to budget,
    /// and claim the next segment.
    fn rotate(&mut self) -> anyhow::Result<()> {
        let Some(journal) = self.active.take() else { return Ok(()) };
        let path = journal.path().to_path_buf();
        drop(journal); // release the writer lock before compacting
        if let Err(e) = compact_journal(&path) {
            crate::log_warn!("eval", "store rotation: compacting {} failed: {e}", path.display());
        }
        // Everything in the closed segment is already in our index; mark
        // it fully consumed so a refresh does not re-read our own work.
        if let Ok(meta) = std::fs::metadata(&path) {
            self.offsets.insert(path.clone(), meta.len());
        }
        match prune_store(&self.dir, self.budget_bytes) {
            Ok(stats) if stats.deleted > 0 => {
                crate::log_info!(
                    "eval",
                    "store {}: pruned {} segment(s), {} -> {} bytes (budget {})",
                    self.dir.display(),
                    stats.deleted,
                    stats.bytes_before,
                    stats.bytes_after,
                    self.budget_bytes
                );
            }
            Ok(_) => {}
            Err(e) => crate::log_warn!("eval", "{e}"),
        }
        self.claim_active()
    }

    /// Claim a writer segment: the first unlocked, non-full segment at or
    /// after the current highest index, else the next fresh index. The
    /// `.lock` create is atomic, so two processes racing for the same
    /// index get one winner; the loser moves to the next.
    fn claim_active(&mut self) -> anyhow::Result<()> {
        let mut idx = list_segments(&self.dir)?.last().map_or(0, |(i, _)| *i);
        loop {
            let path = segment_path(&self.dir, idx);
            let full =
                std::fs::metadata(&path).map(|m| m.len() >= self.segment_bytes).unwrap_or(false);
            if !full {
                let claimed = Journal::try_open_writer(&path)
                    .map_err(|e| refuse_segment(&self.dir, &path, &e))?;
                if let Some(journal) = claimed {
                    // A reclaimed segment (dead shard) may hold records we
                    // have not ingested yet.
                    for e in journal.entries() {
                        self.index
                            .entry((e.backend.clone(), e.key.clone()))
                            .or_insert(e.result);
                    }
                    self.active = Some(journal);
                    return Ok(());
                }
            }
            idx += 1;
        }
    }

    /// Tail every segment other processes are writing, adding new complete
    /// records to the index. Returns how many records were added.
    fn refresh(&mut self) -> usize {
        let segments = match list_segments(&self.dir) {
            Ok(s) => s,
            Err(_) => return 0,
        };
        let active = self.active.as_ref().map(|j| j.path().to_path_buf());
        let mut added = 0;
        for (_, path) in segments {
            if active.as_deref() == Some(path.as_path()) {
                continue; // our own writes are indexed at record time
            }
            match self.ingest_segment(&path) {
                Ok(n) => added += n,
                Err(e) => {
                    crate::log_warn!("eval", "{e}");
                    self.quarantined.insert(path);
                }
            }
        }
        added
    }

    /// Read the unconsumed tail of one segment into the index. Only
    /// complete (newline-terminated) lines are consumed: a line another
    /// process is mid-append stays unread until its newline lands. A
    /// refusal is an error either way; at open time it fails the store,
    /// at refresh time the caller quarantines the segment and keeps going.
    fn ingest_segment(&mut self, path: &Path) -> anyhow::Result<usize> {
        if self.quarantined.contains(path) {
            return Ok(0);
        }
        let start = self.offsets.get(path).copied().unwrap_or(0);
        let mut file = match std::fs::File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
                self.offsets.remove(path); // pruned by another process
                return Ok(0);
            }
            Err(e) => {
                return Err(refuse_segment(&self.dir, path, &anyhow::anyhow!("{e}")));
            }
        };
        if start > 0 {
            let len = file.metadata().map(|m| m.len()).unwrap_or(0);
            if len <= start {
                return Ok(0);
            }
            file.seek(SeekFrom::Start(start))
                .map_err(|e| refuse_segment(&self.dir, path, &anyhow::anyhow!("{e}")))?;
        }
        let mut reader = std::io::BufReader::new(file);
        let mut pos = start;
        let mut header_pending = start == 0;
        let mut added = 0;
        let mut buf: Vec<u8> = Vec::new();
        loop {
            buf.clear();
            let n = match reader.read_until(b'\n', &mut buf) {
                Ok(n) => n,
                Err(e) => {
                    return Err(refuse_segment(&self.dir, path, &anyhow::anyhow!("{e}")));
                }
            };
            if n == 0 || buf.last() != Some(&b'\n') {
                break; // EOF, or a line still being appended
            }
            pos += n as u64;
            let Ok(line) = std::str::from_utf8(&buf) else { continue };
            let line = line.trim_end_matches(['\n', '\r']);
            if header_pending {
                header_pending = false;
                match journal::check_header(path, line) {
                    Ok(HeaderCheck::Journal) => continue,
                    Ok(HeaderCheck::NotAJournal) => {
                        return Err(refuse_segment(
                            &self.dir,
                            path,
                            &anyhow::anyhow!("not a measurement journal"),
                        ));
                    }
                    Err(e) => return Err(refuse_segment(&self.dir, path, &e)),
                }
            }
            if line.trim().is_empty() {
                continue;
            }
            if let Some((backend, key, result)) = record_from_line(line) {
                if let std::collections::hash_map::Entry::Vacant(slot) =
                    self.index.entry((backend, key))
                {
                    slot.insert(result);
                    added += 1;
                }
            }
        }
        self.offsets.insert(path.to_path_buf(), pos);
        Ok(added)
    }
}

/// Read-only scan of a store directory: segment count, bytes, distinct
/// identities, live locks. Refuses foreign-fingerprint segments exactly
/// like opening them as journals would.
pub fn store_stat(dir: &Path) -> anyhow::Result<StoreStats> {
    let segments = list_segments(dir)?;
    if segments.is_empty() && !dir.is_dir() {
        anyhow::bail!("store {}: directory does not exist", dir.display());
    }
    let mut stats = StoreStats { segments: segments.len(), ..Default::default() };
    let mut seen: HashSet<(String, PointKey)> = HashSet::new();
    for (_, path) in &segments {
        stats.bytes += std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
        if segment_locked(path) {
            stats.locked += 1;
        }
        let journal =
            Journal::open_read_only(path).map_err(|e| refuse_segment(dir, path, &e))?;
        for e in journal.entries() {
            seen.insert((e.backend.clone(), e.key.clone()));
        }
    }
    stats.identities = seen.len();
    Ok(stats)
}

/// Delete oldest segments until the directory fits `budget_bytes`. The
/// newest segment is always kept (a store never prunes to nothing), as is
/// any segment held by a live writer — those are reported instead, and an
/// error is returned when they alone kept the store over budget. A
/// sentinel left by a verifiably dead writer is reclaimed and its segment
/// pruned like any other.
pub fn prune_store(dir: &Path, budget_bytes: u64) -> anyhow::Result<PruneStats> {
    let segments = list_segments(dir)?;
    if segments.is_empty() && !dir.is_dir() {
        anyhow::bail!("store {}: directory does not exist", dir.display());
    }
    let sizes: Vec<u64> = segments
        .iter()
        .map(|(_, p)| std::fs::metadata(p).map(|m| m.len()).unwrap_or(0))
        .collect();
    let mut stats = PruneStats {
        segments_before: segments.len(),
        bytes_before: sizes.iter().sum(),
        ..Default::default()
    };
    let mut remaining = stats.bytes_before;
    for (i, (_, path)) in segments.iter().enumerate() {
        if remaining <= budget_bytes || i + 1 == segments.len() {
            break; // under budget, or down to the newest segment
        }
        if segment_locked(path) {
            stats.locked_kept += 1;
            continue;
        }
        let _ = std::fs::remove_file(journal::sibling(path, ".lock"));
        std::fs::remove_file(path).map_err(|e| {
            anyhow::anyhow!("store {}: cannot delete segment {}: {e}", dir.display(), path.display())
        })?;
        remaining = remaining.saturating_sub(sizes[i]);
        stats.deleted += 1;
    }
    stats.bytes_after = remaining;
    if remaining > budget_bytes && stats.locked_kept > 0 {
        anyhow::bail!(
            "store {}: cannot prune below the byte budget: {} segment(s) locked by live \
             writers ({} bytes kept, budget {})",
            dir.display(),
            stats.locked_kept,
            remaining,
            budget_bytes
        );
    }
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::measure_point;
    use crate::eval::proto::Fingerprint;
    use crate::space::ConfigSpace;
    use crate::util::json::Json;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir =
            PathBuf::from("target/tmp").join(format!("store_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn cleanup(dir: &Path) {
        let _ = std::fs::remove_dir_all(dir);
    }

    /// `n` distinct measured points under a fixed seed.
    fn points(seed: u64, n: usize) -> Vec<(PointKey, MeasureResult)> {
        let s = space();
        let mut rng = Pcg32::seeded(seed);
        let mut out: Vec<(PointKey, MeasureResult)> = Vec::new();
        while out.len() < n {
            let p = s.random_point(&mut rng);
            let key = PointKey::of(&s, &p);
            if !out.iter().any(|(k, _)| *k == key) {
                let m = measure_point(&s, &p);
                out.push((key, m));
            }
        }
        out
    }

    fn small_config(dir: &Path) -> StoreConfig {
        StoreConfig { dir: dir.to_path_buf(), segment_bytes: 512, budget_bytes: 4096 }
    }

    #[test]
    fn roundtrips_across_instances_bit_identically() {
        let dir = tmp_dir("roundtrip");
        let pts = points(1, 6);
        let mut a = MeasureStore::open(&StoreConfig::new(dir.clone())).unwrap();
        for (k, m) in &pts {
            assert!(a.record("vta-sim", k, m));
            assert!(!a.record("vta-sim", k, m), "duplicate identity must be ignored");
        }
        a.flush().unwrap();
        drop(a);

        let mut b = MeasureStore::open(&StoreConfig::new(dir.clone())).unwrap();
        let keys: Vec<PointKey> = pts.iter().map(|(k, _)| k.clone()).collect();
        let hits = b.lookup_many("vta-sim", &keys);
        for (hit, (_, m)) in hits.iter().zip(&pts) {
            let got = hit.expect("measured point must be answered by a fresh instance");
            if m.valid {
                assert_eq!(&got, m, "store answers must be bit-identical");
            } else {
                assert!(!got.valid);
            }
        }
        // A different backend is a different identity.
        assert!(b.lookup_many("analytical", &keys[..1]).iter().all(Option::is_none));
        cleanup(&dir);
    }

    #[test]
    fn rotation_threshold_is_honored() {
        let dir = tmp_dir("rotate");
        let mut s = MeasureStore::open(&small_config(&dir)).unwrap();
        for (k, m) in points(2, 12) {
            s.record("vta-sim", &k, &m);
            s.flush().unwrap();
        }
        drop(s);
        let segs = list_segments(&dir).unwrap();
        assert!(segs.len() >= 2, "tiny segment threshold must force rotation, got {segs:?}");
        // Every closed (non-newest) segment respects the threshold plus at
        // most one record of overshoot; all are valid journals.
        for (_, path) in &segs {
            Journal::open_read_only(path).unwrap();
        }
        // The full history survives rotation.
        let mut again = MeasureStore::open(&small_config(&dir)).unwrap();
        let keys: Vec<PointKey> = points(2, 12).into_iter().map(|(k, _)| k).collect();
        assert!(again.lookup_many("vta-sim", &keys).iter().all(Option::is_some));
        cleanup(&dir);
    }

    #[test]
    fn prune_keeps_newest_segments_under_budget() {
        let dir = tmp_dir("prune");
        let mut s = MeasureStore::open(&small_config(&dir)).unwrap();
        for (k, m) in points(3, 40) {
            s.record("vta-sim", &k, &m);
            s.flush().unwrap();
        }
        drop(s);
        let before = list_segments(&dir).unwrap();
        assert!(before.len() >= 3, "need several segments, got {}", before.len());
        let budget = 1024u64;
        let stats = prune_store(&dir, budget).unwrap();
        assert!(stats.deleted > 0, "over-budget store must shed segments: {stats:?}");
        assert!(
            stats.bytes_after <= budget || list_segments(&dir).unwrap().len() == 1,
            "prune must land under budget (or keep only the newest segment): {stats:?}"
        );
        let after = list_segments(&dir).unwrap();
        // Oldest deleted, newest kept.
        let before_max = before.last().unwrap().0;
        assert_eq!(after.last().unwrap().0, before_max, "newest segment must survive");
        assert!(after.first().unwrap().0 > before.first().unwrap().0, "oldest must go first");
        // Idempotent under budget.
        let again = prune_store(&dir, budget).unwrap();
        assert_eq!(again.deleted, 0);
        cleanup(&dir);
    }

    #[test]
    fn prune_never_deletes_a_live_writers_segment() {
        let dir = tmp_dir("prune_locked");
        let pts = points(4, 40);
        {
            let mut s = MeasureStore::open(&small_config(&dir)).unwrap();
            for (k, m) in &pts {
                s.record("vta-sim", k, m);
                s.flush().unwrap();
            }
            drop(s);
        }
        // A live writer (this process) claims the *oldest* segment by
        // locking it directly, then pruning to a tiny budget must keep it.
        let oldest = list_segments(&dir).unwrap().first().unwrap().1.clone();
        let held = Journal::try_open_writer(&oldest).unwrap().expect("claimable");
        let err = prune_store(&dir, 1).unwrap_err().to_string();
        assert!(
            err.contains("cannot prune below the byte budget"),
            "unexpected error: {err}"
        );
        assert!(oldest.exists(), "locked segment must never be deleted");
        drop(held);
        assert!(prune_store(&dir, 1).is_ok());
        assert!(!oldest.exists());
        cleanup(&dir);
    }

    #[test]
    fn foreign_fingerprint_segment_is_refused() {
        let dir = tmp_dir("foreign");
        std::fs::create_dir_all(&dir).unwrap();
        let mut fp = Fingerprint::current();
        fp.cycle_model += 1;
        let header = Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num(Journal::VERSION as f64)),
            ("fingerprint", fp.to_json()),
        ]);
        std::fs::write(segment_path(&dir, 0), header.dump() + "\n").unwrap();
        let err = MeasureStore::open(&StoreConfig::new(dir.clone()))
            .err()
            .expect("foreign segment must refuse the store")
            .to_string();
        assert!(err.contains("refused"), "unexpected error: {err}");
        assert!(err.contains("different simulator"), "unexpected error: {err}");
        let err = store_stat(&dir).unwrap_err().to_string();
        assert!(err.contains("refused"), "unexpected error: {err}");
        cleanup(&dir);
    }

    #[test]
    fn concurrent_writers_claim_disjoint_segments() {
        let dir = tmp_dir("two_writers");
        let cfg = StoreConfig::new(dir.clone());
        let mut a = MeasureStore::open(&cfg).unwrap();
        let mut b = MeasureStore::open(&cfg).unwrap();
        let seg_a = a.active_segment().expect("a claims a segment").to_path_buf();
        let seg_b = b.active_segment().expect("b claims a segment").to_path_buf();
        assert_ne!(seg_a, seg_b, "two live writers must never share a segment");

        let pts = points(5, 8);
        for (i, (k, m)) in pts.iter().enumerate() {
            if i % 2 == 0 {
                a.record("vta-sim", k, m);
            } else {
                b.record("vta-sim", k, m);
            }
        }
        a.flush().unwrap();
        b.flush().unwrap();
        // Each segment holds only its writer's records — no interleaving.
        let in_a = Journal::open_read_only(&seg_a).unwrap();
        let in_b = Journal::open_read_only(&seg_b).unwrap();
        assert_eq!(in_a.len(), 4);
        assert_eq!(in_b.len(), 4);
        for (i, (k, _)) in pts.iter().enumerate() {
            let (own, other) = if i % 2 == 0 { (&in_a, &in_b) } else { (&in_b, &in_a) };
            assert!(own.entries().iter().any(|e| &e.key == k));
            assert!(!other.entries().iter().any(|e| &e.key == k));
        }
        // And each sees the other's flushed work through lookup.
        let keys: Vec<PointKey> = pts.iter().map(|(k, _)| k.clone()).collect();
        assert!(a.lookup_many("vta-sim", &keys).iter().all(Option::is_some));
        assert!(b.lookup_many("vta-sim", &keys).iter().all(Option::is_some));
        cleanup(&dir);
    }

    #[test]
    fn stat_reports_segments_bytes_and_identities() {
        let dir = tmp_dir("stat");
        let pts = points(6, 5);
        let mut s = MeasureStore::open(&StoreConfig::new(dir.clone())).unwrap();
        for (k, m) in &pts {
            s.record("vta-sim", k, m);
        }
        s.flush().unwrap();
        let held = store_stat(&dir).unwrap();
        assert_eq!(held.locked, 1, "our own writer holds its segment");
        drop(s);
        let stats = store_stat(&dir).unwrap();
        assert_eq!(stats.identities, 5);
        assert_eq!(stats.locked, 0);
        assert!(stats.bytes > 0);
        assert!(store_stat(&tmp_dir("stat_missing")).is_err());
        cleanup(&dir);
    }
}
