//! Persistent measurement journal: JSON on disk, reused across processes.
//!
//! Format (version 1):
//!
//! ```json
//! {
//!   "version": 1,
//!   "entries": [
//!     {
//!       "backend": "vta-sim",
//!       "task": {"n":1,"ci":64,"h":56,"w":56,"co":64,"kh":3,"kw":3,"stride":1,"pad":1},
//!       "values": [1, 16, 16, 1, 1, 8, 8],
//!       "valid": true,
//!       "seconds": 0.00123,
//!       "cycles": 123456,
//!       "gflops": 41.2,
//!       "area_mm2": 2.31,
//!       "occupancy": 0.92
//!     }
//!   ]
//! }
//! ```
//!
//! `values` are decoded knob values in space knob order (the same identity
//! as [`PointKey`]); invalid configurations carry `"seconds": null` and are
//! restored with infinite runtime. Entries from a different backend than
//! the engine's are kept on disk but not preloaded into its cache, so one
//! journal file can serve both the simulator and the analytical proxy.
//!
//! Durability model: one writing engine per journal file. A `(backend,
//! key)` pair is recorded at most once, flushes rewrite the file atomically
//! (temp file + rename), and a torn or corrupt file degrades to an empty
//! journal rather than aborting. Concurrent *writer* processes are not
//! coordinated — the last flusher wins (see ROADMAP open items).
//!
//! Staleness caveat: entries are keyed on `(backend, task, knob values)`
//! only — they carry no fingerprint of the simulator itself. If the cycle
//! model or the non-tunable `VtaConfig` defaults change, delete the
//! journal file; reusing it would silently mix old-model and new-model
//! numbers. This is why no shipped config enables a journal by default.

use super::cache::PointKey;
use crate::codegen::MeasureResult;
use crate::util::json::{read_json_file, write_json_file, Json};
use crate::workload::Conv2dTask;
use std::collections::HashSet;
use std::path::{Path, PathBuf};

/// One persisted measurement.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    pub backend: String,
    pub key: PointKey,
    pub result: MeasureResult,
}

/// An append-only measurement log bound to one file.
pub struct Journal {
    path: PathBuf,
    entries: Vec<JournalEntry>,
    /// `(backend, key)` identities already present, so repeated `record`
    /// calls (e.g. cache-less engines re-measuring) never grow the file.
    seen: HashSet<(String, PointKey)>,
    dirty: bool,
}

impl Journal {
    pub const VERSION: usize = 1;

    /// Open (or create-on-first-flush) the journal at `path`. A missing
    /// file is an empty journal; an unreadable one is logged and treated
    /// as empty rather than aborting the run.
    pub fn open(path: &Path) -> Journal {
        let mut entries = Vec::new();
        if path.exists() {
            match read_json_file(path) {
                Ok(doc) => entries = parse_entries(&doc),
                Err(e) => {
                    crate::log_warn!("eval", "ignoring unreadable journal {}: {e}", path.display());
                }
            }
        }
        let seen = entries
            .iter()
            .map(|e: &JournalEntry| (e.backend.clone(), e.key.clone()))
            .collect();
        Journal { path: path.to_path_buf(), entries, seen, dirty: false }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn entries(&self) -> &[JournalEntry] {
        &self.entries
    }

    /// Append one measurement (persisted at the next [`flush`](Self::flush)).
    /// A `(backend, key)` pair already journaled is ignored.
    pub fn record(&mut self, backend: &str, key: &PointKey, result: &MeasureResult) {
        if !self.seen.insert((backend.to_string(), key.clone())) {
            return;
        }
        self.entries.push(JournalEntry {
            backend: backend.to_string(),
            key: key.clone(),
            result: *result,
        });
        self.dirty = true;
    }

    /// Write the journal out if anything was recorded since the last flush.
    /// The rewrite is atomic (temp file + rename), so an interrupted flush
    /// leaves the previous journal intact instead of a torn file.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if !self.dirty {
            return Ok(());
        }
        let tmp = self.path.with_extension("json.tmp");
        write_json_file(&tmp, &self.to_json())?;
        std::fs::rename(&tmp, &self.path)?;
        self.dirty = false;
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(Self::VERSION as f64)),
            ("entries", Json::Arr(self.entries.iter().map(entry_to_json).collect())),
        ])
    }
}

fn entry_to_json(e: &JournalEntry) -> Json {
    let r = &e.result;
    Json::obj(vec![
        ("backend", Json::str(e.backend.clone())),
        ("task", e.key.task.to_json()),
        (
            "values",
            Json::Arr(e.key.values.iter().map(|&v| Json::num(v as f64)).collect()),
        ),
        ("valid", Json::Bool(r.valid)),
        // Infinite runtimes (invalid configs) serialize as null.
        ("seconds", Json::num(r.seconds)),
        ("cycles", Json::num(r.cycles as f64)),
        ("gflops", Json::num(r.gflops)),
        ("area_mm2", Json::num(r.area_mm2)),
        ("occupancy", Json::num(r.occupancy)),
    ])
}

fn entry_from_json(v: &Json) -> Option<JournalEntry> {
    let backend = v.get_str("backend")?.to_string();
    let task = Conv2dTask::from_json(v.get("task")?)?;
    let values: Vec<usize> =
        v.get("values")?.as_arr()?.iter().map(|x| x.as_usize()).collect::<Option<_>>()?;
    let valid = v.get_bool("valid")?;
    let seconds = if valid { v.get_f64("seconds")? } else { f64::INFINITY };
    let result = MeasureResult {
        seconds,
        cycles: v.get_f64("cycles").unwrap_or(0.0) as u64,
        gflops: v.get_f64("gflops").unwrap_or(0.0),
        area_mm2: v.get_f64("area_mm2").unwrap_or(0.0),
        occupancy: v.get_f64("occupancy").unwrap_or(0.0),
        valid,
    };
    Some(JournalEntry { backend, key: PointKey { task, values }, result })
}

fn parse_entries(doc: &Json) -> Vec<JournalEntry> {
    let mut out = Vec::new();
    let Some(items) = doc.get("entries").and_then(Json::as_arr) else {
        return out;
    };
    let mut skipped = 0usize;
    for item in items {
        match entry_from_json(item) {
            Some(e) => out.push(e),
            None => skipped += 1,
        }
    }
    if skipped > 0 {
        crate::log_warn!("eval", "journal: skipped {skipped} malformed entries");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::measure_point;
    use crate::space::ConfigSpace;
    use crate::util::rng::Pcg32;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    fn tmp_path(tag: &str) -> PathBuf {
        // Keep test artifacts inside the build tree.
        PathBuf::from("target/tmp").join(format!("journal_{tag}_{}.json", std::process::id()))
    }

    #[test]
    fn roundtrips_through_util_json() {
        let s = space();
        let mut rng = Pcg32::seeded(2);
        let path = tmp_path("roundtrip");
        let _ = std::fs::remove_file(&path);

        let mut j = Journal::open(&path);
        assert!(j.is_empty());
        let mut keys: Vec<(PointKey, crate::codegen::MeasureResult)> = Vec::new();
        for _ in 0..8 {
            let p = s.random_point(&mut rng);
            let key = PointKey::of(&s, &p);
            let m = measure_point(&s, &p);
            j.record("vta-sim", &key, &m);
            if !keys.iter().any(|(k, _)| *k == key) {
                keys.push((key, m));
            }
        }
        j.flush().unwrap();

        let j2 = Journal::open(&path);
        assert_eq!(j2.len(), keys.len());
        for (e, (key, m)) in j2.entries().iter().zip(&keys) {
            assert_eq!(e.backend, "vta-sim");
            assert_eq!(&e.key, key);
            if m.valid {
                assert_eq!(&e.result, m);
            } else {
                assert!(!e.result.valid);
                assert!(e.result.seconds.is_infinite());
            }
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn flush_is_idempotent_and_lazy() {
        let path = tmp_path("lazy");
        let _ = std::fs::remove_file(&path);
        let mut j = Journal::open(&path);
        // Nothing recorded: flush must not create the file.
        j.flush().unwrap();
        assert!(!path.exists());
        let s = space();
        let p = s.default_point();
        j.record("vta-sim", &PointKey::of(&s, &p), &measure_point(&s, &p));
        j.flush().unwrap();
        assert!(path.exists());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn duplicate_records_are_ignored_across_sessions() {
        let s = space();
        let path = tmp_path("dedup");
        let _ = std::fs::remove_file(&path);
        let p = s.default_point();
        let key = PointKey::of(&s, &p);
        let m = measure_point(&s, &p);

        let mut j = Journal::open(&path);
        j.record("vta-sim", &key, &m);
        j.record("vta-sim", &key, &m); // same session duplicate
        j.record("analytical", &key, &m); // different backend: distinct
        assert_eq!(j.len(), 2);
        j.flush().unwrap();

        // A second session re-recording the same identity must not grow
        // the file or mark it dirty.
        let mut j2 = Journal::open(&path);
        assert_eq!(j2.len(), 2);
        j2.record("vta-sim", &key, &m);
        assert_eq!(j2.len(), 2);
        j2.flush().unwrap();
        assert_eq!(Journal::open(&path).len(), 2);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn unreadable_journal_degrades_to_empty() {
        let path = tmp_path("garbage");
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        std::fs::write(&path, "not json {").unwrap();
        let j = Journal::open(&path);
        assert!(j.is_empty());
        let _ = std::fs::remove_file(&path);
    }
}
