//! Persistent measurement journal: fingerprinted, append-only JSON lines.
//!
//! Format (version 2) — one JSON document per line. The first line is a
//! header stamping the simulator [`Fingerprint`] (cycle-model version +
//! non-tunable `VtaConfig` defaults); every following line is one
//! measurement record in the shared schema of [`super::proto`]:
//!
//! ```text
//! {"format":"arco-journal","version":2,"fingerprint":{"cycle_model":1,...}}
//! {"backend":"vta-sim","task":{"n":1,...},"values":[1,16,16,1,1,8,8],"valid":true,"seconds":0.00123,...}
//! {"backend":"analytical","task":{...},"values":[...],...}
//! ```
//!
//! `values` are decoded knob values in space knob order (the same identity
//! as [`PointKey`] and the `serve-measure` wire); invalid configurations
//! carry `"seconds": null` and are restored with infinite runtime. Entries
//! from a different backend than the engine's are kept on disk but not
//! preloaded into its cache, so one journal file can serve both the
//! simulator and the analytical proxy.
//!
//! Safety model:
//!
//! - **Fingerprint.** Opening a journal whose header fingerprint differs
//!   from this binary's refuses with an error: reusing it would silently
//!   mix numbers from different cycle models. Delete (or archive) the file
//!   after a simulator change and let runs re-measure.
//! - **Single writer.** A writer takes a `<path>.lock` sentinel on open
//!   (freed on drop); a second writing process fails fast with a clear
//!   error instead of silently last-wins on flush. Read-only opens
//!   ([`Journal::open_read_only`]) take no lock.
//! - **Append-only flush.** A flush appends only the records since the
//!   previous flush, so flush cost is O(new entries), not O(file). A torn
//!   final line (crash mid-append) is dropped on the next load and the
//!   file is compacted on the next flush.
//! - **v1 migration.** Version-1 whole-file JSON journals (`{"version":1,
//!   "entries":[...]}`) carry no fingerprint, so their numbers cannot be
//!   trusted across binaries: opening one refuses with a migration error.
//!   Delete or archive the old file; re-runs repopulate it in v2 form.

use super::cache::PointKey;
use super::proto::{record_from_line, record_identity_from_line, write_record_line, Fingerprint};
use crate::codegen::MeasureResult;
use crate::util::json::Json;
use std::collections::HashSet;
use std::io::{BufRead, Read, Write};
use std::path::{Path, PathBuf};

/// One persisted measurement.
#[derive(Debug, Clone)]
pub struct JournalEntry {
    pub backend: String,
    pub key: PointKey,
    pub result: MeasureResult,
}

/// `path` with `suffix` appended to the file name (keeps any extension).
pub(crate) fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

/// Can we *prove* the lock-holding pid is gone? Only where a process table
/// is inspectable (Linux `/proc`); anywhere else — or for an unparsable
/// sentinel — assume it is alive and fail fast.
pub(crate) fn holder_is_dead(holder: &str) -> bool {
    if holder.is_empty() || holder.parse::<u32>().is_err() {
        return false;
    }
    let proc_root = Path::new("/proc");
    proc_root.is_dir() && !proc_root.join(holder).exists()
}

/// How an attempt to take a `<path>.lock` writer sentinel ended.
enum LockAcquire {
    /// Sentinel created; the caller owns the lock.
    Acquired,
    /// A live (or unverifiable) writer holds it; `holder` is its recorded
    /// pid, empty when unreadable.
    Busy { holder: String },
    /// Filesystem trouble unrelated to contention (e.g. read-only dir).
    Failed(std::io::Error),
}

/// Take the `<path>.lock` writer sentinel for the journal at `path`. A
/// sentinel left behind by a killed process (SIGTERM skips Drop) is
/// reclaimed when the recorded pid is verifiably dead (Linux `/proc`).
/// The reclaim must not race another reclaimer into two writers: the
/// sentinel is renamed aside (atomic; one winner) and its content
/// re-checked — if the rename grabbed a *fresh* lock instead (a racer
/// already reclaimed and re-locked), it is put back and the retry
/// collides with that live lock and reports `Busy`. Shared by every
/// writer-mode entry point ([`Journal::open`], [`compact_journal`]) so
/// the two writers' lock semantics cannot drift.
fn acquire_lock_sentinel(path: &Path) -> LockAcquire {
    let lock = sibling(path, ".lock");
    let mut attempts = 0;
    loop {
        match std::fs::OpenOptions::new().write(true).create_new(true).open(&lock) {
            Ok(mut f) => {
                let _ = writeln!(f, "{}", std::process::id());
                return LockAcquire::Acquired;
            }
            Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => {
                let holder = std::fs::read_to_string(&lock)
                    .map(|s| s.trim().to_string())
                    .unwrap_or_default();
                if attempts == 0 && holder_is_dead(&holder) {
                    attempts += 1;
                    let aside = sibling(path, &format!(".lock.stale.{}", std::process::id()));
                    if std::fs::rename(&lock, &aside).is_ok() {
                        let renamed = std::fs::read_to_string(&aside)
                            .map(|s| s.trim().to_string())
                            .unwrap_or_default();
                        if renamed == holder {
                            crate::log_warn!(
                                "eval",
                                "journal {}: reclaimed stale lock from dead pid {holder}",
                                path.display()
                            );
                            let _ = std::fs::remove_file(&aside);
                        } else {
                            let _ = std::fs::rename(&aside, &lock);
                        }
                    }
                    continue;
                }
                return LockAcquire::Busy { holder };
            }
            Err(e) => return LockAcquire::Failed(e),
        }
    }
}

/// Verdict of [`check_header`] on a journal's first line.
pub(crate) enum HeaderCheck {
    /// A valid v2 header stamped with this binary's fingerprint.
    Journal,
    /// Not a v2 journal header at all; the caller discriminates v1 files
    /// from garbage (that needs the whole text, which only it may have).
    NotAJournal,
}

/// Validate a v2 journal header line. The fatal data-safety refusals
/// (unsupported version, missing or foreign fingerprint) are shared by
/// [`Journal::open`] and [`merge_journals`] through this helper so the two
/// entry points cannot drift.
pub(crate) fn check_header(path: &Path, first: &str) -> anyhow::Result<HeaderCheck> {
    let header = match Json::parse(first) {
        Ok(h) if h.get_str("format") == Some("arco-journal") => h,
        _ => return Ok(HeaderCheck::NotAJournal),
    };
    let version = header.get_usize("version").unwrap_or(0);
    if version != Journal::VERSION {
        anyhow::bail!(
            "journal {}: unsupported version {version} (this binary writes v{})",
            path.display(),
            Journal::VERSION
        );
    }
    let stamped = header.get("fingerprint").and_then(Fingerprint::from_json).ok_or_else(|| {
        anyhow::anyhow!("journal {}: header carries no fingerprint", path.display())
    })?;
    let current = Fingerprint::current();
    if stamped != current {
        anyhow::bail!(
            "journal {} was measured under a different simulator — refusing to mix numbers.\n  \
             journal: {}\n  binary:  {}\nDelete or archive the file and re-run to re-measure",
            path.display(),
            stamped.describe(),
            current.describe()
        );
    }
    Ok(HeaderCheck::Journal)
}

/// The first line was not a v2 header: refuse the whole text if it is a v1
/// whole-file journal (its numbers carry no fingerprint), otherwise let the
/// caller treat the file as garbage.
fn refuse_if_v1(path: &Path, text: &str) -> anyhow::Result<()> {
    if let Ok(doc) = Json::parse(text) {
        if doc.get("entries").is_some() || doc.get_usize("version").is_some() {
            anyhow::bail!(
                "journal {} is in the v1 whole-file JSON format, which carries no \
                 simulator fingerprint; its numbers cannot be safely reused. Delete \
                 or archive the file and re-run to repopulate it in v2 form",
                path.display()
            );
        }
    }
    Ok(())
}

/// An append-only measurement log bound to one file.
pub struct Journal {
    path: PathBuf,
    fingerprint: Fingerprint,
    entries: Vec<JournalEntry>,
    /// `(backend, key)` identities already present, so repeated `record`
    /// calls (e.g. cache-less engines re-measuring) never grow the file.
    seen: HashSet<(String, PointKey)>,
    /// How many of `entries` are already on disk.
    flushed: usize,
    /// The on-disk bytes are not a clean v2 prefix (garbage, torn tail):
    /// the next flush rewrites the whole file instead of appending.
    rewrite: bool,
    /// Writer mode: holds the lock sentinel, may flush.
    writer: bool,
}

impl Journal {
    pub const VERSION: usize = 2;

    /// Open the journal at `path` for writing: takes the `<path>.lock`
    /// sentinel (failing fast if another writer holds it), verifies the
    /// header fingerprint against this binary, and loads existing entries.
    /// A missing file is an empty journal; a file that is not a journal at
    /// all is logged and treated as empty (it is replaced on first flush).
    ///
    /// Error policy: *data-safety* problems are fatal (another live
    /// writer, a foreign fingerprint, a v1 file) — silently proceeding
    /// would lose or mix measurements. Plain *filesystem* trouble (a
    /// read-only results dir) degrades to a read-only journal with a
    /// warning: existing entries still seed the cache, new ones are
    /// simply not persisted, and the run continues.
    pub fn open(path: &Path) -> anyhow::Result<Journal> {
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                if let Err(e) = std::fs::create_dir_all(parent) {
                    crate::log_warn!(
                        "eval",
                        "cannot create journal dir {} ({e}); journal opens read-only, \
                         measurements will not be persisted",
                        parent.display()
                    );
                    return Journal::load(path, false);
                }
            }
        }
        match acquire_lock_sentinel(path) {
            LockAcquire::Acquired => {}
            LockAcquire::Busy { holder } => {
                anyhow::bail!(
                    "journal {} is locked by another writer (pid {}): one writing engine \
                     per journal; if that process is dead, delete {}",
                    path.display(),
                    if holder.is_empty() { "unknown".to_string() } else { holder },
                    sibling(path, ".lock").display()
                );
            }
            LockAcquire::Failed(e) => {
                crate::log_warn!(
                    "eval",
                    "cannot lock journal {} ({e}); journal opens read-only, \
                     measurements will not be persisted",
                    path.display()
                );
                return Journal::load(path, false);
            }
        }
        match Journal::load(path, true) {
            Ok(j) => Ok(j),
            Err(e) => {
                // Do not leave the sentinel behind on a refused open.
                let _ = std::fs::remove_file(sibling(path, ".lock"));
                Err(e)
            }
        }
    }

    /// Open without taking the writer lock. The journal can be inspected
    /// but [`flush`](Self::flush) is a no-op.
    pub fn open_read_only(path: &Path) -> anyhow::Result<Journal> {
        Journal::load(path, false)
    }

    /// Open `path` for writing without treating contention as an error:
    /// `Ok(None)` when a live writer holds the `<path>.lock` sentinel.
    /// The measurement store uses this to skip past a segment another
    /// shard is appending to. Unlike [`Journal::open`], filesystem
    /// trouble is an error here rather than a read-only degradation —
    /// the caller wants a *writable* segment or none at all. Data-safety
    /// refusals (foreign fingerprint, v1 file) are the same as `open`.
    pub(crate) fn try_open_writer(path: &Path) -> anyhow::Result<Option<Journal>> {
        match acquire_lock_sentinel(path) {
            LockAcquire::Acquired => {}
            LockAcquire::Busy { .. } => return Ok(None),
            LockAcquire::Failed(e) => {
                anyhow::bail!("cannot lock {}: {e}", path.display());
            }
        }
        match Journal::load(path, true) {
            Ok(j) => Ok(Some(j)),
            Err(e) => {
                // Do not leave the sentinel behind on a refused open.
                let _ = std::fs::remove_file(sibling(path, ".lock"));
                Err(e)
            }
        }
    }

    fn load(path: &Path, writer: bool) -> anyhow::Result<Journal> {
        let mut journal = Journal {
            path: path.to_path_buf(),
            fingerprint: Fingerprint::current(),
            entries: Vec::new(),
            seen: HashSet::new(),
            flushed: 0,
            rewrite: false,
            writer,
        };
        let file = match std::fs::File::open(path) {
            Ok(f) => f,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(journal),
            Err(e) => {
                crate::log_warn!("eval", "ignoring unreadable journal {}: {e}", path.display());
                journal.rewrite = true;
                return Ok(journal);
            }
        };
        // Stream the file line by line: a million-record warm-start journal
        // is replayed without ever holding the whole file (or a JSON tree
        // per record) in memory.
        let mut reader = std::io::BufReader::new(file);
        let mut first_raw: Vec<u8> = Vec::new();
        if let Err(e) = reader.read_until(b'\n', &mut first_raw) {
            crate::log_warn!("eval", "ignoring unreadable journal {}: {e}", path.display());
            journal.rewrite = true;
            return Ok(journal);
        }
        let first_line = String::from_utf8_lossy(&first_raw);
        match check_header(path, first_line.trim_end_matches(['\n', '\r']))? {
            HeaderCheck::Journal => {}
            HeaderCheck::NotAJournal => {
                // Not a v2 header. A v1 journal is a single pretty-printed
                // JSON document; anything else is garbage.
                let mut rest = String::new();
                let _ = reader.read_to_string(&mut rest);
                let text = format!("{first_line}{rest}");
                if text.trim().is_empty() {
                    journal.rewrite = true;
                    return Ok(journal);
                }
                refuse_if_v1(path, &text)?;
                crate::log_warn!(
                    "eval",
                    "file {} is not a measurement journal; treating as empty",
                    path.display()
                );
                journal.rewrite = true;
                return Ok(journal);
            }
        }
        let mut skipped = 0usize;
        let mut ends_with_newline = first_raw.last() == Some(&b'\n');
        let mut buf: Vec<u8> = Vec::new();
        loop {
            buf.clear();
            match reader.read_until(b'\n', &mut buf) {
                Ok(0) => break,
                Ok(_) => {}
                Err(e) => {
                    crate::log_warn!(
                        "eval",
                        "ignoring rest of unreadable journal {}: {e}",
                        path.display()
                    );
                    journal.rewrite = true;
                    break;
                }
            }
            ends_with_newline = buf.last() == Some(&b'\n');
            let Ok(line) = std::str::from_utf8(&buf) else {
                skipped += 1;
                continue;
            };
            let line = line.trim_end_matches(['\n', '\r']);
            if line.trim().is_empty() {
                continue;
            }
            match record_from_line(line) {
                Some((backend, key, result)) => {
                    if journal.seen.insert((backend.clone(), key.clone())) {
                        journal.entries.push(JournalEntry { backend, key, result });
                    }
                }
                None => skipped += 1,
            }
        }
        if skipped > 0 {
            crate::log_warn!(
                "eval",
                "journal {}: dropped {skipped} malformed lines (torn flush?); \
                 file will be compacted on next flush",
                path.display()
            );
            journal.rewrite = true;
        }
        if !ends_with_newline {
            // A torn final line without its newline would corrupt the next
            // appended record; rewrite instead.
            journal.rewrite = true;
        }
        journal.flushed = journal.entries.len();
        Ok(journal)
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// The simulator fingerprint this journal is stamped with.
    pub fn fingerprint(&self) -> &Fingerprint {
        &self.fingerprint
    }

    /// Records currently held in memory: everything on a fresh open, only
    /// the unflushed tail after a flush (see [`entries`](Self::entries)).
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// The in-memory records. A freshly opened journal holds everything
    /// loaded from disk (this is when the engine seeds its cache); after a
    /// flush the persisted prefix is dropped so a long-lived shard's
    /// journal memory stays bounded by its unflushed tail — re-open the
    /// file to read the full history.
    pub fn entries(&self) -> &[JournalEntry] {
        &self.entries
    }

    /// Append one measurement (persisted at the next [`flush`](Self::flush)).
    /// A `(backend, key)` pair already journaled is ignored, as is every
    /// record on a read-only journal — nothing would ever flush it, and a
    /// long-lived degraded shard must not accumulate records forever.
    /// Returns whether the record was newly added (`false`: duplicate
    /// identity or read-only journal).
    pub fn record(&mut self, backend: &str, key: &PointKey, result: &MeasureResult) -> bool {
        if !self.writer {
            return false;
        }
        if !self.seen.insert((backend.to_string(), key.clone())) {
            return false;
        }
        self.entries.push(JournalEntry {
            backend: backend.to_string(),
            key: key.clone(),
            result: *result,
        });
        true
    }

    /// Distinct `(backend, key)` identities this journal knows about —
    /// loaded from disk plus recorded this session (flushes keep the
    /// identity set even after dropping persisted entries from memory).
    pub fn identities(&self) -> usize {
        self.seen.len()
    }

    /// Whether a `(backend, key)` identity is already journaled. Lets a
    /// merge reject a duplicate from the identity prefix of its line alone,
    /// before paying for a full record decode.
    pub(crate) fn contains_identity(&self, backend: &str, key: &PointKey) -> bool {
        self.seen.contains(&(backend.to_string(), key.clone()))
    }

    fn header_json(&self) -> Json {
        Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num(Self::VERSION as f64)),
            ("fingerprint", self.fingerprint.to_json()),
        ])
    }

    fn entry_line(e: &JournalEntry) -> String {
        // Serialized straight into a buffer by the streaming writer — no
        // intermediate JSON tree — byte-identical to the tree encoding
        // (including the trailing newline).
        let mut buf = Vec::with_capacity(256);
        write_record_line(&mut buf, &e.backend, &e.key, &e.result)
            .expect("writing a record to a Vec cannot fail");
        String::from_utf8(buf).expect("serialized records are valid UTF-8")
    }

    /// Persist any records added since the last flush. Appends only the new
    /// lines (O(new entries)); the whole file is rewritten atomically (temp
    /// file + rename) only on first creation or after torn/garbage content.
    /// No-op for read-only journals and when nothing is pending.
    ///
    /// After a successful flush the persisted records are dropped from
    /// memory (the `seen` identity set is kept for dedup), so a shard that
    /// journals for weeks holds only its unflushed tail, not the whole
    /// history.
    pub fn flush(&mut self) -> anyhow::Result<()> {
        if !self.writer || self.flushed == self.entries.len() {
            return Ok(());
        }
        if self.rewrite || !self.path.exists() {
            let mut text = self.header_json().dump();
            text.push('\n');
            for e in &self.entries {
                text.push_str(&Self::entry_line(e));
            }
            let tmp = sibling(&self.path, ".tmp");
            std::fs::write(&tmp, text)?;
            std::fs::rename(&tmp, &self.path)?;
            self.rewrite = false;
        } else {
            let mut file = std::fs::OpenOptions::new().append(true).open(&self.path)?;
            let mut text = String::new();
            for e in &self.entries[self.flushed..] {
                text.push_str(&Self::entry_line(e));
            }
            file.write_all(text.as_bytes())?;
            file.flush()?;
        }
        self.entries.clear();
        self.flushed = 0;
        Ok(())
    }
}

impl Drop for Journal {
    fn drop(&mut self) {
        if self.writer {
            let _ = std::fs::remove_file(sibling(&self.path, ".lock"));
        }
    }
}

/// Outcome of a [`merge_journals`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MergeStats {
    /// Input files read.
    pub inputs: usize,
    /// Records read across all inputs (after each input's own dedup).
    pub read: usize,
    /// Records newly added to the output.
    pub added: usize,
    /// Records skipped as duplicates of the output or an earlier input.
    pub duplicates: usize,
    /// Distinct identities in the output after the merge.
    pub total: usize,
}

/// Union fingerprint-identical measurement journals into `out` — the warm
/// start for `serve-measure` fleets: merge every shard's local journal,
/// hand the union to a new shard via `--warm-start`, and it inherits the
/// fleet's entire measurement history before its first batch.
///
/// Records are deduplicated on the shared identity `(backend, task,
/// decoded knob values)`; re-merging the same inputs is idempotent (the
/// output's existing identities are loaded first). Every input must exist,
/// be a v2 journal, and carry this binary's [`Fingerprint`] — a v1 file or
/// a journal measured under a different simulator is refused, exactly as
/// [`Journal::open`] refuses it. Torn tails in inputs are tolerated (the
/// torn line is dropped, as on any load). The output is opened as a writer
/// (lock sentinel taken), so merging into a journal another process is
/// writing fails fast.
pub fn merge_journals(out: &Path, inputs: &[PathBuf]) -> anyhow::Result<MergeStats> {
    if inputs.is_empty() {
        anyhow::bail!("journal merge: need at least one input journal");
    }
    let mut dst = Journal::open(out)?;
    let mut stats = MergeStats { inputs: inputs.len(), ..Default::default() };
    for path in inputs {
        if !path.exists() {
            anyhow::bail!("journal merge: input {} does not exist", path.display());
        }
        merge_one_input(&mut dst, path, &mut stats)?;
    }
    dst.flush()?;
    if !out.exists() {
        // Every input was empty: still materialize a valid (header-only)
        // journal so a `--warm-start` pointed at the output finds one.
        let mut text = dst.header_json().dump();
        text.push('\n');
        std::fs::write(out, text)?;
    }
    stats.total = dst.identities();
    Ok(stats)
}

/// Stream one input journal into `dst`, line by line. A record already in
/// `dst` (the common case for mostly-overlapping fleet journals) is counted
/// as a duplicate from the `(backend, task, values)` identity prefix of its
/// line alone — the payload fields are decoded only for records that will
/// actually be added. Header safety checks (version, fingerprint, v1) are
/// the same refusals [`Journal::open`] makes, via [`check_header`].
fn merge_one_input(dst: &mut Journal, path: &Path, stats: &mut MergeStats) -> anyhow::Result<()> {
    let file = std::fs::File::open(path)?;
    let mut reader = std::io::BufReader::new(file);
    let mut first_raw: Vec<u8> = Vec::new();
    reader.read_until(b'\n', &mut first_raw)?;
    let first_line = String::from_utf8_lossy(&first_raw);
    match check_header(path, first_line.trim_end_matches(['\n', '\r']))? {
        HeaderCheck::Journal => {}
        HeaderCheck::NotAJournal => {
            let mut rest = String::new();
            let _ = reader.read_to_string(&mut rest);
            let text = format!("{first_line}{rest}");
            if text.trim().is_empty() {
                return Ok(());
            }
            refuse_if_v1(path, &text)?;
            crate::log_warn!(
                "eval",
                "file {} is not a measurement journal; treating as empty",
                path.display()
            );
            return Ok(());
        }
    }
    // Per-input dedup mirrors what loading the input as a Journal would do:
    // a line repeated inside one input counts as a single read.
    let mut local_seen: HashSet<(String, PointKey)> = HashSet::new();
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        if reader.read_until(b'\n', &mut buf)? == 0 {
            break;
        }
        let Ok(line) = std::str::from_utf8(&buf) else {
            continue; // corrupt line: dropped, as on any load
        };
        let line = line.trim_end_matches(['\n', '\r']);
        if line.trim().is_empty() {
            continue;
        }
        let Some((backend, key)) = record_identity_from_line(line) else {
            continue; // torn/malformed line: dropped, as on any load
        };
        if !local_seen.insert((backend.clone(), key.clone())) {
            continue;
        }
        stats.read += 1;
        if dst.contains_identity(&backend, &key) {
            stats.duplicates += 1;
            continue;
        }
        // New identity: now (and only now) decode the payload.
        let Some((backend, key, result)) = record_from_line(line) else {
            continue;
        };
        if dst.record(&backend, &key, &result) {
            stats.added += 1;
        } else {
            stats.duplicates += 1;
        }
    }
    Ok(())
}

/// Outcome of a [`compact_journal`] run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CompactStats {
    /// Record lines read from the file (header excluded).
    pub read: usize,
    /// Records kept in the compacted output.
    pub kept: usize,
    /// Duplicate `(backend, task, decoded knobs)` records dropped.
    pub dropped_duplicates: usize,
    /// Malformed lines dropped (torn flushes, line-level corruption).
    pub dropped_malformed: usize,
    /// Records dropped because the file was measured under a foreign or
    /// stale fingerprint (a simulator bump, or an unfingerprinted v1
    /// file): their numbers cannot be trusted by this binary.
    pub dropped_stale: usize,
    /// Whether the file was rewritten (false: already compact, untouched).
    pub rewritten: bool,
}

impl CompactStats {
    /// Total records dropped, all causes.
    pub fn dropped(&self) -> usize {
        self.dropped_duplicates + self.dropped_malformed + self.dropped_stale
    }
}

/// Removes the writer lock sentinel on drop, covering every error path.
struct LockGuard(PathBuf);

impl Drop for LockGuard {
    fn drop(&mut self) {
        let _ = std::fs::remove_file(&self.0);
    }
}

/// Rewrite the journal at `path` in place, dropping duplicate `(backend,
/// task, decoded knobs)` records, malformed (torn) lines, and records
/// measured under a foreign or stale fingerprint — the GC pass that keeps
/// long-lived warm-start files bounded (`arco journal compact`).
///
/// Unlike [`Journal::open`], which *refuses* fingerprint-mismatched and
/// v1 files (silently reusing their numbers would be wrong), compaction
/// is the explicit cleanup tool: a journal stamped by a different
/// simulator (or an unfingerprinted v1 journal) has nothing this binary
/// can reuse, so its records are dropped wholesale and the file becomes
/// a valid, empty v2 journal under the current fingerprint. A healthy,
/// already-compact file is left byte-untouched. A file that is not a
/// measurement journal at all — a typo'd path, a torn header, a future
/// format version — is refused with an error, never rewritten: GC only
/// touches data it can positively identify as journal records.
///
/// Takes the `<path>.lock` writer sentinel for the duration (failing fast
/// if a live writer holds it; a dead writer's stale sentinel is reclaimed,
/// exactly as [`Journal::open`] does) and replaces the file atomically
/// (temp file + rename), so a crash mid-compaction never loses the
/// original.
pub fn compact_journal(path: &Path) -> anyhow::Result<CompactStats> {
    if !path.exists() {
        anyhow::bail!("journal compact: {} does not exist", path.display());
    }
    match acquire_lock_sentinel(path) {
        LockAcquire::Acquired => {}
        LockAcquire::Busy { holder } => {
            anyhow::bail!(
                "journal {} is locked by another writer (pid {}): compact it when no engine \
                 is journaling to it; if that process is dead, delete {}",
                path.display(),
                if holder.is_empty() { "unknown".to_string() } else { holder },
                sibling(path, ".lock").display()
            );
        }
        LockAcquire::Failed(e) => {
            anyhow::bail!("journal compact: cannot lock {}: {e}", path.display());
        }
    }
    let _guard = LockGuard(sibling(path, ".lock"));

    let text = std::fs::read_to_string(path)?;
    let current = Fingerprint::current();
    let mut stats = CompactStats::default();
    let mut kept_lines: Vec<String> = Vec::new();
    let mut seen: HashSet<(String, PointKey)> = HashSet::new();

    let mut lines = text.lines();
    let first = lines.next().unwrap_or("");
    let header = Json::parse(first)
        .ok()
        .filter(|h| h.get_str("format") == Some("arco-journal"));
    let trusted = match &header {
        Some(h) => {
            let version = h.get_usize("version").unwrap_or(0);
            if version != Journal::VERSION {
                // A future format may hold data this binary cannot even
                // parse: wiping it would be destruction, not GC.
                anyhow::bail!(
                    "journal compact: {} is journal version {version}, this binary compacts \
                     v{} — refusing to touch it",
                    path.display(),
                    Journal::VERSION
                );
            }
            // Same version, different simulator fingerprint: the records
            // are parseable but their numbers are stale — the documented
            // GC case, dropped wholesale below.
            h.get("fingerprint").and_then(Fingerprint::from_json).as_ref() == Some(&current)
        }
        None => {
            // No v2 header. A v1 whole-file journal carries no fingerprint
            // at all, so its records are stale by construction and
            // compacting it into an empty v2 journal is the documented
            // migration. Anything else is NOT a journal — a results file,
            // a typo'd path — and rewriting it would destroy data the
            // operator never asked us to manage: refuse.
            let v1_entries = Json::parse(&text).ok().and_then(|doc| {
                if doc.get_usize("version") == Some(1) {
                    Some(doc.get("entries").and_then(Json::as_arr).map_or(0, Vec::len))
                } else {
                    None
                }
            });
            let Some(entries) = v1_entries else {
                anyhow::bail!(
                    "journal compact: {} is not a measurement journal (no v2 header, not a \
                     v1 journal) — refusing to rewrite it",
                    path.display()
                );
            };
            stats.read = entries;
            stats.dropped_stale = entries;
            false
        }
    };
    if header.is_some() {
        for line in lines {
            if line.trim().is_empty() {
                continue;
            }
            stats.read += 1;
            if !trusted {
                stats.dropped_stale += 1;
                continue;
            }
            // GC only needs each record's identity: the payload bytes are
            // carried over verbatim, never decoded.
            match record_identity_from_line(line) {
                Some((backend, key)) => {
                    if seen.insert((backend, key)) {
                        stats.kept += 1;
                        kept_lines.push(line.to_string());
                    } else {
                        stats.dropped_duplicates += 1;
                    }
                }
                None => stats.dropped_malformed += 1,
            }
        }
    }

    // Already compact (healthy header, nothing dropped, clean final
    // newline): leave the bytes untouched — compaction is idempotent.
    if trusted && stats.dropped() == 0 && text.ends_with('\n') {
        return Ok(stats);
    }

    let header_line = Json::obj(vec![
        ("format", Json::str("arco-journal")),
        ("version", Json::num(Journal::VERSION as f64)),
        ("fingerprint", current.to_json()),
    ])
    .dump();
    let mut out = header_line;
    out.push('\n');
    for line in &kept_lines {
        out.push_str(line);
        out.push('\n');
    }
    let tmp = sibling(path, ".tmp");
    std::fs::write(&tmp, out)?;
    std::fs::rename(&tmp, path)?;
    stats.rewritten = true;
    Ok(stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codegen::measure_point;
    use crate::eval::proto::record_to_json;
    use crate::space::ConfigSpace;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    fn tmp_path(tag: &str) -> PathBuf {
        // Keep test artifacts inside the build tree.
        PathBuf::from("target/tmp").join(format!("journal_{tag}_{}.jsonl", std::process::id()))
    }

    fn cleanup(path: &Path) {
        let _ = std::fs::remove_file(path);
        let _ = std::fs::remove_file(sibling(path, ".lock"));
    }

    #[test]
    fn roundtrips_through_jsonl() {
        let s = space();
        let mut rng = Pcg32::seeded(2);
        let path = tmp_path("roundtrip");
        cleanup(&path);

        let mut j = Journal::open(&path).unwrap();
        assert!(j.is_empty());
        let mut keys: Vec<(PointKey, crate::codegen::MeasureResult)> = Vec::new();
        for _ in 0..8 {
            let p = s.random_point(&mut rng);
            let key = PointKey::of(&s, &p);
            let m = measure_point(&s, &p);
            j.record("vta-sim", &key, &m);
            if !keys.iter().any(|(k, _)| *k == key) {
                keys.push((key, m));
            }
        }
        j.flush().unwrap();
        drop(j);

        let j2 = Journal::open_read_only(&path).unwrap();
        assert_eq!(j2.len(), keys.len());
        for (e, (key, m)) in j2.entries().iter().zip(&keys) {
            assert_eq!(e.backend, "vta-sim");
            assert_eq!(&e.key, key);
            if m.valid {
                assert_eq!(&e.result, m);
            } else {
                assert!(!e.result.valid);
                assert!(e.result.seconds.is_infinite());
            }
        }
        cleanup(&path);
    }

    #[test]
    fn flush_is_idempotent_and_lazy() {
        let path = tmp_path("lazy");
        cleanup(&path);
        let mut j = Journal::open(&path).unwrap();
        // Nothing recorded: flush must not create the file.
        j.flush().unwrap();
        assert!(!path.exists());
        let s = space();
        let p = s.default_point();
        j.record("vta-sim", &PointKey::of(&s, &p), &measure_point(&s, &p));
        j.flush().unwrap();
        assert!(path.exists());
        cleanup(&path);
    }

    #[test]
    fn flush_appends_instead_of_rewriting() {
        let s = space();
        let path = tmp_path("append");
        cleanup(&path);
        let mut rng = Pcg32::seeded(12);

        let mut j = Journal::open(&path).unwrap();
        let p1 = s.random_point(&mut rng);
        j.record("vta-sim", &PointKey::of(&s, &p1), &measure_point(&s, &p1));
        j.flush().unwrap();
        let after_first = std::fs::read_to_string(&path).unwrap();

        let mut p2 = s.random_point(&mut rng);
        while PointKey::of(&s, &p2) == PointKey::of(&s, &p1) {
            p2 = s.random_point(&mut rng);
        }
        j.record("vta-sim", &PointKey::of(&s, &p2), &measure_point(&s, &p2));
        j.flush().unwrap();
        let after_second = std::fs::read_to_string(&path).unwrap();

        // The second flush appended: the first flush's bytes are a prefix.
        assert!(after_second.starts_with(&after_first));
        assert_eq!(after_second.lines().count(), 3); // header + 2 records
        drop(j);
        assert_eq!(Journal::open_read_only(&path).unwrap().len(), 2);
        cleanup(&path);
    }

    #[test]
    fn duplicate_records_are_ignored_across_sessions() {
        let s = space();
        let path = tmp_path("dedup");
        cleanup(&path);
        let p = s.default_point();
        let key = PointKey::of(&s, &p);
        let m = measure_point(&s, &p);

        let mut j = Journal::open(&path).unwrap();
        j.record("vta-sim", &key, &m);
        j.record("vta-sim", &key, &m); // same session duplicate
        j.record("analytical", &key, &m); // different backend: distinct
        assert_eq!(j.len(), 2);
        j.flush().unwrap();
        drop(j);

        // A second session re-recording the same identity must not grow
        // the file.
        let mut j2 = Journal::open(&path).unwrap();
        assert_eq!(j2.len(), 2);
        j2.record("vta-sim", &key, &m);
        assert_eq!(j2.len(), 2);
        j2.flush().unwrap();
        drop(j2);
        assert_eq!(Journal::open_read_only(&path).unwrap().len(), 2);
        cleanup(&path);
    }

    #[test]
    fn unreadable_journal_degrades_to_empty() {
        let path = tmp_path("garbage");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        std::fs::write(&path, "not json {").unwrap();
        let j = Journal::open(&path).unwrap();
        assert!(j.is_empty());
        cleanup(&path);
    }

    #[test]
    fn second_writer_fails_fast() {
        let path = tmp_path("lock");
        cleanup(&path);
        let first = Journal::open(&path).unwrap();
        let err = Journal::open(&path).unwrap_err().to_string();
        assert!(err.contains("locked"), "unexpected error: {err}");
        // Read-only opens are not writers and need no lock.
        assert!(Journal::open_read_only(&path).is_ok());
        drop(first);
        // Lock released on drop: a new writer may open.
        let again = Journal::open(&path).unwrap();
        drop(again);
        cleanup(&path);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn stale_lock_from_dead_pid_is_reclaimed() {
        let path = tmp_path("stale_lock");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        // A pid far above any default pid_max: verifiably not running.
        std::fs::write(sibling(&path, ".lock"), "4294967294\n").unwrap();
        let j = Journal::open(&path).unwrap();
        drop(j);
        // An unparsable sentinel is never reclaimed.
        std::fs::write(sibling(&path, ".lock"), "not-a-pid\n").unwrap();
        assert!(Journal::open(&path).is_err());
        let _ = std::fs::remove_file(sibling(&path, ".lock"));

        // Compaction shares the same acquisition: a dead writer's sentinel
        // is reclaimed, a live/unverifiable one fails fast.
        let _ = write_journal(&path, "vta-sim", 63, 2);
        std::fs::write(sibling(&path, ".lock"), "4294967294\n").unwrap();
        assert!(compact_journal(&path).is_ok());
        assert!(!sibling(&path, ".lock").exists());
        std::fs::write(sibling(&path, ".lock"), "not-a-pid\n").unwrap();
        assert!(compact_journal(&path).unwrap_err().to_string().contains("locked"));
        cleanup(&path);
    }

    #[test]
    fn fingerprint_mismatch_is_refused() {
        let path = tmp_path("fingerprint");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        let mut fp = Fingerprint::current();
        fp.cycle_model += 1;
        let header = Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num(Journal::VERSION as f64)),
            ("fingerprint", fp.to_json()),
        ]);
        std::fs::write(&path, header.dump() + "\n").unwrap();
        let err = Journal::open(&path).unwrap_err().to_string();
        assert!(err.contains("different simulator"), "unexpected error: {err}");
        // The refused open must not leak its lock sentinel.
        assert!(!sibling(&path, ".lock").exists());
        cleanup(&path);
    }

    #[test]
    fn v1_journal_is_refused_with_migration_hint() {
        let path = tmp_path("v1");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        std::fs::write(&path, "{\n  \"version\": 1,\n  \"entries\": []\n}\n").unwrap();
        let err = Journal::open(&path).unwrap_err().to_string();
        assert!(err.contains("v1"), "unexpected error: {err}");
        cleanup(&path);
    }

    /// Write a v2 journal at `path` holding `n` distinct points measured
    /// under `backend`, returning the identities written.
    fn write_journal(path: &Path, backend: &str, seed: u64, n: usize) -> Vec<PointKey> {
        cleanup(path);
        let s = space();
        let mut rng = Pcg32::seeded(seed);
        let mut j = Journal::open(path).unwrap();
        let mut keys = Vec::new();
        while keys.len() < n {
            let p = s.random_point(&mut rng);
            let key = PointKey::of(&s, &p);
            if j.record(backend, &key, &measure_point(&s, &p)) {
                keys.push(key);
            }
        }
        j.flush().unwrap();
        keys
    }

    #[test]
    fn merge_unions_and_dedups_overlapping_inputs() {
        let a = tmp_path("merge_a");
        let b = tmp_path("merge_b");
        let out = tmp_path("merge_out");
        cleanup(&out);
        let keys_a = write_journal(&a, "vta-sim", 101, 5);
        let keys_b = write_journal(&b, "vta-sim", 101, 8); // same seed: first 5 overlap a
        assert_eq!(&keys_b[..5], &keys_a[..]);

        let stats = merge_journals(&out, &[a.clone(), b.clone()]).unwrap();
        assert_eq!(stats.inputs, 2);
        assert_eq!(stats.read, 13);
        assert_eq!(stats.added, 8, "union of overlapping inputs");
        assert_eq!(stats.duplicates, 5);
        assert_eq!(stats.total, 8);
        let merged = Journal::open_read_only(&out).unwrap();
        assert_eq!(merged.len(), 8);

        // Idempotent re-merge: nothing new, file byte-identical.
        let before = std::fs::read_to_string(&out).unwrap();
        let again = merge_journals(&out, &[a.clone(), b.clone()]).unwrap();
        assert_eq!(again.added, 0);
        assert_eq!(again.duplicates, 13);
        assert_eq!(again.total, 8);
        assert_eq!(std::fs::read_to_string(&out).unwrap(), before);
        cleanup(&a);
        cleanup(&b);
        cleanup(&out);
    }

    #[test]
    fn merge_rejects_empty_input_list_and_missing_inputs() {
        let out = tmp_path("merge_empty");
        cleanup(&out);
        let err = merge_journals(&out, &[]).unwrap_err().to_string();
        assert!(err.contains("at least one input"), "unexpected error: {err}");
        assert!(!out.exists(), "a refused merge must not create the output");

        let missing = tmp_path("merge_missing_input");
        cleanup(&missing);
        let err = merge_journals(&out, &[missing.clone()]).unwrap_err().to_string();
        assert!(err.contains("does not exist"), "unexpected error: {err}");
        cleanup(&out);
    }

    #[test]
    fn merge_refuses_fingerprint_mismatched_and_v1_inputs() {
        let out = tmp_path("merge_fp_out");
        let foreign = tmp_path("merge_fp_in");
        cleanup(&out);
        cleanup(&foreign);
        if let Some(parent) = foreign.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        let mut fp = Fingerprint::current();
        fp.cycle_model += 1;
        let header = Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num(Journal::VERSION as f64)),
            ("fingerprint", fp.to_json()),
        ]);
        std::fs::write(&foreign, header.dump() + "\n").unwrap();
        let err = merge_journals(&out, &[foreign.clone()]).unwrap_err().to_string();
        assert!(err.contains("different simulator"), "unexpected error: {err}");
        // The refused merge must not leave a writer lock on the output.
        assert!(!sibling(&out, ".lock").exists());

        std::fs::write(&foreign, "{\n  \"version\": 1,\n  \"entries\": []\n}\n").unwrap();
        let err = merge_journals(&out, &[foreign.clone()]).unwrap_err().to_string();
        assert!(err.contains("v1"), "unexpected error: {err}");
        cleanup(&out);
        cleanup(&foreign);
    }

    #[test]
    fn merge_tolerates_torn_tail_inputs() {
        let input = tmp_path("merge_torn_in");
        let out = tmp_path("merge_torn_out");
        cleanup(&out);
        write_journal(&input, "vta-sim", 77, 3);
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&input).unwrap();
            f.write_all(b"{\"backend\":\"vta-sim\",\"task\":{\"n\":").unwrap();
        }
        let stats = merge_journals(&out, &[input.clone()]).unwrap();
        assert_eq!(stats.read, 3, "the torn line must be dropped, not merged");
        assert_eq!(stats.added, 3);
        assert_eq!(Journal::open_read_only(&out).unwrap().len(), 3);
        cleanup(&input);
        cleanup(&out);
    }

    #[test]
    fn merge_of_empty_inputs_materializes_a_valid_header_only_journal() {
        let out = tmp_path("merge_hdr_out");
        cleanup(&out);
        // An existing-but-record-less input: a bare v2 header.
        let header_only = tmp_path("merge_hdr_empty");
        cleanup(&header_only);
        if let Some(parent) = header_only.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        let header = Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num(Journal::VERSION as f64)),
            ("fingerprint", Fingerprint::current().to_json()),
        ]);
        std::fs::write(&header_only, header.dump() + "\n").unwrap();
        let stats = merge_journals(&out, &[header_only.clone()]).unwrap();
        assert_eq!(stats.added, 0);
        assert!(out.exists(), "even an all-empty merge must materialize the output");
        assert!(Journal::open_read_only(&out).unwrap().is_empty());
        cleanup(&header_only);
        cleanup(&out);
    }

    #[test]
    fn compact_drops_duplicates_and_is_idempotent() {
        let path = tmp_path("compact_dup");
        let keys = write_journal(&path, "vta-sim", 61, 4);
        assert_eq!(keys.len(), 4);
        // Simulate journals concatenated by hand / duplicated flushes: the
        // last two record lines appended again, plus a torn tail.
        let text = std::fs::read_to_string(&path).unwrap();
        let records: Vec<&str> = text.lines().skip(1).collect();
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            writeln!(f, "{}", records[2]).unwrap();
            writeln!(f, "{}", records[3]).unwrap();
            f.write_all(b"{\"backend\":\"vta-sim\",\"task\":{\"n\":").unwrap();
        }

        let stats = compact_journal(&path).unwrap();
        assert_eq!(stats.read, 7, "4 originals + 2 duplicates + 1 torn line");
        assert_eq!(stats.kept, 4);
        assert_eq!(stats.dropped_duplicates, 2);
        assert_eq!(stats.dropped_malformed, 1);
        assert_eq!(stats.dropped_stale, 0);
        assert!(stats.rewritten);
        // The compacted file is a healthy journal holding the 4 identities.
        let j = Journal::open_read_only(&path).unwrap();
        assert_eq!(j.len(), 4);
        // No writer lock left behind.
        assert!(!sibling(&path, ".lock").exists());

        // Compacting a compact journal is a byte-level no-op.
        let before = std::fs::read_to_string(&path).unwrap();
        let again = compact_journal(&path).unwrap();
        assert_eq!(again.read, 4);
        assert_eq!(again.kept, 4);
        assert_eq!(again.dropped(), 0);
        assert!(!again.rewritten, "an already-compact journal must not be rewritten");
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before);
        cleanup(&path);
    }

    #[test]
    fn compact_drops_foreign_fingerprint_records_wholesale() {
        let path = tmp_path("compact_foreign");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        // A journal stamped by a bumped cycle model, holding one record:
        // nothing in it can be trusted by this binary.
        let s = space();
        let p = s.default_point();
        let key = PointKey::of(&s, &p);
        let mut fp = Fingerprint::current();
        fp.cycle_model += 1;
        let header = Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num(Journal::VERSION as f64)),
            ("fingerprint", fp.to_json()),
        ]);
        let record = record_to_json("vta-sim", &key, &measure_point(&s, &p));
        std::fs::write(&path, format!("{}\n{}\n", header.dump(), record.dump())).unwrap();

        // Journal::open refuses the file outright...
        assert!(Journal::open(&path).is_err());
        // ...compaction is the sanctioned cleanup: stale records dropped,
        // the file reborn as a valid empty journal under this fingerprint.
        let stats = compact_journal(&path).unwrap();
        assert_eq!(stats.read, 1);
        assert_eq!(stats.kept, 0);
        assert_eq!(stats.dropped_stale, 1);
        assert!(stats.rewritten);
        let j = Journal::open(&path).unwrap();
        assert!(j.is_empty());
        drop(j);
        cleanup(&path);
    }

    #[test]
    fn compact_converts_v1_to_empty_v2() {
        let path = tmp_path("compact_v1");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        std::fs::write(&path, "{\n  \"version\": 1,\n  \"entries\": [{}, {}]\n}\n").unwrap();
        let stats = compact_journal(&path).unwrap();
        assert_eq!(stats.dropped_stale, 2, "v1 records carry no fingerprint: all stale");
        assert_eq!(stats.kept, 0);
        assert!(stats.rewritten);
        // The unfingerprinted v1 file, which open() refused, is now a
        // valid empty v2 journal.
        assert!(Journal::open_read_only(&path).unwrap().is_empty());
        cleanup(&path);
    }

    #[test]
    fn compact_refuses_files_that_are_not_journals() {
        // GC must never destroy data it cannot positively identify as
        // journal records: a typo'd path (some results JSON), a torn
        // header, or a future format version are refused, not wiped.
        let path = tmp_path("compact_not_a_journal");
        cleanup(&path);
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent).unwrap();
        }
        for content in [
            "{\"model\": \"alexnet\", \"outcomes\": []}\n", // some other JSON file
            "not json at all {\n",                          // garbage / torn header
        ] {
            std::fs::write(&path, content).unwrap();
            let err = compact_journal(&path).unwrap_err().to_string();
            assert!(err.contains("not a measurement journal"), "unexpected error: {err}");
            assert_eq!(std::fs::read_to_string(&path).unwrap(), content, "file must be untouched");
            assert!(!sibling(&path, ".lock").exists(), "refusal must not leak the lock");
        }
        // A future journal version is refused too.
        let header = Json::obj(vec![
            ("format", Json::str("arco-journal")),
            ("version", Json::num((Journal::VERSION + 1) as f64)),
            ("fingerprint", Fingerprint::current().to_json()),
        ]);
        std::fs::write(&path, header.dump() + "\n").unwrap();
        let err = compact_journal(&path).unwrap_err().to_string();
        assert!(err.contains("refusing to touch"), "unexpected error: {err}");
        cleanup(&path);
    }

    #[test]
    fn compact_refuses_missing_and_locked_files() {
        let missing = tmp_path("compact_missing");
        cleanup(&missing);
        let err = compact_journal(&missing).unwrap_err().to_string();
        assert!(err.contains("does not exist"), "unexpected error: {err}");

        let locked = tmp_path("compact_locked");
        let _ = write_journal(&locked, "vta-sim", 62, 2);
        let writer = Journal::open(&locked).unwrap();
        let err = compact_journal(&locked).unwrap_err().to_string();
        assert!(err.contains("locked"), "unexpected error: {err}");
        drop(writer);
        // Once the writer is gone, compaction proceeds (and the journal
        // was already compact).
        assert!(!compact_journal(&locked).unwrap().rewritten);
        cleanup(&locked);
    }

    #[test]
    fn torn_tail_line_is_dropped_and_compacted() {
        let s = space();
        let path = tmp_path("torn");
        cleanup(&path);
        let p = s.default_point();
        let key = PointKey::of(&s, &p);
        let m = measure_point(&s, &p);
        let mut j = Journal::open(&path).unwrap();
        j.record("vta-sim", &key, &m);
        j.flush().unwrap();
        drop(j);

        // Simulate a crash mid-append: half a record, no newline.
        {
            let mut f = std::fs::OpenOptions::new().append(true).open(&path).unwrap();
            f.write_all(b"{\"backend\":\"vta-sim\",\"task\":{\"n\":").unwrap();
        }
        let mut j2 = Journal::open(&path).unwrap();
        assert_eq!(j2.len(), 1, "torn line must be dropped");
        j2.record("analytical", &key, &m);
        j2.flush().unwrap();
        drop(j2);

        let j3 = Journal::open_read_only(&path).unwrap();
        assert_eq!(j3.len(), 2, "compacted journal must carry both records");
        cleanup(&path);
    }
}
