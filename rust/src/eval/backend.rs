//! Measurement backends: *how* one configuration gets a number.

use crate::codegen::{measure_point, MeasureResult};
use crate::marl::env::memory_overflow_ratio;
use crate::space::{ConfigSpace, PointConfig};
use crate::util::json::Json;
use crate::util::pool::parallel_map;
use crate::util::stats::ceil_div;
use crate::vta::area::total_area_mm2;
use crate::vta::config::{INP_BYTES, OUT_BYTES, WGT_BYTES};

/// How a remote fleet splits each measurement batch across its alive
/// shards. Local backends ignore it.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Placement {
    /// Equal-size contiguous chunks, one per alive shard — the
    /// reproducible default: placement never depends on observed timings,
    /// so two runs of the same fleet chunk identically.
    #[default]
    Uniform,
    /// Chunk sizes proportional to estimated shard throughput: a per-point
    /// service-time EWMA per shard, discounted by the queue depth the
    /// shard's `stats` op reports. Heterogeneous fleets finish batches
    /// sooner; measured *numbers* are identical either way (placement only
    /// decides which deterministic shard runs which point).
    Weighted,
}

impl Placement {
    pub fn name(self) -> &'static str {
        match self {
            Placement::Uniform => "uniform",
            Placement::Weighted => "weighted",
        }
    }

    pub fn from_name(s: &str) -> Option<Placement> {
        match s {
            "uniform" => Some(Placement::Uniform),
            "weighted" => Some(Placement::Weighted),
            _ => None,
        }
    }

    /// All selectable names, for CLI error messages.
    pub fn known_names() -> &'static [&'static str] {
        &["uniform", "weighted"]
    }
}

/// Per-shard placement counters a remote fleet reports (empty for local
/// backends): where the points went and the evidence behind the choice.
#[derive(Debug, Clone)]
pub struct ShardPlacement {
    pub addr: String,
    pub alive: bool,
    /// Batch chunks this shard served.
    pub batches: usize,
    /// Points this shard served.
    pub points: usize,
    /// EWMA of observed service seconds per point (`None` before the
    /// shard's first successfully served chunk).
    pub ewma_secs_per_point: Option<f64>,
    /// Queue depth (`active_batches`) the shard last reported.
    pub queue_depth: usize,
    /// Cache entries the shard reported preloaded at handshake (journal +
    /// warm start) — the fleet history it inherited.
    pub preloaded: usize,
}

impl ShardPlacement {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("addr", Json::str(self.addr.clone())),
            ("alive", Json::Bool(self.alive)),
            ("batches", Json::num(self.batches as f64)),
            ("points", Json::num(self.points as f64)),
            (
                "ewma_secs_per_point",
                match self.ewma_secs_per_point {
                    Some(s) => Json::num(s),
                    None => Json::Null,
                },
            ),
            ("queue_depth", Json::num(self.queue_depth as f64)),
            ("preloaded", Json::num(self.preloaded as f64)),
        ])
    }
}

/// One way of measuring a configuration. Implementations must be pure
/// functions of `(space, point)` — the engine relies on determinism for
/// caching and for order-independent parallel fan-out — and `Send + Sync`
/// so the engine can share them across worker threads.
pub trait MeasureBackend: Send + Sync {
    /// Stable backend id (used for journal entries and diagnostics).
    fn name(&self) -> &'static str;

    /// Measure one point. Invalid configurations return
    /// `MeasureResult { valid: false, .. }` rather than erroring.
    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult;

    /// Measure a batch of unique points, results in input order. The
    /// default fans [`measure`](Self::measure) out over up to `workers`
    /// local threads; backends that own their parallelism (a remote fleet
    /// sharding the batch across hosts) override this instead.
    fn measure_many(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> Vec<MeasureResult> {
        parallel_map(points, workers, |_, p| self.measure(space, p))
    }

    /// Like [`measure_many`](Self::measure_many), but also reports per
    /// point whether this backend *freshly* computed the number (`true`)
    /// or answered it from shared state someone else already paid for —
    /// e.g. a fleet shard's cache (`false`). Local backends hold no shared
    /// state, so the default reports everything fresh.
    fn measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> (Vec<MeasureResult>, Vec<bool>) {
        let results = self.measure_many(space, points, workers);
        let fresh = vec![true; results.len()];
        (results, fresh)
    }

    /// Fallible [`measure_many_traced`](Self::measure_many_traced): the
    /// variant the engine actually calls. Local backends cannot lose their
    /// measurement substrate, so the default is infallible; a remote fleet
    /// returns a typed [`super::remote::FleetLostError`] when no shard can
    /// serve — the whole-fleet-outage case — instead of panicking, so a
    /// tuning run can fail cleanly.
    fn try_measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
        Ok(self.measure_many_traced(space, points, workers))
    }

    /// How many measurement batches this backend can usefully serve
    /// concurrently. A local backend already saturates its worker pool
    /// with one batch; a remote fleet can serve one batch per alive shard.
    /// The multi-tenant dispatcher sizes its admission slots from this.
    fn concurrent_batch_capacity(&self) -> usize {
        1
    }

    /// Remote fleets: one `stats` snapshot per alive shard (address,
    /// free-form counters object). Local backends have no fleet.
    fn fleet_stats(&self) -> Vec<(String, Json)> {
        Vec::new()
    }

    /// Remote fleets: per-shard placement counters (points/batches served,
    /// service-time EWMA, queue depth, warm-start coverage). Local
    /// backends have no shards.
    fn placement_stats(&self) -> Vec<ShardPlacement> {
        Vec::new()
    }
}

/// Shared handles to a backend are backends: lets a caller keep a handle
/// to a fleet client (to probe revival, read placement counters) while an
/// [`super::Engine`] owns another.
impl<B: MeasureBackend + ?Sized> MeasureBackend for std::sync::Arc<B> {
    fn name(&self) -> &'static str {
        (**self).name()
    }

    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        (**self).measure(space, point)
    }

    fn measure_many(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> Vec<MeasureResult> {
        (**self).measure_many(space, points, workers)
    }

    fn measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> (Vec<MeasureResult>, Vec<bool>) {
        (**self).measure_many_traced(space, points, workers)
    }

    fn try_measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
        (**self).try_measure_many_traced(space, points, workers)
    }

    fn concurrent_batch_capacity(&self) -> usize {
        (**self).concurrent_batch_capacity()
    }

    fn fleet_stats(&self) -> Vec<(String, Json)> {
        (**self).fleet_stats()
    }

    fn placement_stats(&self) -> Vec<ShardPlacement> {
        (**self).placement_stats()
    }
}

/// Which built-in backend to use (config / CLI selectable).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Full decode → lower → VTA++ cycle simulation (the production oracle).
    VtaSim,
    /// Cheap roofline proxy (smoke tests, CI scenarios, huge sweeps).
    Analytical,
}

impl BackendKind {
    pub fn name(self) -> &'static str {
        match self {
            BackendKind::VtaSim => "vta-sim",
            BackendKind::Analytical => "analytical",
        }
    }

    pub fn from_name(s: &str) -> Option<BackendKind> {
        match s {
            "vta-sim" | "vtasim" | "sim" => Some(BackendKind::VtaSim),
            "analytical" | "roofline" => Some(BackendKind::Analytical),
            _ => None,
        }
    }

    /// All selectable names, for CLI error messages.
    pub fn known_names() -> &'static [&'static str] {
        &["vta-sim", "analytical"]
    }

    pub fn build(self) -> Box<dyn MeasureBackend> {
        match self {
            BackendKind::VtaSim => Box::new(VtaSimBackend),
            BackendKind::Analytical => Box::new(AnalyticalBackend),
        }
    }
}

/// Full backend selection: a built-in local backend, or a fleet of remote
/// `arco serve-measure` shards (`remote:host:port[,host:port...]`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum BackendSpec {
    /// An in-process backend.
    Builtin(BackendKind),
    /// Shard addresses of a remote measurement fleet.
    Remote(Vec<String>),
}

impl BackendSpec {
    /// Parse a CLI/config backend string: a [`BackendKind`] name, or
    /// `remote:` followed by comma-separated `host:port` shard addresses.
    pub fn parse(s: &str) -> Option<BackendSpec> {
        if let Some(rest) = s.strip_prefix("remote:") {
            let addrs: Vec<String> = rest
                .split(',')
                .map(|a| a.trim().to_string())
                .filter(|a| !a.is_empty())
                .collect();
            if addrs.is_empty() || addrs.iter().any(|a| !a.contains(':')) {
                return None;
            }
            return Some(BackendSpec::Remote(addrs));
        }
        BackendKind::from_name(s).map(BackendSpec::Builtin)
    }

    /// Human-readable selection (CLI diagnostics).
    pub fn describe(&self) -> String {
        match self {
            BackendSpec::Builtin(k) => k.name().to_string(),
            BackendSpec::Remote(addrs) => format!("remote:{}", addrs.join(",")),
        }
    }

    /// Build the backend. Remote fleets handshake with every shard here,
    /// so a bad address, protocol skew or fingerprint mismatch fails fast.
    pub fn build(&self) -> anyhow::Result<Box<dyn MeasureBackend>> {
        self.build_with(Placement::default())
    }

    /// [`build`](Self::build) with an explicit fleet [`Placement`] policy
    /// (ignored by built-in local backends).
    pub fn build_with(&self, placement: Placement) -> anyhow::Result<Box<dyn MeasureBackend>> {
        match self {
            BackendSpec::Builtin(k) => Ok(k.build()),
            BackendSpec::Remote(addrs) => {
                Ok(Box::new(super::remote::RemoteBackend::connect_with(addrs, placement)?))
            }
        }
    }
}

impl From<BackendKind> for BackendSpec {
    fn from(kind: BackendKind) -> BackendSpec {
        BackendSpec::Builtin(kind)
    }
}

/// The cycle-accurate oracle: wraps [`crate::codegen::measure_point`]
/// (decode the point, lower the convolution, simulate the instruction
/// stream on the VTA++ pipeline model).
#[derive(Debug, Clone, Copy, Default)]
pub struct VtaSimBackend;

impl MeasureBackend for VtaSimBackend {
    fn name(&self) -> &'static str {
        "vta-sim"
    }

    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        measure_point(space, point)
    }
}

/// Version of the analytical roofline formulas. Bump on any change that
/// can alter [`AnalyticalBackend`] numbers (e.g. recalibrating the overlap
/// coefficients): it is part of the measurement [`super::proto::Fingerprint`],
/// so stale analytical journals and skewed analytical shards are refused
/// the same way cycle-model drift is.
///
/// *Online* calibration ([`super::calib::Calibration`]) deliberately does
/// NOT require a bump: it only affects screening estimates that are never
/// journaled, while [`MeasureBackend::measure`] keeps producing the seed
/// (uncalibrated) numbers this version stamps.
pub const ANALYTICAL_MODEL_VERSION: u32 = 1;

/// Seed overlap coefficients, indexed by vthread class (`[single, dual]`):
/// the fraction of the smaller roofline term that load/compute overlap
/// hides. These are the historical hard-coded constants; online
/// calibration ([`super::calib::Calibration`]) starts from them and
/// refines them per task against fresh cycle-model observations.
pub const SEED_OVERLAP: [f64; 2] = [0.60, 0.85];

/// The decomposed pieces of one analytical roofline evaluation — every
/// input the final cycle count needs, *except* the overlap coefficient.
/// This is the seam online calibration fits against: the model is
/// `cycles = serial_cycles + (1 - overlap) * overlap_cycles`, linear in
/// the unknown `(1 - overlap)` per vthread class.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AnalyticalTerms {
    /// `max(compute, dram)` cycles — the roofline floor no overlap removes.
    pub serial_cycles: f64,
    /// `min(compute, dram)` cycles — the term overlap (partially) hides.
    pub overlap_cycles: f64,
    /// Virtual-thread count, clamped to `[1, 2]` (selects the overlap class).
    pub vthreads: usize,
    /// Accelerator area (mm²), valid or not.
    pub area_mm2: f64,
    /// GEMM-array occupancy (true MACs / padded MACs).
    pub occupancy: f64,
    /// Seconds per cycle at the configured clock.
    pub cycle_time: f64,
    /// Task FLOPs, for the GFLOPS readout.
    pub flops: f64,
    /// Structurally buildable? Invalid terms carry only `area_mm2`.
    pub valid: bool,
}

impl AnalyticalTerms {
    /// Overlap-coefficient class this point falls in: `0` single-threaded,
    /// `1` dual virtual threads — the index into [`SEED_OVERLAP`] and into
    /// a calibration's fitted coefficients.
    pub fn class(&self) -> usize {
        usize::from(self.vthreads >= 2)
    }

    /// Assemble the [`MeasureResult`] under explicit overlap coefficients
    /// (`[single, dual]`). `result_with(SEED_OVERLAP)` reproduces the
    /// uncalibrated backend bit for bit.
    pub fn result_with(&self, overlaps: [f64; 2]) -> MeasureResult {
        if !self.valid {
            return MeasureResult {
                seconds: f64::INFINITY,
                cycles: 0,
                gflops: 0.0,
                area_mm2: self.area_mm2,
                occupancy: 0.0,
                valid: false,
            };
        }
        let overlap = overlaps[self.class()];
        let cycles = self.serial_cycles + (1.0 - overlap) * self.overlap_cycles;
        let seconds = cycles * self.cycle_time;
        MeasureResult {
            seconds,
            cycles: cycles as u64,
            gflops: self.flops / seconds / 1e9,
            area_mm2: self.area_mm2,
            occupancy: self.occupancy,
            valid: true,
        }
    }
}

/// Decompose one point into its roofline terms (see [`AnalyticalTerms`]).
/// Pure function of `(space, point)`, a few hundred nanoseconds per call.
pub fn analytical_terms(space: &ConfigSpace, point: &PointConfig) -> AnalyticalTerms {
    let (hw, sw) = space.decode(point);
    let area_mm2 = total_area_mm2(&hw);
    // Same validity surface as the lowering path: structurally bad
    // hardware or tile working sets that overflow a scratchpad
    // partition cannot be built.
    if hw.validate().is_err() || memory_overflow_ratio(space, point) > 0.0 {
        return AnalyticalTerms {
            serial_cycles: 0.0,
            overlap_cycles: 0.0,
            vthreads: 1,
            area_mm2,
            occupancy: 0.0,
            cycle_time: 0.0,
            flops: 0.0,
            valid: false,
        };
    }

    let t = &space.task;
    // Padded problem dims on the GEMM array.
    let pad_n = ceil_div(t.n, hw.batch) * hw.batch;
    let pad_ci = ceil_div(t.ci, hw.block_in) * hw.block_in;
    let pad_co = ceil_div(t.co, hw.block_out) * hw.block_out;
    let true_macs = t.macs() as f64;
    let padded_macs = (pad_n * pad_co * t.oh() * t.ow()) as f64 * (pad_ci * t.kh * t.kw) as f64;
    let occupancy = true_macs / padded_macs;
    let compute_cycles = padded_macs / hw.macs_per_cycle() as f64;

    // DRAM traffic: inputs and outputs stream once; weights re-stream
    // once per spatial tile (the scratchpad holds one tile's working
    // set); every tile pays three DMA setup latencies.
    let tiles = ceil_div(t.oh(), sw.tile_h.max(1)) * ceil_div(t.ow(), sw.tile_w.max(1));
    let tiles = tiles.max(1);
    let inp_bytes = (pad_n * pad_ci * t.h * t.w * INP_BYTES) as f64;
    let wgt_bytes = (pad_co * pad_ci * t.kh * t.kw * WGT_BYTES) as f64 * tiles as f64;
    let out_bytes = (pad_n * pad_co * t.oh() * t.ow() * OUT_BYTES) as f64;
    let dram_cycles = (inp_bytes + wgt_bytes + out_bytes) / hw.dram_bytes_per_cycle as f64
        + (3 * tiles * hw.dma_latency) as f64;

    // Virtual threads overlap load/compute; a single thread exposes
    // more of the smaller term.
    let vthreads = (sw.h_threading * sw.oc_threading).clamp(1, 2);
    AnalyticalTerms {
        serial_cycles: compute_cycles.max(dram_cycles),
        overlap_cycles: compute_cycles.min(dram_cycles),
        vthreads,
        area_mm2,
        occupancy,
        cycle_time: hw.cycle_time(),
        flops: t.flops() as f64,
        valid: true,
    }
}

/// A roofline-style analytical proxy: a few hundred nanoseconds per point
/// instead of a full instruction-stream simulation.
///
/// The model charges `max(compute, DRAM)` cycles plus a fraction of the
/// smaller term that virtual threading fails to overlap. It preserves the
/// qualitative structure the tuners care about — GEMM padding waste from
/// mismatched geometry, weight re-streaming per spatial tile, scratchpad
/// overflow invalidity, GFLOPS bounded by the configured peak — without
/// claiming cycle accuracy. Use it for smoke runs and scenario sweeps; the
/// paper's numbers come from [`VtaSimBackend`].
#[derive(Debug, Clone, Copy, Default)]
pub struct AnalyticalBackend;

impl AnalyticalBackend {
    /// Measure under explicit overlap coefficients — the screening path,
    /// which gets per-task fitted coefficients from a
    /// [`super::calib::Calibration`] instead of the seeds.
    pub fn measure_with_overlaps(
        space: &ConfigSpace,
        point: &PointConfig,
        overlaps: [f64; 2],
    ) -> MeasureResult {
        analytical_terms(space, point).result_with(overlaps)
    }
}

impl MeasureBackend for AnalyticalBackend {
    fn name(&self) -> &'static str {
        "analytical"
    }

    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        // Always the *seed* coefficients: backend numbers are journaled
        // under ANALYTICAL_MODEL_VERSION and must not drift with whatever
        // a run's online calibration has learned.
        AnalyticalBackend::measure_with_overlaps(space, point, SEED_OVERLAP)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1), true)
    }

    #[test]
    fn kind_roundtrips_names() {
        for k in [BackendKind::VtaSim, BackendKind::Analytical] {
            assert_eq!(BackendKind::from_name(k.name()), Some(k));
            assert_eq!(k.build().name(), k.name());
        }
        assert_eq!(BackendKind::from_name("bogus"), None);
    }

    #[test]
    fn every_accepted_backend_spelling_roundtrips() {
        // The documented flags table lists every alias; this pins the set
        // so a new spelling (or a dropped one) must update the docs too.
        let spellings = [
            ("vta-sim", BackendKind::VtaSim),
            ("vtasim", BackendKind::VtaSim),
            ("sim", BackendKind::VtaSim),
            ("analytical", BackendKind::Analytical),
            ("roofline", BackendKind::Analytical),
        ];
        for (s, want) in spellings {
            assert_eq!(BackendKind::from_name(s), Some(want), "alias {s}");
            // Every alias lands on a kind whose canonical name re-parses
            // to itself — the round trip.
            let canon = want.name();
            assert_eq!(BackendKind::from_name(canon), Some(want));
            assert_eq!(BackendSpec::parse(s), Some(BackendSpec::Builtin(want)));
        }
        // Canonical names are exactly the advertised ones.
        assert_eq!(BackendKind::known_names(), &["vta-sim", "analytical"]);
    }

    #[test]
    fn seed_overlap_terms_reproduce_the_backend_exactly() {
        let s = space();
        let b = AnalyticalBackend;
        let mut rng = Pcg32::seeded(11);
        for _ in 0..100 {
            let p = s.random_point(&mut rng);
            let via_terms = analytical_terms(&s, &p).result_with(SEED_OVERLAP);
            assert_eq!(via_terms, b.measure(&s, &p));
        }
        // Calibrated overlaps move the numbers; the seed path must not.
        let p = s.default_point();
        let warped = AnalyticalBackend::measure_with_overlaps(&s, &p, [0.0, 0.0]);
        let seeded = b.measure(&s, &p);
        assert!(warped.seconds >= seeded.seconds);
    }

    #[test]
    fn placement_roundtrips_names_and_defaults_uniform() {
        for p in [Placement::Uniform, Placement::Weighted] {
            assert_eq!(Placement::from_name(p.name()), Some(p));
        }
        assert_eq!(Placement::from_name("bogus"), None);
        assert_eq!(Placement::default(), Placement::Uniform);
        // The reproducibility default must never drift silently.
        assert_eq!(Placement::default().name(), "uniform");
    }

    #[test]
    fn arc_wrapped_backend_delegates() {
        let s = space();
        let b = std::sync::Arc::new(VtaSimBackend);
        assert_eq!(MeasureBackend::name(&b), "vta-sim");
        let p = s.default_point();
        assert_eq!(MeasureBackend::measure(&b, &s, &p), measure_point(&s, &p));
        assert_eq!(b.concurrent_batch_capacity(), 1);
        assert!(b.placement_stats().is_empty());
        let (rs, fresh) = b.try_measure_many_traced(&s, std::slice::from_ref(&p), 1).unwrap();
        assert_eq!(rs[0], measure_point(&s, &p));
        assert_eq!(fresh, vec![true]);
    }

    #[test]
    fn spec_parses_builtin_and_remote() {
        assert_eq!(
            BackendSpec::parse("vta-sim"),
            Some(BackendSpec::Builtin(BackendKind::VtaSim))
        );
        assert_eq!(
            BackendSpec::parse("remote:127.0.0.1:4917"),
            Some(BackendSpec::Remote(vec!["127.0.0.1:4917".into()]))
        );
        let multi = BackendSpec::parse("remote:a:1, b:2").unwrap();
        assert_eq!(multi, BackendSpec::Remote(vec!["a:1".into(), "b:2".into()]));
        assert_eq!(multi.describe(), "remote:a:1,b:2");
        assert_eq!(BackendSpec::parse("remote:"), None);
        assert_eq!(BackendSpec::parse("remote:no-port"), None);
        assert_eq!(BackendSpec::parse("bogus"), None);
    }

    #[test]
    fn measure_many_default_matches_pointwise() {
        let s = space();
        let b = VtaSimBackend;
        let mut rng = Pcg32::seeded(7);
        let points: Vec<_> = (0..12).map(|_| s.random_point(&mut rng)).collect();
        for workers in [1, 4] {
            let batch = b.measure_many(&s, &points, workers);
            for (p, r) in points.iter().zip(&batch) {
                assert_eq!(*r, b.measure(&s, p));
            }
        }
    }

    #[test]
    fn vta_sim_backend_is_measure_point() {
        let s = space();
        let b = VtaSimBackend;
        let mut rng = Pcg32::seeded(3);
        for _ in 0..10 {
            let p = s.random_point(&mut rng);
            assert_eq!(b.measure(&s, &p), measure_point(&s, &p));
        }
    }

    #[test]
    fn analytical_default_point_is_sane() {
        let s = space();
        let b = AnalyticalBackend;
        let m = b.measure(&s, &s.default_point());
        assert!(m.valid);
        assert!(m.seconds.is_finite() && m.seconds > 0.0);
        assert!(m.occupancy > 0.0 && m.occupancy <= 1.0);
        let (hw, _) = s.decode(&s.default_point());
        assert!(m.gflops > 0.0 && m.gflops <= hw.peak_gops() + 1e-9);
    }

    #[test]
    fn analytical_is_deterministic_and_varied() {
        let s = space();
        let b = AnalyticalBackend;
        let mut rng = Pcg32::seeded(5);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..100 {
            let p = s.random_point(&mut rng);
            let a = b.measure(&s, &p);
            assert_eq!(a, b.measure(&s, &p));
            if a.valid {
                distinct.insert(a.cycles);
            }
        }
        assert!(distinct.len() > 10, "landscape too flat: {}", distinct.len());
    }

    #[test]
    fn analytical_flags_overflowing_configs_invalid() {
        let s = space();
        let b = AnalyticalBackend;
        let mut p = s.default_point();
        // Max out every knob: guaranteed scratchpad overflow in this space.
        for (i, k) in s.knobs.iter().enumerate() {
            p.0[i] = k.len() - 1;
        }
        let m = b.measure(&s, &p);
        assert!(!m.valid);
        assert_eq!(m.fitness(), 0.0);
    }
}
