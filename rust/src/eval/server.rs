//! `arco serve-measure`: expose a local [`Engine`] to the network.
//!
//! A thin threaded TCP front-end over one shared measurement engine: the
//! accept loop hands each connection to its own thread, and every thread
//! funnels measure requests into the same [`Engine`] — so the shard-wide
//! cache, in-flight coalescing and journal all apply across clients. The
//! wire format is the JSONL protocol of [`super::proto`].
//!
//! Lifecycle: [`spawn`] binds and returns a [`ServerHandle`] (port 0 picks
//! a free port — the bound address is on the handle). `shutdown()` stops
//! the accept loop and joins it; in-flight connections finish their current
//! request and then drop. The CLI runs `spawn(...)` + `wait()`.

use super::engine::Engine;
use super::proto::{
    point_from_values, read_frame_line, request_from_line, write_response_frame, Fingerprint,
    Request, Response, PROTO_VERSION,
};
use crate::space::ConfigSpace;
use crate::util::json::Json;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

/// Shard behaviour knobs beyond the engine's own configuration.
#[derive(Debug, Clone, Copy)]
pub struct ServeOptions {
    /// Artificial service latency added *per point* of every measure
    /// request (`--throttle-ms`). Zero in production; non-zero turns a
    /// shard into a deterministic slowpoke for heterogeneous-fleet
    /// scenario tests and placement benchmarks — the latency is charged
    /// before the engine runs, so cached answers are throttled too, just
    /// like a genuinely slow host.
    pub measure_delay: Duration,
    /// Per-response write deadline. A client that requests a batch and
    /// then stops draining its socket would otherwise pin this
    /// connection's thread forever once the kernel send buffer fills;
    /// hitting the deadline ends the connection like a hangup. Zero
    /// disables the deadline. The default mirrors the client-side
    /// measure read timeout so neither end outwaits the other.
    pub write_timeout: Duration,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            measure_delay: Duration::ZERO,
            write_timeout: Duration::from_secs(600),
        }
    }
}

/// Hard ceiling on the *total* artificial delay charged to one request.
/// Keeps `--throttle-ms` proportional for realistic batches while making
/// a giant batch bounded instead of a multi-hour (or, unchecked, an
/// overflowing) sleep.
const MAX_BATCH_THROTTLE: Duration = Duration::from_secs(60);

/// Total throttle for a `points`-sized batch: a saturating per-point
/// multiply capped at [`MAX_BATCH_THROTTLE`]. `Duration * u32` panics on
/// overflow and `points.len()` silently truncates through `as u32` —
/// both reachable from the wire by a large enough batch.
fn throttle_duration(per_point: Duration, points: usize) -> Duration {
    per_point
        .saturating_mul(u32::try_from(points).unwrap_or(u32::MAX))
        .min(MAX_BATCH_THROTTLE)
}

/// A running measurement server.
pub struct ServerHandle {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    engine: Arc<Engine>,
    clients: Arc<AtomicUsize>,
    accept: Option<JoinHandle<()>>,
}

impl ServerHandle {
    /// The actually-bound address (resolves `:0` to the chosen port).
    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The engine serving this shard (stats, journal flush).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Connections currently being served (the `stats` op reports this to
    /// fleet clients as `active_connections`).
    pub fn active_connections(&self) -> usize {
        self.clients.load(Ordering::Relaxed)
    }

    /// Block until the accept loop exits (the CLI's serve-forever mode).
    pub fn wait(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    /// Stop accepting, join the accept loop, flush the journal.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // Nudge the blocking accept() with a throwaway connection.
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
        self.engine.flush_journal();
    }
}

/// Bind `addr` and serve `engine` until the handle is shut down.
pub fn spawn(addr: &str, engine: Arc<Engine>) -> anyhow::Result<ServerHandle> {
    spawn_with(addr, engine, ServeOptions::default())
}

/// [`spawn`] with explicit [`ServeOptions`].
pub fn spawn_with(
    addr: &str,
    engine: Arc<Engine>,
    opts: ServeOptions,
) -> anyhow::Result<ServerHandle> {
    let listener = TcpListener::bind(addr)
        .map_err(|e| anyhow::anyhow!("binding measure server to {addr}: {e}"))?;
    let bound = listener.local_addr()?;
    let stop = Arc::new(AtomicBool::new(false));
    let clients = Arc::new(AtomicUsize::new(0));
    let accept = {
        let stop = Arc::clone(&stop);
        let engine = Arc::clone(&engine);
        let clients = Arc::clone(&clients);
        std::thread::spawn(move || accept_loop(listener, engine, clients, stop, opts))
    };
    Ok(ServerHandle { addr: bound, stop, engine, clients, accept: Some(accept) })
}

fn accept_loop(
    listener: TcpListener,
    engine: Arc<Engine>,
    clients: Arc<AtomicUsize>,
    stop: Arc<AtomicBool>,
    opts: ServeOptions,
) {
    for conn in listener.incoming() {
        if stop.load(Ordering::SeqCst) {
            break;
        }
        match conn {
            Ok(stream) => {
                let engine = Arc::clone(&engine);
                let clients = Arc::clone(&clients);
                std::thread::spawn(move || {
                    let peer = stream
                        .peer_addr()
                        .map(|a| a.to_string())
                        .unwrap_or_else(|_| "?".to_string());
                    clients.fetch_add(1, Ordering::Relaxed);
                    let served = serve_connection(stream, &engine, &clients, opts);
                    clients.fetch_sub(1, Ordering::Relaxed);
                    if let Err(e) = served {
                        crate::log_debug!("eval", "connection {peer} ended: {e}");
                    }
                });
            }
            Err(e) => crate::log_warn!("eval", "accept failed: {e}"),
        }
    }
}

/// One request → one response per line until the client hangs up.
fn serve_connection(
    stream: TcpStream,
    engine: &Engine,
    clients: &AtomicUsize,
    opts: ServeOptions,
) -> anyhow::Result<()> {
    stream.set_nodelay(true).ok();
    // Symmetric with the client's measure read timeout (`RemoteBackend`
    // arms `set_read_timeout` on every request): a reader that stalls
    // mid-response releases this thread instead of holding it hostage.
    if !opts.write_timeout.is_zero() {
        stream.set_write_timeout(Some(opts.write_timeout)).ok();
    }
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    loop {
        let Some(line) = read_frame_line(&mut reader)? else {
            return Ok(());
        };
        // Streaming decode with tree fallback inside `request_from_line`; a
        // frame that is not JSON at all gets a structured Error reply (the
        // client sees *why* instead of a dropped connection).
        let response = match request_from_line(&line) {
            Some(req) => handle(engine, clients, req, opts),
            None => Response::Error("unintelligible request".to_string()),
        };
        if let Err(e) = write_response_frame(&mut writer, &response) {
            // A write deadline expiring means the client stopped reading:
            // treat it as a hangup (clean connection end), not a fault.
            use std::io::ErrorKind;
            return match e.kind() {
                ErrorKind::TimedOut | ErrorKind::WouldBlock => Ok(()),
                _ => Err(e.into()),
            };
        }
    }
}

fn handle(engine: &Engine, clients: &AtomicUsize, req: Request, opts: ServeOptions) -> Response {
    match req {
        Request::Ping => Response::Pong {
            backend: engine.backend_name().to_string(),
            proto: PROTO_VERSION,
            fingerprint: Fingerprint::current(),
            // Inherited coverage: how much persistent history (journal +
            // warm start) seeded this shard's cache before it accepted a
            // single batch.
            preloaded: engine.preloaded_entries(),
        },
        Request::Stats => {
            // Engine counters plus the shard's own connection gauge: how
            // many tuning clients it is serving right now.
            let mut stats = engine.stats().to_json();
            if let Json::Obj(fields) = &mut stats {
                fields.push((
                    "active_connections".to_string(),
                    Json::num(clients.load(Ordering::Relaxed) as f64),
                ));
            }
            Response::Stats(stats)
        }
        Request::Measure { task, points } => {
            // Artificial slowness (scenario tests, placement benchmarks):
            // charged per point, before the engine — a throttled shard is
            // slow even when it answers from its cache, like a slow host.
            if !opts.measure_delay.is_zero() && !points.is_empty() {
                std::thread::sleep(throttle_duration(opts.measure_delay, points.len()));
            }
            // Both sides rebuild the identical space from the task shape;
            // decoded values are the portable point identity.
            let space = ConfigSpace::for_task(&task, true);
            let mut decoded = Vec::with_capacity(points.len());
            for (i, values) in points.iter().enumerate() {
                match point_from_values(&space, values) {
                    Some(p) => decoded.push(p),
                    None => {
                        return Response::Error(format!(
                            "point {i}: values {values:?} are not candidates of the space for \
                             task {} (client/server version skew?)",
                            task.short_id()
                        ));
                    }
                }
            }
            // The shard's own provenance rides back to the client: a point
            // this shard served from its cache (another tenant already
            // paid) is reported non-fresh so client-side ledgers can keep
            // fleet-wide "measure once, charge everyone" accounting honest.
            // The shard sits *below* the ledger: budgets are charged on the
            // client side (RemoteBackend callers), so this submission is
            // intentionally unmetered. devcheck:allow(ledger-order)
            let traced = engine.measure_batch_traced(&space, &decoded);
            let fresh = traced.origins.iter().map(|o| o.is_fresh()).collect();
            // Piggyback the queue depth (batches still measuring for other
            // clients — this request's own batch has already drained from
            // the gauge) so weighted placement needs no extra `stats` RTT.
            let active_batches = Some(engine.stats().active_batches);
            Response::Results { results: traced.results, fresh, active_batches }
        }
    }
}

/// Convenience for tests and embedding: serve a fresh engine on a loopback
/// port picked by the OS.
pub fn spawn_local(engine: Arc<Engine>) -> anyhow::Result<ServerHandle> {
    spawn("127.0.0.1:0", engine)
}

/// [`spawn_local`] with explicit [`ServeOptions`] (scenario tests:
/// loopback shards with injected per-point latency).
pub fn spawn_local_with(engine: Arc<Engine>, opts: ServeOptions) -> anyhow::Result<ServerHandle> {
    spawn_with("127.0.0.1:0", engine, opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn throttle_is_proportional_then_capped() {
        let per = Duration::from_millis(10);
        assert_eq!(throttle_duration(per, 3), Duration::from_millis(30));
        assert_eq!(throttle_duration(per, 100), Duration::from_secs(1));
        // Far past the cap: bounded, not hours.
        assert_eq!(throttle_duration(per, 1_000_000), MAX_BATCH_THROTTLE);
    }

    #[test]
    fn throttle_survives_overflowing_batch_sizes() {
        // Pre-fix this panicked (Duration mul overflow) or truncated
        // (usize → u32 `as` cast). Saturate, then cap.
        let huge = Duration::from_secs(u64::MAX / 2);
        assert_eq!(throttle_duration(huge, usize::MAX), MAX_BATCH_THROTTLE);
        assert_eq!(throttle_duration(Duration::from_nanos(1), usize::MAX), MAX_BATCH_THROTTLE);
        // u32::MAX + 1 used to truncate to 0 points → zero sleep; now it
        // saturates to the cap instead.
        assert_eq!(
            throttle_duration(Duration::from_millis(10), u32::MAX as usize + 1),
            MAX_BATCH_THROTTLE
        );
    }

    #[test]
    fn default_write_timeout_matches_client_measure_timeout() {
        assert_eq!(ServeOptions::default().write_timeout, Duration::from_secs(600));
        assert_eq!(ServeOptions::default().measure_delay, Duration::ZERO);
    }
}
