//! A [`MeasureBackend`] that farms measurement out to a fleet of
//! `arco serve-measure` shards.
//!
//! Construction ([`RemoteBackend::connect`]) handshakes with every shard:
//! protocol version, backend identity and simulator [`Fingerprint`] must
//! all match this binary, so a skewed or differently-configured shard is
//! rejected before it can contribute a single number.
//!
//! Each batch is split into contiguous chunks across the currently-alive
//! shards and dispatched concurrently (one connection per shard per batch).
//! A shard that fails mid-batch — connection refused, reset, short reply —
//! is marked dead and its chunk is re-dispatched to the survivors on the
//! next round; dead shards are re-pinged at the start of later batches and
//! revived when they come back. Only when *no* shard can serve a chunk
//! after repeated rounds does the backend panic (the [`MeasureBackend`]
//! contract has no error channel: measurement infrastructure loss is fatal
//! to a tuning run, invalid *configurations* are not errors).

use super::backend::{BackendKind, MeasureBackend};
use super::cache::PointKey;
use super::proto::{read_frame, write_frame, Fingerprint, Request, Response, PROTO_VERSION};
use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::util::json::Json;
use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Establishing a TCP connection to a shard.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(3);
/// Waiting for a handshake reply.
const PING_TIMEOUT: Duration = Duration::from_secs(5);
/// Waiting for a batch of measurements (a vta-sim batch can be slow).
const MEASURE_TIMEOUT: Duration = Duration::from_secs(600);
/// Minimum spacing between routine probes of dead shards: each probe can
/// burn a connect timeout per dead shard, so it must not run per batch.
const REVIVE_INTERVAL: Duration = Duration::from_secs(30);

struct Shard {
    addr: String,
    alive: AtomicBool,
}

/// Remote measurement fleet client (`--backend remote:host:port[,...]`).
pub struct RemoteBackend {
    shards: Vec<Shard>,
    /// The backend id every shard serves (journal/cache identity).
    name: &'static str,
    /// When dead shards were last probed for revival.
    last_probe: Mutex<Option<Instant>>,
}

fn connect(addr: &str) -> anyhow::Result<TcpStream> {
    let resolved = addr
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("resolving {addr}: {e}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("address {addr} resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&resolved, CONNECT_TIMEOUT)
        .map_err(|e| anyhow::anyhow!("connecting {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    Ok(stream)
}

/// One request → one response over a fresh connection.
fn call(addr: &str, req: &Request, read_timeout: Duration) -> anyhow::Result<Response> {
    let stream = connect(addr)?;
    stream.set_read_timeout(Some(read_timeout)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    write_frame(&mut writer, &req.to_json())?;
    let Some(frame) = read_frame(&mut reader)? else {
        anyhow::bail!("{addr} closed the connection before replying");
    };
    Response::from_json(&frame)
        .ok_or_else(|| anyhow::anyhow!("{addr} sent an unintelligible reply"))
}

/// Handshake with one shard, returning its advertised backend id.
fn handshake(addr: &str) -> anyhow::Result<String> {
    match call(addr, &Request::Ping, PING_TIMEOUT)? {
        Response::Pong { backend, proto, fingerprint } => {
            if proto != PROTO_VERSION {
                anyhow::bail!(
                    "shard {addr} speaks measure-protocol v{proto}, this binary v{PROTO_VERSION}"
                );
            }
            let local = Fingerprint::current();
            if fingerprint != local {
                anyhow::bail!(
                    "shard {addr} embeds a different simulator — refusing to mix numbers.\n  \
                     shard:  {}\n  binary: {}",
                    fingerprint.describe(),
                    local.describe()
                );
            }
            Ok(backend)
        }
        Response::Error(e) => anyhow::bail!("shard {addr} refused the handshake: {e}"),
        _ => anyhow::bail!("shard {addr} sent a non-handshake reply to ping"),
    }
}

impl RemoteBackend {
    /// Handshake with every shard address; any failure is fatal (a fleet
    /// with a bad member should be fixed, not silently thinned, before a
    /// run starts depending on it).
    pub fn connect(addrs: &[String]) -> anyhow::Result<RemoteBackend> {
        if addrs.is_empty() {
            anyhow::bail!("remote backend needs at least one shard address");
        }
        let mut served: Option<String> = None;
        for addr in addrs {
            let backend = handshake(addr)?;
            match &served {
                None => served = Some(backend),
                Some(first) if *first != backend => {
                    anyhow::bail!(
                        "shards disagree on the backend they serve: {} vs {backend} ({addr}); \
                         point a fleet at one backend kind",
                        first
                    );
                }
                Some(_) => {}
            }
        }
        let served = served.expect("at least one shard");
        let name = match BackendKind::from_name(&served) {
            Some(kind) => kind.name(),
            None => "remote",
        };
        crate::log_info!(
            "eval",
            "remote backend: {} shard(s) serving {name}, fingerprints verified",
            addrs.len()
        );
        Ok(RemoteBackend {
            shards: addrs
                .iter()
                .map(|a| Shard { addr: a.clone(), alive: AtomicBool::new(true) })
                .collect(),
            name,
            last_probe: Mutex::new(None),
        })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn alive_count(&self) -> usize {
        self.shards.iter().filter(|s| s.alive.load(Ordering::Relaxed)).count()
    }

    fn alive_ids(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.alive.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .collect()
    }

    /// Re-ping dead shards and revive the ones that answer correctly.
    /// Each probe of an unreachable shard costs up to the connect timeout.
    fn revive_dead(&self) {
        for s in &self.shards {
            if !s.alive.load(Ordering::Relaxed) && handshake(&s.addr).is_ok() {
                crate::log_info!("eval", "shard {} is back, rejoining the fleet", s.addr);
                s.alive.store(true, Ordering::Relaxed);
            }
        }
    }

    /// Routine revival: only when something is dead, and at most once per
    /// [`REVIVE_INTERVAL`] — probing serially on every batch would stall
    /// all measurement for the whole time a shard stays down.
    fn maybe_revive(&self) {
        if self.alive_count() == self.shards.len() {
            return;
        }
        {
            let mut last = self.last_probe.lock().unwrap();
            let now = Instant::now();
            if last.is_some_and(|t| now.duration_since(t) < REVIVE_INTERVAL) {
                return;
            }
            *last = Some(now);
        }
        self.revive_dead();
    }

    /// Send one chunk to one shard, validating the reply shape. Returns
    /// results paired with the shard's per-point freshness report (`false`
    /// when the shard answered from its own cache/coalescing instead of
    /// simulating).
    fn measure_on(
        &self,
        shard: usize,
        task: crate::workload::Conv2dTask,
        values: Vec<Vec<usize>>,
    ) -> Result<(Vec<MeasureResult>, Vec<bool>), String> {
        let expect = values.len();
        let addr = &self.shards[shard].addr;
        // Every failure marks the shard dead — including a structured
        // refusal: a server that answers `Error` to a well-formed batch
        // (version skew) will refuse every retry, and leaving it in the
        // rotation would burn the bounded re-dispatch rounds on a shard
        // that can never serve, starving points that the healthy rest of
        // the fleet could have absorbed.
        let err = match call(addr, &Request::Measure { task, points: values }, MEASURE_TIMEOUT) {
            Ok(Response::Results { results, fresh }) if results.len() == expect => {
                return Ok((results, fresh));
            }
            Ok(Response::Results { results, .. }) => {
                format!("shard {addr}: short reply ({} of {expect} results)", results.len())
            }
            Ok(Response::Error(e)) => format!("shard {addr} refused the batch: {e}"),
            Ok(_) => format!("shard {addr}: unexpected reply kind"),
            Err(e) => format!("shard {addr}: {e}"),
        };
        self.shards[shard].alive.store(false, Ordering::Relaxed);
        Err(err)
    }

    /// One `stats` snapshot per alive shard (used for fleet-load
    /// diagnostics; a shard that fails the call is skipped, not killed —
    /// stats are advisory, measurement traffic decides liveness).
    pub fn shard_stats(&self) -> Vec<(String, Json)> {
        self.shards
            .iter()
            .filter(|s| s.alive.load(Ordering::Relaxed))
            .filter_map(|s| match call(&s.addr, &Request::Stats, PING_TIMEOUT) {
                Ok(Response::Stats(stats)) => Some((s.addr.clone(), stats)),
                _ => None,
            })
            .collect()
    }
}

impl MeasureBackend for RemoteBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        self.measure_many(space, std::slice::from_ref(point), 1)[0]
    }

    fn measure_many(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> Vec<MeasureResult> {
        self.measure_many_traced(space, points, workers).0
    }

    /// One batch slot per alive shard: the fleet genuinely serves that
    /// many batches at once, which is what the multi-tenant dispatcher
    /// sizes admission from.
    fn concurrent_batch_capacity(&self) -> usize {
        self.alive_count().max(1)
    }

    fn fleet_stats(&self) -> Vec<(String, Json)> {
        self.shard_stats()
    }

    /// Shard the batch across the alive fleet; chunks of a shard that dies
    /// mid-batch are re-dispatched to the survivors. The freshness vector
    /// relays each shard's own report, so a point another tenant already
    /// paid for on a shard comes back `false`.
    ///
    /// Panics when no shard can serve a chunk after repeated rounds (the
    /// whole fleet is unreachable): there is nothing measurable left.
    fn measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        _workers: usize,
    ) -> (Vec<MeasureResult>, Vec<bool>) {
        let n = points.len();
        if n == 0 {
            return (Vec::new(), Vec::new());
        }
        self.maybe_revive();
        let values: Vec<Vec<usize>> =
            points.iter().map(|p| PointKey::of(space, p).values).collect();
        let values = &values;
        let task = space.task;
        let mut out: Vec<Option<(MeasureResult, bool)>> = vec![None; n];
        let mut pending: Vec<usize> = (0..n).collect();
        let mut last_error = String::new();
        let max_rounds = 2 * self.shards.len() + 2;
        for round in 0..max_rounds {
            let mut alive = self.alive_ids();
            if alive.is_empty() {
                self.revive_dead();
                alive = self.alive_ids();
            }
            if alive.is_empty() {
                break;
            }
            // Contiguous chunks, one per alive shard (at most one point of
            // imbalance; chunk i may be empty when points < shards).
            let per = pending.len().div_ceil(alive.len());
            type ChunkOutcome = (Vec<usize>, Result<(Vec<MeasureResult>, Vec<bool>), String>);
            let outcomes: Vec<ChunkOutcome> = std::thread::scope(|scope| {
                let handles: Vec<_> = alive
                    .iter()
                    .zip(pending.chunks(per.max(1)))
                    .map(|(&shard, chunk)| {
                        let idxs: Vec<usize> = chunk.to_vec();
                        scope.spawn(move || {
                            let vals: Vec<Vec<usize>> =
                                idxs.iter().map(|&i| values[i].clone()).collect();
                            let res = self.measure_on(shard, task, vals);
                            (idxs, res)
                        })
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|h| h.join().expect("remote dispatch thread panicked"))
                    .collect()
            });
            let mut next = Vec::new();
            for (idxs, res) in outcomes {
                match res {
                    Ok((rs, fr)) => {
                        for ((&slot, r), f) in idxs.iter().zip(rs).zip(fr) {
                            out[slot] = Some((r, f));
                        }
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "eval",
                            "re-dispatching {} point(s) (round {}): {e}",
                            idxs.len(),
                            round + 1
                        );
                        last_error = e;
                        next.extend(idxs);
                    }
                }
            }
            pending = next;
            if pending.is_empty() {
                break;
            }
        }
        assert!(
            pending.is_empty(),
            "remote measurement fleet lost: {} point(s) undeliverable after {} rounds \
             (last error: {last_error})",
            pending.len(),
            max_rounds
        );
        let mut results = Vec::with_capacity(n);
        let mut fresh = Vec::with_capacity(n);
        for cell in out {
            let (r, f) = cell.expect("every point measured");
            results.push(r);
            fresh.push(f);
        }
        (results, fresh)
    }
}
