//! A [`MeasureBackend`] that farms measurement out to a fleet of
//! `arco serve-measure` shards.
//!
//! Construction ([`RemoteBackend::connect`]) handshakes with every shard:
//! protocol version, backend identity and simulator [`Fingerprint`] must
//! all match this binary, so a skewed or differently-configured shard is
//! rejected before it can contribute a single number. The handshake also
//! carries each shard's preloaded-cache count (journal seeding plus
//! `--warm-start`), so a tuning client can log how much fleet history a
//! shard inherited before its first batch.
//!
//! Each batch is split into contiguous chunks across the currently-alive
//! shards and dispatched concurrently (one connection per shard per batch).
//! How big each chunk is depends on the [`Placement`] policy:
//!
//! - [`Placement::Uniform`] (default): equal chunks, at most one point of
//!   imbalance — placement is independent of observed timings, so runs are
//!   bit-for-bit reproducible in *where* points were measured too.
//! - [`Placement::Weighted`]: chunks proportional to estimated shard
//!   throughput. The estimate is an EWMA of each shard's observed
//!   per-point service time, discounted by the queue depth
//!   (`active_batches`) the shard piggybacks on every measure response —
//!   a `stats` poll is only paid for shards that have not reported one
//!   yet (first contact, revival, or an older peer) — so a 10×-slower or
//!   heavily-loaded shard receives proportionally fewer points. Measured
//!   *numbers* are identical under both policies (shards embed the same
//!   deterministic simulator); placement only moves wall-clock.
//!
//! A shard that fails mid-batch — connection refused, reset, short reply —
//! is marked dead and its chunk is re-dispatched to the survivors on the
//! next round; dead shards are re-pinged at the start of later batches and
//! revived when they come back. Only when *no* shard can serve a chunk
//! after repeated rounds does the backend give up, returning a typed
//! [`FleetLostError`] through [`MeasureBackend::try_measure_many_traced`]
//! so the whole run can fail cleanly (invalid *configurations* are still
//! not errors — only the loss of the measurement infrastructure is).

use super::backend::{BackendKind, MeasureBackend, Placement, ShardPlacement};
use super::cache::PointKey;
use super::sync::lock_unpoisoned;
use super::proto::{
    read_frame_line, response_from_line, write_request_frame, Fingerprint, Request, Response,
    PROTO_VERSION,
};
use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::util::json::Json;
use std::io::{BufReader, BufWriter};
use std::net::{TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Establishing a TCP connection to a shard.
const CONNECT_TIMEOUT: Duration = Duration::from_secs(3);
/// Waiting for a handshake reply.
const PING_TIMEOUT: Duration = Duration::from_secs(5);
/// Waiting for a batch of measurements (a vta-sim batch can be slow).
const MEASURE_TIMEOUT: Duration = Duration::from_secs(600);
/// Minimum spacing between routine probes of dead shards: each probe can
/// burn a connect timeout per dead shard, so it must not run per batch.
const REVIVE_INTERVAL: Duration = Duration::from_secs(30);
/// EWMA smoothing for observed per-point service time: high enough that a
/// heterogeneous fleet is learned within a couple of batches, low enough
/// that one noisy batch does not whipsaw the placement.
const EWMA_ALPHA: f64 = 0.4;

/// The whole measurement fleet became unreachable: after bounded
/// re-dispatch rounds (with revival probes in between) some points still
/// had no shard able to serve them. Measurement infrastructure loss is
/// fatal to a tuning run — this error propagates through
/// [`super::Engine`] and the tuning loop so the run exits cleanly instead
/// of panicking.
#[derive(Debug, Clone)]
pub struct FleetLostError {
    /// Points that could not be delivered to any shard.
    pub undeliverable: usize,
    /// Dispatch rounds attempted before giving up.
    pub rounds: usize,
    /// The last shard failure observed (the proximate cause).
    pub last_error: String,
}

impl std::fmt::Display for FleetLostError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "remote measurement fleet lost: {} point(s) undeliverable after {} dispatch \
             round(s) (last error: {})",
            self.undeliverable, self.rounds, self.last_error
        )
    }
}

impl std::error::Error for FleetLostError {}

struct Shard {
    addr: String,
    alive: AtomicBool,
    /// EWMA of observed service seconds per point, stored as `f64` bits
    /// (0 = no successfully served chunk yet).
    ewma_bits: AtomicU64,
    /// Batch chunks this shard served (placement counter).
    batches: AtomicUsize,
    /// Points this shard served (placement counter).
    points: AtomicUsize,
    /// Queue depth (`active_batches`) the shard last reported — weighted
    /// placement's load signal. Normally piggybacked on every measure
    /// response; polled from the `stats` op only while no served chunk has
    /// reported one yet.
    queue_depth: AtomicUsize,
    /// Whether any measure response from this shard has piggybacked a
    /// queue depth. Until it has (a brand-new or just-revived shard, or an
    /// older peer that omits the additive field), weighted placement falls
    /// back to polling the shard's `stats` op before the batch.
    depth_piggybacked: AtomicBool,
    /// Preloaded cache entries the shard reported at handshake (journal
    /// seeding + warm start): inherited fleet coverage.
    preloaded: AtomicUsize,
}

impl Shard {
    fn new(addr: String) -> Shard {
        Shard {
            addr,
            alive: AtomicBool::new(true),
            ewma_bits: AtomicU64::new(0),
            batches: AtomicUsize::new(0),
            points: AtomicUsize::new(0),
            queue_depth: AtomicUsize::new(0),
            depth_piggybacked: AtomicBool::new(false),
            preloaded: AtomicUsize::new(0),
        }
    }

    fn ewma(&self) -> Option<f64> {
        let bits = self.ewma_bits.load(Ordering::Relaxed);
        if bits == 0 {
            None
        } else {
            Some(f64::from_bits(bits))
        }
    }

    fn observe_service(&self, secs_per_point: f64) {
        if !secs_per_point.is_finite() || secs_per_point <= 0.0 {
            return;
        }
        let next = match self.ewma() {
            Some(prev) => EWMA_ALPHA * secs_per_point + (1.0 - EWMA_ALPHA) * prev,
            None => secs_per_point,
        };
        self.ewma_bits.store(next.to_bits(), Ordering::Relaxed);
    }
}

/// Remote measurement fleet client (`--backend remote:host:port[,...]`).
pub struct RemoteBackend {
    shards: Vec<Shard>,
    /// The backend id every shard serves (journal/cache identity).
    name: &'static str,
    /// How batches are split across the alive shards.
    placement: Placement,
    /// When dead shards were last probed for revival.
    last_probe: Mutex<Option<Instant>>,
}

fn connect(addr: &str) -> anyhow::Result<TcpStream> {
    let resolved = addr
        .to_socket_addrs()
        .map_err(|e| anyhow::anyhow!("resolving {addr}: {e}"))?
        .next()
        .ok_or_else(|| anyhow::anyhow!("address {addr} resolved to nothing"))?;
    let stream = TcpStream::connect_timeout(&resolved, CONNECT_TIMEOUT)
        .map_err(|e| anyhow::anyhow!("connecting {addr}: {e}"))?;
    stream.set_nodelay(true).ok();
    Ok(stream)
}

/// One request → one response over a fresh connection. Both directions use
/// the streaming codec: the request is serialized straight into the socket
/// buffer and the reply line is decoded without building a JSON tree.
fn call(addr: &str, req: &Request, read_timeout: Duration) -> anyhow::Result<Response> {
    let stream = connect(addr)?;
    stream.set_read_timeout(Some(read_timeout)).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    write_request_frame(&mut writer, req)?;
    let Some(line) = read_frame_line(&mut reader)? else {
        anyhow::bail!("{addr} closed the connection before replying");
    };
    response_from_line(&line).ok_or_else(|| anyhow::anyhow!("{addr} sent an unintelligible reply"))
}

/// Handshake with one shard, returning its advertised backend id and
/// preloaded-cache entry count (inherited coverage).
fn handshake(addr: &str) -> anyhow::Result<(String, usize)> {
    match call(addr, &Request::Ping, PING_TIMEOUT)? {
        Response::Pong { backend, proto, fingerprint, preloaded } => {
            if proto != PROTO_VERSION {
                anyhow::bail!(
                    "shard {addr} speaks measure-protocol v{proto}, this binary v{PROTO_VERSION}"
                );
            }
            let local = Fingerprint::current();
            if fingerprint != local {
                anyhow::bail!(
                    "shard {addr} embeds a different simulator — refusing to mix numbers.\n  \
                     shard:  {}\n  binary: {}",
                    fingerprint.describe(),
                    local.describe()
                );
            }
            Ok((backend, preloaded))
        }
        Response::Error(e) => anyhow::bail!("shard {addr} refused the handshake: {e}"),
        _ => anyhow::bail!("shard {addr} sent a non-handshake reply to ping"),
    }
}

/// Split `pending` points into per-shard counts proportional to `weights`
/// (largest-remainder rounding; deterministic, exact sum). Degenerate
/// weights (all zero / non-finite) fall back to equal shares.
fn apportion(pending: usize, weights: &[f64]) -> Vec<usize> {
    let n = weights.len();
    if n == 0 || pending == 0 {
        return vec![0; n];
    }
    let sane: Vec<f64> =
        weights.iter().map(|w| if w.is_finite() && *w > 0.0 { *w } else { 0.0 }).collect();
    let total: f64 = sane.iter().sum();
    if total <= 0.0 {
        return apportion(pending, &vec![1.0; n]);
    }
    let quotas: Vec<f64> = sane.iter().map(|w| pending as f64 * w / total).collect();
    let mut counts: Vec<usize> = quotas.iter().map(|q| q.floor() as usize).collect();
    let assigned: usize = counts.iter().sum();
    // Distribute the remainder to the largest fractional parts
    // (deterministic tie-break by shard index).
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let fa = quotas[a] - quotas[a].floor();
        let fb = quotas[b] - quotas[b].floor();
        fb.partial_cmp(&fa).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    for i in 0..pending.saturating_sub(assigned) {
        counts[order[i % n]] += 1;
    }
    counts
}

/// Raise every zero count to one by taking from the largest counts, so
/// each alive shard serves at least one point per batch and keeps its
/// service-time EWMA fresh. No-op when there are fewer points than shards
/// (someone must get zero then).
fn ensure_probe_floor(counts: &mut [usize], pending: usize) {
    if pending < counts.len() {
        return;
    }
    while let Some(zero) = counts.iter().position(|&c| c == 0) {
        let Some(donor) = (0..counts.len()).max_by_key(|&i| counts[i]) else {
            return;
        };
        if counts[donor] <= 1 {
            return;
        }
        counts[donor] -= 1;
        counts[zero] += 1;
    }
}

/// The legacy equal-chunk sizes: `ceil(pending / shards)` points per shard
/// until exhausted (trailing shards may receive zero).
fn uniform_counts(pending: usize, shards: usize) -> Vec<usize> {
    let mut counts = vec![0; shards];
    if shards == 0 || pending == 0 {
        return counts;
    }
    let per = pending.div_ceil(shards).max(1);
    let mut left = pending;
    for c in counts.iter_mut() {
        let take = per.min(left);
        *c = take;
        left -= take;
        if left == 0 {
            break;
        }
    }
    counts
}

impl RemoteBackend {
    /// Handshake with every shard address; any failure is fatal (a fleet
    /// with a bad member should be fixed, not silently thinned, before a
    /// run starts depending on it). Uniform placement.
    pub fn connect(addrs: &[String]) -> anyhow::Result<RemoteBackend> {
        RemoteBackend::connect_with(addrs, Placement::default())
    }

    /// [`connect`](Self::connect) with an explicit [`Placement`] policy.
    pub fn connect_with(addrs: &[String], placement: Placement) -> anyhow::Result<RemoteBackend> {
        if addrs.is_empty() {
            anyhow::bail!("remote backend needs at least one shard address");
        }
        let mut served: Option<String> = None;
        let mut preloaded_counts = Vec::with_capacity(addrs.len());
        for addr in addrs {
            let (backend, preloaded) = handshake(addr)?;
            preloaded_counts.push(preloaded);
            if preloaded > 0 {
                crate::log_info!(
                    "eval",
                    "shard {addr}: inherited {preloaded} preloaded measurement(s) (warm start)"
                );
            }
            match &served {
                None => served = Some(backend),
                Some(first) if *first != backend => {
                    anyhow::bail!(
                        "shards disagree on the backend they serve: {} vs {backend} ({addr}); \
                         point a fleet at one backend kind",
                        first
                    );
                }
                Some(_) => {}
            }
        }
        let Some(served) = served else {
            anyhow::bail!("remote backend needs at least one shard address");
        };
        let name = match BackendKind::from_name(&served) {
            Some(kind) => kind.name(),
            None => "remote",
        };
        crate::log_info!(
            "eval",
            "remote backend: {} shard(s) serving {name}, fingerprints verified, {} placement",
            addrs.len(),
            placement.name()
        );
        let shards: Vec<Shard> = addrs.iter().map(|a| Shard::new(a.clone())).collect();
        for (shard, count) in shards.iter().zip(&preloaded_counts) {
            shard.preloaded.store(*count, Ordering::Relaxed);
        }
        Ok(RemoteBackend { shards, name, placement, last_probe: Mutex::new(None) })
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn alive_count(&self) -> usize {
        self.shards.iter().filter(|s| s.alive.load(Ordering::Relaxed)).count()
    }

    pub fn placement(&self) -> Placement {
        self.placement
    }

    fn alive_ids(&self) -> Vec<usize> {
        self.shards
            .iter()
            .enumerate()
            .filter(|(_, s)| s.alive.load(Ordering::Relaxed))
            .map(|(i, _)| i)
            .collect()
    }

    /// Re-ping dead shards and revive the ones that answer correctly.
    /// Each probe of an unreachable shard costs up to the connect timeout.
    fn revive_dead(&self) {
        for s in &self.shards {
            if !s.alive.load(Ordering::Relaxed) {
                if let Ok((_, preloaded)) = handshake(&s.addr) {
                    crate::log_info!("eval", "shard {} is back, rejoining the fleet", s.addr);
                    s.preloaded.store(preloaded, Ordering::Relaxed);
                    // A revived shard may be a different process on the
                    // same address: forget the dead one's service profile.
                    s.ewma_bits.store(0, Ordering::Relaxed);
                    s.queue_depth.store(0, Ordering::Relaxed);
                    s.depth_piggybacked.store(false, Ordering::Relaxed);
                    s.alive.store(true, Ordering::Relaxed);
                }
            }
        }
    }

    /// Probe dead shards for revival *now*, bypassing the routine
    /// [`REVIVE_INTERVAL`] spacing. Costs up to a connect timeout per dead
    /// shard; meant for operators (and tests) that just restarted one.
    pub fn revive_now(&self) {
        *lock_unpoisoned(&self.last_probe) = Some(Instant::now());
        self.revive_dead();
    }

    /// Routine revival: only when something is dead, and at most once per
    /// [`REVIVE_INTERVAL`] — probing serially on every batch would stall
    /// all measurement for the whole time a shard stays down.
    fn maybe_revive(&self) {
        if self.alive_count() == self.shards.len() {
            return;
        }
        {
            let mut last = lock_unpoisoned(&self.last_probe);
            let now = Instant::now();
            if last.is_some_and(|t| now.duration_since(t) < REVIVE_INTERVAL) {
                return;
            }
            *last = Some(now);
        }
        self.revive_dead();
    }

    /// Send one chunk to one shard, validating the reply shape. Returns
    /// results paired with the shard's per-point freshness report (`false`
    /// when the shard answered from its own cache/coalescing instead of
    /// simulating). A served chunk updates the shard's service-time EWMA
    /// and placement counters.
    fn measure_on(
        &self,
        shard: usize,
        task: crate::workload::Conv2dTask,
        values: Vec<Vec<usize>>,
    ) -> Result<(Vec<MeasureResult>, Vec<bool>), String> {
        let expect = values.len();
        let addr = &self.shards[shard].addr;
        let started = Instant::now();
        // Every failure marks the shard dead — including a structured
        // refusal: a server that answers `Error` to a well-formed batch
        // (version skew) will refuse every retry, and leaving it in the
        // rotation would burn the bounded re-dispatch rounds on a shard
        // that can never serve, starving points that the healthy rest of
        // the fleet could have absorbed.
        let err = match call(addr, &Request::Measure { task, points: values }, MEASURE_TIMEOUT) {
            Ok(Response::Results { results, fresh, active_batches }) if results.len() == expect => {
                let s = &self.shards[shard];
                s.observe_service(started.elapsed().as_secs_f64() / expect.max(1) as f64);
                s.batches.fetch_add(1, Ordering::Relaxed);
                s.points.fetch_add(expect, Ordering::Relaxed);
                // The queue depth rides the reply (shards report it with
                // every measure response), sparing weighted placement its
                // per-batch `stats` round trip. Older peers omit the
                // field; those shards keep being polled instead.
                if let Some(depth) = active_batches {
                    s.queue_depth.store(depth, Ordering::Relaxed);
                    s.depth_piggybacked.store(true, Ordering::Relaxed);
                }
                return Ok((results, fresh));
            }
            Ok(Response::Results { results, .. }) => {
                format!("shard {addr}: short reply ({} of {expect} results)", results.len())
            }
            Ok(Response::Error(e)) => format!("shard {addr} refused the batch: {e}"),
            Ok(_) => format!("shard {addr}: unexpected reply kind"),
            Err(e) => format!("shard {addr}: {e}"),
        };
        self.shards[shard].alive.store(false, Ordering::Relaxed);
        Err(err)
    }

    /// One `stats` snapshot per alive shard (used for fleet-load
    /// diagnostics; a shard that fails the call is skipped, not killed —
    /// stats are advisory, measurement traffic decides liveness).
    pub fn shard_stats(&self) -> Vec<(String, Json)> {
        self.shards
            .iter()
            .filter(|s| s.alive.load(Ordering::Relaxed))
            .filter_map(|s| match call(&s.addr, &Request::Stats, PING_TIMEOUT) {
                Ok(Response::Stats(stats)) => Some((s.addr.clone(), stats)),
                _ => None,
            })
            .collect()
    }

    /// Refresh each alive shard's queue-depth gauge from its `stats` op
    /// (weighted placement's load signal). Advisory: a failed poll keeps
    /// the previous value and does not mark the shard dead. Polls run
    /// concurrently — one per shard — so the pre-batch cost is a single
    /// round trip (bounded by the slowest shard), not N serial ones.
    fn poll_queue_depths(&self, alive: &[usize]) {
        std::thread::scope(|scope| {
            for &i in alive {
                let shard = &self.shards[i];
                scope.spawn(move || {
                    if let Ok(Response::Stats(stats)) =
                        call(&shard.addr, &Request::Stats, PING_TIMEOUT)
                    {
                        if let Some(depth) = stats.get_usize("active_batches") {
                            shard.queue_depth.store(depth, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
    }

    /// Estimated relative throughput per alive shard: inverse service-time
    /// EWMA, discounted by the last-reported queue depth. Shards with no
    /// observation yet borrow the fastest known rate (optimistic: they are
    /// profiled by their first chunk anyway).
    fn shard_weights(&self, alive: &[usize]) -> Vec<f64> {
        let ewmas: Vec<Option<f64>> = alive.iter().map(|&i| self.shards[i].ewma()).collect();
        let fastest = ewmas
            .iter()
            .flatten()
            .copied()
            .fold(f64::INFINITY, f64::min);
        alive
            .iter()
            .zip(&ewmas)
            .map(|(&i, e)| {
                let secs = match e {
                    Some(s) => *s,
                    None if fastest.is_finite() => fastest,
                    None => 1.0,
                };
                let speed = 1.0 / secs.max(1e-9);
                speed / (1.0 + self.shards[i].queue_depth.load(Ordering::Relaxed) as f64)
            })
            .collect()
    }

    /// Per-shard chunk sizes for this round, by the placement policy.
    fn plan_counts(&self, pending: usize, alive: &[usize], first_round: bool) -> Vec<usize> {
        match self.placement {
            Placement::Uniform => uniform_counts(pending, alive.len()),
            Placement::Weighted => {
                if first_round {
                    // The load signal normally piggybacks on measure
                    // responses; an explicit `stats` poll is only worth a
                    // round trip for shards that have not reported one yet
                    // (first contact, a revival, or an older peer).
                    let unpiggybacked: Vec<usize> = alive
                        .iter()
                        .copied()
                        .filter(|&i| !self.shards[i].depth_piggybacked.load(Ordering::Relaxed))
                        .collect();
                    if !unpiggybacked.is_empty() {
                        self.poll_queue_depths(&unpiggybacked);
                    }
                }
                let mut counts = apportion(pending, &self.shard_weights(alive));
                // Probe floor: an alive shard that receives zero points
                // would never refresh its EWMA (only a served chunk
                // updates it), so one bad observation could starve it
                // permanently even after it recovers. Give every alive
                // shard at least one point per batch (when the batch is
                // big enough) — the probe that lets a slandered shard
                // earn its weight back.
                ensure_probe_floor(&mut counts, pending);
                counts
            }
        }
    }

    /// The fallible batch path: shard the batch across the alive fleet,
    /// re-dispatching chunks of shards that die mid-batch; see the module
    /// docs. `Err` carries a [`FleetLostError`] when the whole fleet is
    /// unreachable.
    fn try_measure(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
    ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
        let n = points.len();
        if n == 0 {
            return Ok((Vec::new(), Vec::new()));
        }
        self.maybe_revive();
        let values: Vec<Vec<usize>> =
            points.iter().map(|p| PointKey::of(space, p).values).collect();
        let values = &values;
        let task = space.task;
        let mut out: Vec<Option<(MeasureResult, bool)>> = vec![None; n];
        let mut pending: Vec<usize> = (0..n).collect();
        let mut last_error = String::from("no shard reachable");
        let max_rounds = 2 * self.shards.len() + 2;
        let mut rounds_attempted = 0usize;
        for round in 0..max_rounds {
            let mut alive = self.alive_ids();
            if alive.is_empty() {
                self.revive_dead();
                alive = self.alive_ids();
            }
            if alive.is_empty() {
                break;
            }
            rounds_attempted = round + 1;
            // Contiguous chunks, one per alive shard; sizes decided by the
            // placement policy (a zero-size chunk skips its shard).
            let counts = self.plan_counts(pending.len(), &alive, round == 0);
            let mut chunks: Vec<(usize, Vec<usize>)> = Vec::with_capacity(alive.len());
            let mut cursor = 0usize;
            for (&shard, &count) in alive.iter().zip(&counts) {
                if count == 0 {
                    continue;
                }
                chunks.push((shard, pending[cursor..cursor + count].to_vec()));
                cursor += count;
            }
            debug_assert_eq!(cursor, pending.len(), "placement must cover every point");
            type ChunkOutcome = (Vec<usize>, Result<(Vec<MeasureResult>, Vec<bool>), String>);
            let outcomes: Vec<ChunkOutcome> = std::thread::scope(|scope| {
                let handles: Vec<_> = chunks
                    .into_iter()
                    .map(|(shard, idxs)| {
                        let vals: Vec<Vec<usize>> =
                            idxs.iter().map(|&i| values[i].clone()).collect();
                        let h = scope.spawn(move || self.measure_on(shard, task, vals));
                        (idxs, h)
                    })
                    .collect();
                handles
                    .into_iter()
                    .map(|(idxs, h)| {
                        // A panicked dispatch thread is indistinguishable
                        // from a failed chunk: re-dispatch it like one.
                        let res = h.join().unwrap_or_else(|_| {
                            Err("dispatch thread panicked; re-dispatching its chunk".to_string())
                        });
                        (idxs, res)
                    })
                    .collect()
            });
            let mut next = Vec::new();
            for (idxs, res) in outcomes {
                match res {
                    Ok((rs, fr)) => {
                        for ((&slot, r), f) in idxs.iter().zip(rs).zip(fr) {
                            out[slot] = Some((r, f));
                        }
                    }
                    Err(e) => {
                        crate::log_warn!(
                            "eval",
                            "re-dispatching {} point(s) (round {}): {e}",
                            idxs.len(),
                            round + 1
                        );
                        last_error = e;
                        next.extend(idxs);
                    }
                }
            }
            pending = next;
            if pending.is_empty() {
                break;
            }
        }
        if !pending.is_empty() {
            return Err(anyhow::Error::new(FleetLostError {
                undeliverable: pending.len(),
                rounds: rounds_attempted,
                last_error,
            }));
        }
        let mut results = Vec::with_capacity(n);
        let mut fresh = Vec::with_capacity(n);
        for cell in out {
            // Every slot is filled once `pending` drains; an accounting
            // hole must surface as a fleet error, not kill the caller.
            let Some((r, f)) = cell else {
                anyhow::bail!("remote dispatch bug: a point was neither measured nor re-dispatched");
            };
            results.push(r);
            fresh.push(f);
        }
        Ok((results, fresh))
    }
}

impl MeasureBackend for RemoteBackend {
    fn name(&self) -> &'static str {
        self.name
    }

    fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        self.measure_many(space, std::slice::from_ref(point), 1)[0]
    }

    fn measure_many(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> Vec<MeasureResult> {
        self.measure_many_traced(space, points, workers).0
    }

    /// One batch slot per alive shard: the fleet genuinely serves that
    /// many batches at once, which is what the multi-tenant dispatcher
    /// sizes admission from.
    fn concurrent_batch_capacity(&self) -> usize {
        self.alive_count().max(1)
    }

    fn fleet_stats(&self) -> Vec<(String, Json)> {
        self.shard_stats()
    }

    fn placement_stats(&self) -> Vec<ShardPlacement> {
        self.shards
            .iter()
            .map(|s| ShardPlacement {
                addr: s.addr.clone(),
                alive: s.alive.load(Ordering::Relaxed),
                batches: s.batches.load(Ordering::Relaxed),
                points: s.points.load(Ordering::Relaxed),
                ewma_secs_per_point: s.ewma(),
                queue_depth: s.queue_depth.load(Ordering::Relaxed),
                preloaded: s.preloaded.load(Ordering::Relaxed),
            })
            .collect()
    }

    /// Infallible facade over [`try_measure_many_traced`]
    /// (the [`MeasureBackend`] contract for direct callers). The engine
    /// and the tuning loop use the fallible variant; this one panics on a
    /// whole-fleet outage.
    fn measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        workers: usize,
    ) -> (Vec<MeasureResult>, Vec<bool>) {
        match self.try_measure_many_traced(space, points, workers) {
            Ok(out) => out,
            // Deliberately infallible facade: direct MeasureBackend callers
            // have no error channel.
            Err(e) => super::sync::raise(e),
        }
    }

    fn try_measure_many_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
        _workers: usize,
    ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
        self.try_measure(space, points)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_counts_match_legacy_chunking() {
        // ceil(n/k)-sized chunks until exhausted, trailing shards empty —
        // exactly what `pending.chunks(per)` used to produce.
        assert_eq!(uniform_counts(10, 3), vec![4, 4, 2]);
        assert_eq!(uniform_counts(2, 3), vec![1, 1, 0]);
        assert_eq!(uniform_counts(9, 3), vec![3, 3, 3]);
        assert_eq!(uniform_counts(0, 3), vec![0, 0, 0]);
        assert_eq!(uniform_counts(5, 1), vec![5]);
        assert_eq!(uniform_counts(3, 0), Vec::<usize>::new());
    }

    #[test]
    fn apportion_is_exact_and_proportional() {
        // A 10x-faster shard gets ~10x the points.
        let counts = apportion(110, &[10.0, 1.0]);
        assert_eq!(counts.iter().sum::<usize>(), 110);
        assert_eq!(counts, vec![100, 10]);
        // Remainders are distributed deterministically, sum always exact.
        for pending in [1usize, 7, 48, 99] {
            let counts = apportion(pending, &[3.0, 2.0, 1.0]);
            assert_eq!(counts.iter().sum::<usize>(), pending, "pending={pending}");
        }
        // Degenerate weights fall back to equal shares.
        let counts = apportion(9, &[0.0, f64::NAN, -1.0]);
        assert_eq!(counts.iter().sum::<usize>(), 9);
        assert!(counts.iter().all(|&c| c == 3));
        // Empty fleet / empty batch.
        assert_eq!(apportion(5, &[]), Vec::<usize>::new());
        assert_eq!(apportion(0, &[1.0, 1.0]), vec![0, 0]);
    }

    #[test]
    fn apportion_starves_a_much_slower_shard_but_never_loses_points() {
        // Weighted placement with a 10x-slower shard: the slow shard gets
        // roughly 1/11th of the batch.
        let counts = apportion(48, &[1.0, 0.1]);
        assert_eq!(counts.iter().sum::<usize>(), 48);
        assert!(counts[1] <= 5, "slow shard got {} of 48 points", counts[1]);
        assert!(counts[0] >= 43);
    }

    #[test]
    fn probe_floor_keeps_every_shard_warm_without_losing_points() {
        // A starved shard gets its probe point back from the largest chunk.
        let mut counts = vec![4, 0];
        ensure_probe_floor(&mut counts, 4);
        assert_eq!(counts, vec![3, 1]);
        // Several zeros, all fixed, sum preserved.
        let mut counts = vec![6, 0, 0];
        ensure_probe_floor(&mut counts, 6);
        assert_eq!(counts.iter().sum::<usize>(), 6);
        assert!(counts.iter().all(|&c| c >= 1), "no shard may be starved: {counts:?}");
        // Fewer points than shards: someone must get zero; untouched.
        let mut counts = vec![1, 1, 0];
        ensure_probe_floor(&mut counts, 2);
        assert_eq!(counts, vec![1, 1, 0]);
        // Exactly one point per shard.
        let mut counts = vec![3, 0, 0];
        ensure_probe_floor(&mut counts, 3);
        assert_eq!(counts, vec![1, 1, 1]);
    }

    #[test]
    fn ewma_learns_and_forgets_nothing_it_never_saw() {
        let s = Shard::new("x:1".into());
        assert_eq!(s.ewma(), None);
        s.observe_service(1.0);
        assert_eq!(s.ewma(), Some(1.0));
        s.observe_service(2.0);
        let e = s.ewma().unwrap();
        assert!(e > 1.0 && e < 2.0, "ewma must smooth: {e}");
        // Bogus observations are ignored.
        s.observe_service(f64::NAN);
        s.observe_service(-3.0);
        assert_eq!(s.ewma(), Some(e));
    }

    #[test]
    fn fleet_lost_error_renders_cause() {
        let e = FleetLostError {
            undeliverable: 7,
            rounds: 4,
            last_error: "shard x:1: connecting x:1: refused".into(),
        };
        let msg = e.to_string();
        assert!(msg.contains("7 point(s)"));
        assert!(msg.contains("4 dispatch round(s)"));
        assert!(msg.contains("refused"));
        let any: anyhow::Error = anyhow::Error::new(e);
        assert!(any.as_ref().downcast_ref::<FleetLostError>().is_some());
    }
}
