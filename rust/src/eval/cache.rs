//! Concurrent, point-keyed memoization of measurement results.

use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::workload::Conv2dTask;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Canonical identity of one measurable configuration: the task shape plus
/// the *decoded knob values* (not value indices). Keying on values means the
/// same physical (hardware, software) configuration hits the same entry
/// whether it was planned in the full co-design space or a hardware-frozen
/// software-only space — which is what lets one `arco compare` run share
/// measurements across frameworks.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PointKey {
    pub task: Conv2dTask,
    /// One decoded value per knob, in space knob order.
    pub values: Vec<usize>,
}

impl PointKey {
    /// Key for `point` within `space`.
    pub fn of(space: &ConfigSpace, point: &PointConfig) -> PointKey {
        let values = space
            .knobs
            .iter()
            .zip(point.as_slice())
            .map(|(k, &i)| k.values[i])
            .collect();
        PointKey { task: space.task, values }
    }
}

/// Cache counters (monotonic over the cache's lifetime).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: usize,
    /// Lookups that found nothing.
    pub misses: usize,
    /// Entries currently stored.
    pub entries: usize,
}

/// A thread-safe point-keyed result cache.
///
/// A plain `Mutex<HashMap>` is deliberate: one lookup or insert is tens of
/// nanoseconds while one simulation is tens of microseconds to milliseconds,
/// so lock contention is irrelevant and the simplicity pays for itself.
pub struct MeasureCache {
    map: Mutex<HashMap<PointKey, MeasureResult>>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl MeasureCache {
    pub fn new() -> MeasureCache {
        MeasureCache {
            map: Mutex::new(HashMap::new()),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    /// Look up a key, counting the hit or miss.
    pub fn get(&self, key: &PointKey) -> Option<MeasureResult> {
        let found = self.map.lock().unwrap().get(key).copied();
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Store a result. Only [`get`](Self::get) touches the hit/miss
    /// counters; inserts are not counted.
    pub fn insert(&self, key: PointKey, result: MeasureResult) {
        self.map.lock().unwrap().insert(key, result);
    }

    /// Intent-named alias of [`insert`](Self::insert) for seeding entries
    /// from the journal at engine construction.
    pub fn preload(&self, key: PointKey, result: MeasureResult) {
        self.insert(key, result);
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: self.len(),
        }
    }
}

impl Default for MeasureCache {
    fn default() -> Self {
        MeasureCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn space(hardware_tunable: bool) -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), hardware_tunable)
    }

    fn dummy_result(seconds: f64) -> MeasureResult {
        MeasureResult {
            seconds,
            cycles: (seconds * 1e8) as u64,
            gflops: 1.0,
            area_mm2: 2.0,
            occupancy: 0.5,
            valid: true,
        }
    }

    #[test]
    fn key_identifies_decoded_values_across_spaces() {
        // The default point of the frozen space and the full space decode to
        // the same physical configuration, so their keys must collide.
        let full = space(true);
        let frozen = space(false);
        let k_full = PointKey::of(&full, &full.default_point());
        let k_frozen = PointKey::of(&frozen, &frozen.default_point());
        assert_eq!(k_full, k_frozen);
    }

    #[test]
    fn distinct_points_get_distinct_keys() {
        let s = space(true);
        let mut rng = Pcg32::seeded(1);
        let mut keys = std::collections::HashSet::new();
        let mut flats = std::collections::HashSet::new();
        for _ in 0..100 {
            let p = s.random_point(&mut rng);
            keys.insert(PointKey::of(&s, &p));
            flats.insert(s.flat_index(&p));
        }
        // Values are a bijection of indices within one space.
        assert_eq!(keys.len(), flats.len());
    }

    #[test]
    fn hit_miss_accounting() {
        let s = space(true);
        let c = MeasureCache::new();
        let k = PointKey::of(&s, &s.default_point());
        assert!(c.get(&k).is_none());
        c.insert(k.clone(), dummy_result(0.5));
        assert_eq!(c.get(&k).unwrap().seconds, 0.5);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
    }

    #[test]
    fn preload_does_not_count() {
        let s = space(true);
        let c = MeasureCache::new();
        c.preload(PointKey::of(&s, &s.default_point()), dummy_result(1.0));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (0, 0, 1));
    }
}
