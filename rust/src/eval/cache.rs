//! Concurrent, point-keyed memoization of measurement results, with an
//! optional LRU bound for long-lived services.

use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::workload::Conv2dTask;
use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Canonical identity of one measurable configuration: the task shape plus
/// the *decoded knob values* (not value indices). Keying on values means the
/// same physical (hardware, software) configuration hits the same entry
/// whether it was planned in the full co-design space or a hardware-frozen
/// software-only space — which is what lets one `arco compare` run share
/// measurements across frameworks, and what makes the key portable across
/// processes (the journal and the `serve-measure` wire use this identity).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PointKey {
    pub task: Conv2dTask,
    /// One decoded value per knob, in space knob order.
    pub values: Vec<usize>,
}

impl PointKey {
    /// Key for `point` within `space`.
    pub fn of(space: &ConfigSpace, point: &PointConfig) -> PointKey {
        let values = space
            .knobs
            .iter()
            .zip(point.as_slice())
            .map(|(k, &i)| k.values[i])
            .collect();
        PointKey { task: space.task, values }
    }
}

/// Cache counters (monotonic over the cache's lifetime, except `entries`).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Lookups answered from the cache.
    pub hits: usize,
    /// Lookups that found nothing.
    pub misses: usize,
    /// Entries currently stored.
    pub entries: usize,
    /// Entries evicted to stay within the capacity bound.
    pub evictions: usize,
    /// Configured bound (`None` = unbounded).
    pub capacity: Option<usize>,
}

/// Sentinel index for "no node" in the intrusive LRU list.
const NIL: usize = usize::MAX;

struct Node {
    key: PointKey,
    result: MeasureResult,
    /// Towards the most-recently-used end.
    prev: usize,
    /// Towards the least-recently-used end.
    next: usize,
}

/// The state behind the lock: a hash index over an intrusive doubly-linked
/// recency list stored in a slab (`nodes` + `free`), giving O(1) get /
/// insert / evict without per-entry allocation churn.
struct LruInner {
    map: HashMap<PointKey, usize>,
    nodes: Vec<Node>,
    free: Vec<usize>,
    /// Most recently used, `NIL` when empty.
    head: usize,
    /// Least recently used, `NIL` when empty.
    tail: usize,
    evictions: usize,
}

impl LruInner {
    fn new() -> LruInner {
        LruInner {
            map: HashMap::new(),
            nodes: Vec::new(),
            free: Vec::new(),
            head: NIL,
            tail: NIL,
            evictions: 0,
        }
    }

    fn unlink(&mut self, idx: usize) {
        let (p, n) = (self.nodes[idx].prev, self.nodes[idx].next);
        if p == NIL {
            self.head = n;
        } else {
            self.nodes[p].next = n;
        }
        if n == NIL {
            self.tail = p;
        } else {
            self.nodes[n].prev = p;
        }
    }

    fn push_front(&mut self, idx: usize) {
        self.nodes[idx].prev = NIL;
        self.nodes[idx].next = self.head;
        if self.head != NIL {
            self.nodes[self.head].prev = idx;
        }
        self.head = idx;
        if self.tail == NIL {
            self.tail = idx;
        }
    }

    fn touch(&mut self, idx: usize) {
        if self.head != idx {
            self.unlink(idx);
            self.push_front(idx);
        }
    }

    fn get(&mut self, key: &PointKey) -> Option<MeasureResult> {
        let idx = *self.map.get(key)?;
        self.touch(idx);
        Some(self.nodes[idx].result)
    }

    fn insert(&mut self, key: PointKey, result: MeasureResult, capacity: Option<usize>) {
        if let Some(&idx) = self.map.get(&key) {
            self.nodes[idx].result = result;
            self.touch(idx);
            return;
        }
        if let Some(cap) = capacity {
            // Evict from the cold end until there is room for the new entry.
            while self.map.len() >= cap && self.tail != NIL {
                let victim = self.tail;
                self.unlink(victim);
                self.map.remove(&self.nodes[victim].key);
                self.free.push(victim);
                self.evictions += 1;
            }
        }
        let idx = match self.free.pop() {
            Some(slot) => {
                self.nodes[slot] = Node { key: key.clone(), result, prev: NIL, next: NIL };
                slot
            }
            None => {
                self.nodes.push(Node { key: key.clone(), result, prev: NIL, next: NIL });
                self.nodes.len() - 1
            }
        };
        self.push_front(idx);
        self.map.insert(key, idx);
    }
}

/// A thread-safe point-keyed result cache with optional LRU eviction.
///
/// A plain `Mutex` around the whole structure is deliberate: one lookup or
/// insert is tens of nanoseconds while one simulation is tens of
/// microseconds to milliseconds, so lock contention is irrelevant and the
/// simplicity pays for itself. `capacity: None` keeps every entry (the
/// right default for one tuning run, 10^3–10^5 entries); a bound makes the
/// cache safe inside a long-lived `serve-measure` fleet shard.
pub struct MeasureCache {
    inner: Mutex<LruInner>,
    capacity: Option<usize>,
    hits: AtomicUsize,
    misses: AtomicUsize,
}

impl MeasureCache {
    /// Unbounded cache.
    pub fn new() -> MeasureCache {
        MeasureCache::with_capacity(None)
    }

    /// Cache bounded to at most `capacity` entries, evicting the least
    /// recently used. `None` = unbounded; a bound of 0 is clamped to 1.
    pub fn with_capacity(capacity: Option<usize>) -> MeasureCache {
        MeasureCache {
            inner: Mutex::new(LruInner::new()),
            capacity: capacity.map(|c| c.max(1)),
            hits: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
        }
    }

    pub fn capacity(&self) -> Option<usize> {
        self.capacity
    }

    /// Look up a key, counting the hit or miss and refreshing recency.
    pub fn get(&self, key: &PointKey) -> Option<MeasureResult> {
        let found = super::sync::lock_unpoisoned(&self.inner).get(key);
        match found {
            Some(_) => self.hits.fetch_add(1, Ordering::Relaxed),
            None => self.misses.fetch_add(1, Ordering::Relaxed),
        };
        found
    }

    /// Like [`get`](Self::get), but a failed lookup is not counted as a
    /// miss — for the engine's under-lock re-check of keys whose miss was
    /// already counted by the first pass.
    pub fn get_hit_only(&self, key: &PointKey) -> Option<MeasureResult> {
        let found = super::sync::lock_unpoisoned(&self.inner).get(key);
        if found.is_some() {
            self.hits.fetch_add(1, Ordering::Relaxed);
        }
        found
    }

    /// Store a result. Only [`get`](Self::get) touches the hit/miss
    /// counters; inserts are not counted.
    pub fn insert(&self, key: PointKey, result: MeasureResult) {
        super::sync::lock_unpoisoned(&self.inner).insert(key, result, self.capacity);
    }

    /// Intent-named alias of [`insert`](Self::insert) for seeding entries
    /// from the journal at engine construction.
    pub fn preload(&self, key: PointKey, result: MeasureResult) {
        self.insert(key, result);
    }

    pub fn len(&self) -> usize {
        super::sync::lock_unpoisoned(&self.inner).map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn stats(&self) -> CacheStats {
        let inner = super::sync::lock_unpoisoned(&self.inner);
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            entries: inner.map.len(),
            evictions: inner.evictions,
            capacity: self.capacity,
        }
    }
}

impl Default for MeasureCache {
    fn default() -> Self {
        MeasureCache::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn space(hardware_tunable: bool) -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), hardware_tunable)
    }

    fn dummy_result(seconds: f64) -> MeasureResult {
        MeasureResult {
            seconds,
            cycles: (seconds * 1e8) as u64,
            gflops: 1.0,
            area_mm2: 2.0,
            occupancy: 0.5,
            valid: true,
        }
    }

    /// Distinct keys for testing: vary the batch dimension of the task.
    fn key_n(n: usize) -> PointKey {
        PointKey { task: Conv2dTask::new(n.max(1), 32, 28, 28, 32, 3, 3, 1, 1), values: vec![n] }
    }

    #[test]
    fn key_identifies_decoded_values_across_spaces() {
        // The default point of the frozen space and the full space decode to
        // the same physical configuration, so their keys must collide.
        let full = space(true);
        let frozen = space(false);
        let k_full = PointKey::of(&full, &full.default_point());
        let k_frozen = PointKey::of(&frozen, &frozen.default_point());
        assert_eq!(k_full, k_frozen);
    }

    #[test]
    fn distinct_points_get_distinct_keys() {
        let s = space(true);
        let mut rng = Pcg32::seeded(1);
        let mut keys = std::collections::HashSet::new();
        let mut flats = std::collections::HashSet::new();
        for _ in 0..100 {
            let p = s.random_point(&mut rng);
            keys.insert(PointKey::of(&s, &p));
            flats.insert(s.flat_index(&p));
        }
        // Values are a bijection of indices within one space.
        assert_eq!(keys.len(), flats.len());
    }

    #[test]
    fn hit_miss_accounting() {
        let s = space(true);
        let c = MeasureCache::new();
        let k = PointKey::of(&s, &s.default_point());
        assert!(c.get(&k).is_none());
        c.insert(k.clone(), dummy_result(0.5));
        assert_eq!(c.get(&k).unwrap().seconds, 0.5);
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (1, 1, 1));
        assert_eq!(st.evictions, 0);
        assert_eq!(st.capacity, None);
    }

    #[test]
    fn preload_does_not_count() {
        let s = space(true);
        let c = MeasureCache::new();
        c.preload(PointKey::of(&s, &s.default_point()), dummy_result(1.0));
        let st = c.stats();
        assert_eq!((st.hits, st.misses, st.entries), (0, 0, 1));
    }

    #[test]
    fn get_hit_only_counts_no_miss() {
        let c = MeasureCache::new();
        assert!(c.get_hit_only(&key_n(0)).is_none());
        c.insert(key_n(0), dummy_result(1.0));
        assert!(c.get_hit_only(&key_n(0)).is_some());
        let st = c.stats();
        assert_eq!((st.hits, st.misses), (1, 0));
    }

    #[test]
    fn bounded_cache_never_exceeds_capacity() {
        let c = MeasureCache::with_capacity(Some(4));
        for i in 0..32 {
            c.insert(key_n(i), dummy_result(i as f64));
            assert!(c.len() <= 4, "cache grew past capacity at insert {i}");
        }
        let st = c.stats();
        assert_eq!(st.entries, 4);
        assert_eq!(st.evictions, 28);
        assert_eq!(st.capacity, Some(4));
        // The newest 4 survive.
        for i in 28..32 {
            assert!(c.get(&key_n(i)).is_some(), "entry {i} should have survived");
        }
        assert!(c.get(&key_n(0)).is_none());
    }

    #[test]
    fn eviction_order_is_least_recently_used() {
        let c = MeasureCache::with_capacity(Some(3));
        for i in 0..3 {
            c.insert(key_n(i), dummy_result(i as f64));
        }
        // Touch 0 so it is the most recent; 1 becomes the coldest.
        assert!(c.get(&key_n(0)).is_some());
        c.insert(key_n(3), dummy_result(3.0));
        assert!(c.get(&key_n(1)).is_none(), "1 was coldest and must be evicted");
        assert!(c.get(&key_n(0)).is_some());
        assert!(c.get(&key_n(2)).is_some());
        assert!(c.get(&key_n(3)).is_some());
        assert_eq!(c.stats().evictions, 1);
    }

    #[test]
    fn reinsert_updates_without_growth() {
        let c = MeasureCache::with_capacity(Some(2));
        c.insert(key_n(0), dummy_result(1.0));
        c.insert(key_n(0), dummy_result(2.0));
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(&key_n(0)).unwrap().seconds, 2.0);
        assert_eq!(c.stats().evictions, 0);
    }

    #[test]
    fn slab_slots_are_reused_after_eviction() {
        let c = MeasureCache::with_capacity(Some(2));
        for i in 0..100 {
            c.insert(key_n(i), dummy_result(i as f64));
        }
        // 100 inserts through a capacity-2 cache must not grow the slab
        // beyond capacity + the one-slot high-water mark.
        let inner = c.inner.lock().unwrap();
        assert!(inner.nodes.len() <= 3, "slab leaked: {} nodes", inner.nodes.len());
    }

    #[test]
    fn zero_capacity_clamps_to_one() {
        let c = MeasureCache::with_capacity(Some(0));
        c.insert(key_n(0), dummy_result(1.0));
        c.insert(key_n(1), dummy_result(2.0));
        assert_eq!(c.len(), 1);
        assert!(c.get(&key_n(1)).is_some());
    }
}
