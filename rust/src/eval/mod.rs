//! The hardware-measurement layer: every `f[τ(Θ)]` evaluation in the
//! system flows through one [`Engine`] — in-process or across a fleet.
//!
//! The paper's frameworks are all bottlenecked on the expensive hardware
//! measurement call (§2.3). This module makes that call a first-class,
//! shared service instead of scattered `measure_point` invocations:
//!
//! - [`MeasureBackend`] abstracts *how* a configuration is measured:
//!   [`VtaSimBackend`] runs the full decode → lower → cycle-simulate path
//!   (the production oracle), [`AnalyticalBackend`] is a cheap roofline
//!   proxy for smoke tests, and [`RemoteBackend`] farms batches out to a
//!   fleet of `arco serve-measure` shards ([`BackendSpec`] selects:
//!   `vta-sim | analytical | remote:host:port[,...]`).
//! - [`MeasureCache`] memoizes results under a [`PointKey`] — the task
//!   shape plus *decoded knob values* — so the same physical configuration
//!   is recognized across frameworks, spaces (full vs. hardware-frozen),
//!   batches and processes. An optional LRU bound keeps long-lived service
//!   shards at a fixed memory footprint.
//! - [`Journal`] persists measurements as fingerprinted, append-only JSON
//!   lines ([`proto`] owns the record schema, [`Fingerprint`] the
//!   simulator identity), letting `arco compare` re-runs and long-lived
//!   services reuse prior work across processes — and refusing to mix
//!   numbers from different cycle models.
//! - [`Engine`] fronts all of it: it takes a *batch* of points,
//!   deduplicates within the batch, serves repeats from the cache,
//!   coalesces points that a concurrent batch is already measuring, sends
//!   the remaining misses to the backend (worker-pool fan-out locally,
//!   shard fan-out remotely), and records new results in the journal.
//!   Results come back in input order and are deterministic for a
//!   deterministic backend, independent of the worker count.
//! - [`server`] is the other side of the wire: `arco serve-measure`
//!   exposes any local backend as a network shard. A shard can be
//!   *warm-started* from a merged journal ([`merge_journals`] /
//!   `arco journal merge`) so it inherits the fleet's measurement history
//!   before its first batch, and [`RemoteBackend`] can place chunks
//!   [`Placement::Weighted`] by observed shard throughput so heterogeneous
//!   fleets stop waiting on their slowest member.
//! - [`BudgetLedger`] + [`Dispatcher`] ([`ledger`]) implement the paper's
//!   equal-budget protocol on top of all of it: per-(framework, task)
//!   measurement allowances charged before every batch, per-point
//!   fresh/cache-served provenance ([`Origin`]) settled after, and FIFO
//!   admission of concurrent tuning jobs so no framework monopolizes the
//!   fleet ("measure once, charge everyone").
//!
//! Call-site contract: nothing outside this module (and the backend impls
//! it owns) invokes [`crate::codegen::measure_point`] or the simulator on
//! the tuning path. Strategies plan points; the engine pays for them —
//! each unique configuration at most once.

pub mod backend;
pub mod cache;
pub mod calib;
pub mod cursor;
pub mod engine;
pub mod journal;
pub mod ledger;
pub mod proto;
pub mod remote;
pub mod server;
pub mod store;
pub(crate) mod sync;
pub mod tune_client;
pub mod tune_proto;
pub mod tune_server;

pub use crate::codegen::MeasureResult;
pub use backend::{
    analytical_terms, AnalyticalBackend, AnalyticalTerms, BackendKind, BackendSpec,
    MeasureBackend, Placement, ShardPlacement, VtaSimBackend, SEED_OVERLAP,
};
pub use cache::{CacheStats, MeasureCache, PointKey};
pub use calib::Calibration;
pub use engine::{Engine, EngineConfig, EngineStats, PairedBatch, PendingBatch, TracedBatch};
pub use journal::{
    compact_journal, merge_journals, CompactStats, Journal, JournalEntry, MergeStats,
};
pub use ledger::{Account, BudgetLedger, DispatchStats, Dispatcher, LedgerStats, TenantStats};
pub use proto::{Fingerprint, Origin, PROTO_VERSION};
pub use remote::{FleetLostError, RemoteBackend};
pub use store::{prune_store, store_stat, MeasureStore, PruneStats, StoreConfig, StoreStats};
pub use cursor::{Cursor, CursorKind, PageError, PagedTrace};
pub use server::{
    spawn as serve_measure, spawn_local as serve_measure_local,
    spawn_local_with as serve_measure_local_with, spawn_with as serve_measure_with, ServeOptions,
    ServerHandle,
};
pub use tune_client::{TracePage, TuneClient, WaitResult};
pub use tune_proto::{
    JobOutcome, JobSpec, JobState, JobStatus, TuneRequest, TuneResponse, TUNE_PROTO_VERSION,
};
pub use tune_server::{
    spawn_tune, spawn_tune_local, TuneServeOptions, TuneServerHandle,
};
