//! The hardware-measurement layer: every `f[τ(Θ)]` evaluation in the
//! system flows through one [`Engine`].
//!
//! The paper's frameworks are all bottlenecked on the expensive hardware
//! measurement call (§2.3). This module makes that call a first-class,
//! shared service instead of scattered `measure_point` invocations:
//!
//! - [`MeasureBackend`] abstracts *how* a configuration is measured:
//!   [`VtaSimBackend`] runs the full decode → lower → cycle-simulate path
//!   (the production oracle), [`AnalyticalBackend`] is a cheap roofline
//!   proxy for smoke tests and CI-scale scenario sweeps.
//! - [`MeasureCache`] memoizes results under a [`PointKey`] — the task
//!   shape plus *decoded knob values* — so the same physical configuration
//!   is recognized across frameworks, spaces (full vs. hardware-frozen) and
//!   batches.
//! - [`Journal`] persists measurements as JSON (via [`crate::util::json`]),
//!   letting `arco compare` re-runs and long-lived services reuse prior
//!   work across processes.
//! - [`Engine`] fronts all of it: it takes a *batch* of points,
//!   deduplicates within the batch, serves repeats from the cache, fans the
//!   misses out over the scoped worker pool ([`crate::util::pool`]), and
//!   records new results in the journal. Results come back in input order
//!   and are deterministic for a deterministic backend, independent of the
//!   worker count.
//!
//! Call-site contract: nothing outside this module (and the backend impls
//! it owns) invokes [`crate::codegen::measure_point`] or the simulator on
//! the tuning path. Strategies plan points; the engine pays for them —
//! each unique configuration at most once.

pub mod backend;
pub mod cache;
pub mod engine;
pub mod journal;

pub use crate::codegen::MeasureResult;
pub use backend::{AnalyticalBackend, BackendKind, MeasureBackend, VtaSimBackend};
pub use cache::{CacheStats, MeasureCache, PointKey};
pub use engine::{Engine, EngineConfig, EngineStats};
pub use journal::{Journal, JournalEntry};
