//! The measurement record schema, shared by the journal and the wire.
//!
//! One measurement's identity is `(backend, task, decoded knob values)` —
//! the same identity as [`PointKey`] — and its payload is a
//! [`MeasureResult`]. This module owns the JSON encoding of that record
//! plus everything layered on top of it:
//!
//! - [`Fingerprint`]: the simulator identity (cycle-model version +
//!   non-tunable [`VtaConfig`] defaults). Journal files stamp it in their
//!   header and `serve-measure` reports it in the handshake, so cached or
//!   remote numbers can never silently mix across different models.
//! - [`Request`] / [`Response`]: the `serve-measure` protocol. Messages are
//!   single-line JSON documents delimited by `\n` (a JSONL stream — compact
//!   `Json::dump` output never contains a raw newline), framed by
//!   [`read_frame`] / [`write_frame`].
//!
//! Protocol (version [`PROTO_VERSION`]), one request → one response per
//! line, any number of requests per connection:
//!
//! ```json
//! {"op":"ping"}
//!   → {"ok":true,"backend":"vta-sim","proto":1,"fingerprint":{...}}
//! {"op":"measure","task":{...},"points":[[1,16,16,1,1,7,7], ...]}
//!   → {"ok":true,"results":[{"valid":true,"seconds":1.2e-3, ...}, ...],
//!      "fresh":[true,false, ...]}
//! {"op":"stats"}
//!   → {"ok":true,"stats":{"batches":4, ...}}
//! anything else
//!   → {"ok":false,"error":"..."}
//! ```
//!
//! `points` carry *decoded knob values* in space knob order, not value
//! indices: both sides rebuild the identical [`ConfigSpace`] from the task
//! shape, so decoded values are the only portable point identity.
//!
//! # Two codecs, one schema
//!
//! Every message exists in two encodings that produce and accept the same
//! bytes: the original `Json` tree functions (`*_to_json` / `*_from_json`,
//! kept for configs, reports and as the compatibility fallback) and the
//! zero-copy streaming functions (`write_*_frame`, `*_from_line`,
//! [`write_record_line`], [`record_from_line`]) built on
//! [`crate::util::json::stream`]. The streaming writers are byte-identical
//! to `Json::dump` of the tree encoding, with one deliberate exception:
//! integer fields (`cycles`) are written exactly over the full `u64` range,
//! where the `f64` tree detour silently corrupts values above 2^53. The
//! streaming decoders are strict about the shapes our own writers emit and
//! fall back to the lenient tree decoder for anything unusual, so old
//! journals and version-skewed peers parse exactly as before.

use super::cache::PointKey;
use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::util::json::stream::{Reader, StreamWriter, Token};
use crate::util::json::Json;
use crate::vta::{VtaConfig, CYCLE_MODEL_VERSION};
use crate::workload::Conv2dTask;
use std::io::{BufRead, Write};

/// Version of the request/response schema below. Bumped on any
/// incompatible change; the client refuses servers speaking another one.
/// (The per-point `fresh` array on measure responses is an *additive*
/// field — absent means all-fresh — so it did not bump the version.)
pub const PROTO_VERSION: u64 = 1;

/// Where a measured point's number came from, from the perspective of the
/// engine that served the batch. Only [`Origin::Fresh`] cost simulator (or,
/// on a real testbed, hardware) time *for this batch*; every other origin
/// was paid for earlier or by someone else — which is exactly the
/// distinction the equal-budget protocol's [`super::ledger::BudgetLedger`]
/// needs to charge every framework identically while only the first
/// requester pays the wall-clock ("measure once, charge everyone").
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Origin {
    /// The backend actually ran for this point in this batch.
    Fresh,
    /// Served from the engine's in-memory cache (an earlier batch, or a
    /// journal seed, already paid for it).
    Cached,
    /// Repeat of an earlier point within the same batch.
    Dedup,
    /// Waited on a concurrent batch's in-flight measurement of the point.
    Coalesced,
    /// A fleet shard answered from its own shared state (another tenant or
    /// an earlier run already paid); the fleet did not re-simulate.
    ShardCached,
    /// Answered from the shared measurement store (`--store`): some
    /// process, possibly long dead, measured the point under the same
    /// fingerprint and persisted it fleet-wide.
    StoreServed,
}

impl Origin {
    /// Did this measurement cost fresh simulator/hardware time anywhere?
    pub fn is_fresh(self) -> bool {
        matches!(self, Origin::Fresh)
    }
}

/// Identity of the measurement model a process embeds: the cycle-model
/// version plus the non-tunable [`VtaConfig`] defaults (buffer sizes,
/// clock, DRAM interface — everything the design space does *not* expose
/// as a knob). Two processes with equal fingerprints produce identical
/// numbers for identical points; anything else must not share them.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Fingerprint {
    /// [`crate::vta::CYCLE_MODEL_VERSION`] of the producing binary.
    pub cycle_model: u32,
    /// [`super::backend::ANALYTICAL_MODEL_VERSION`] of the producing
    /// binary (the roofline proxy drifts independently of the simulator).
    pub analytical_model: u32,
    /// Input scratchpad KiB.
    pub inp_buf_kib: usize,
    /// Weight scratchpad KiB.
    pub wgt_buf_kib: usize,
    /// Accumulator scratchpad KiB.
    pub acc_buf_kib: usize,
    /// Micro-op cache KiB.
    pub uop_buf_kib: usize,
    /// Core clock MHz.
    pub freq_mhz: usize,
    /// DRAM bytes per cycle.
    pub dram_bytes_per_cycle: usize,
    /// DMA setup latency in cycles.
    pub dma_latency: usize,
    /// ALU vector lanes.
    pub alu_lanes: usize,
}

impl Fingerprint {
    /// The fingerprint of *this* binary.
    pub fn current() -> Fingerprint {
        let d = VtaConfig::default();
        Fingerprint {
            cycle_model: CYCLE_MODEL_VERSION,
            analytical_model: super::backend::ANALYTICAL_MODEL_VERSION,
            inp_buf_kib: d.inp_buf_kib,
            wgt_buf_kib: d.wgt_buf_kib,
            acc_buf_kib: d.acc_buf_kib,
            uop_buf_kib: d.uop_buf_kib,
            freq_mhz: d.freq_mhz,
            dram_bytes_per_cycle: d.dram_bytes_per_cycle,
            dma_latency: d.dma_latency,
            alu_lanes: d.alu_lanes,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cycle_model", Json::num(self.cycle_model as f64)),
            ("analytical_model", Json::num(self.analytical_model as f64)),
            ("inp_buf_kib", Json::num(self.inp_buf_kib as f64)),
            ("wgt_buf_kib", Json::num(self.wgt_buf_kib as f64)),
            ("acc_buf_kib", Json::num(self.acc_buf_kib as f64)),
            ("uop_buf_kib", Json::num(self.uop_buf_kib as f64)),
            ("freq_mhz", Json::num(self.freq_mhz as f64)),
            ("dram_bytes_per_cycle", Json::num(self.dram_bytes_per_cycle as f64)),
            ("dma_latency", Json::num(self.dma_latency as f64)),
            ("alu_lanes", Json::num(self.alu_lanes as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Fingerprint> {
        Some(Fingerprint {
            cycle_model: v.get_usize("cycle_model")? as u32,
            analytical_model: v.get_usize("analytical_model")? as u32,
            inp_buf_kib: v.get_usize("inp_buf_kib")?,
            wgt_buf_kib: v.get_usize("wgt_buf_kib")?,
            acc_buf_kib: v.get_usize("acc_buf_kib")?,
            uop_buf_kib: v.get_usize("uop_buf_kib")?,
            freq_mhz: v.get_usize("freq_mhz")?,
            dram_bytes_per_cycle: v.get_usize("dram_bytes_per_cycle")?,
            dma_latency: v.get_usize("dma_latency")?,
            alu_lanes: v.get_usize("alu_lanes")?,
        })
    }

    /// One-line rendering for mismatch diagnostics.
    pub fn describe(&self) -> String {
        format!(
            "cycle-model v{} analytical v{} bufs {}/{}/{}/{} KiB {} MHz dram {} B/cyc dma {} alu {}",
            self.cycle_model,
            self.analytical_model,
            self.inp_buf_kib,
            self.wgt_buf_kib,
            self.acc_buf_kib,
            self.uop_buf_kib,
            self.freq_mhz,
            self.dram_bytes_per_cycle,
            self.dma_latency,
            self.alu_lanes
        )
    }
}

/// Encode a result's payload fields onto an existing record object.
fn push_result_fields(fields: &mut Vec<(&'static str, Json)>, r: &MeasureResult) {
    fields.push(("valid", Json::Bool(r.valid)));
    // Infinite runtimes (invalid configs) serialize as null.
    fields.push(("seconds", Json::num(r.seconds)));
    fields.push(("cycles", Json::num(r.cycles as f64)));
    fields.push(("gflops", Json::num(r.gflops)));
    fields.push(("area_mm2", Json::num(r.area_mm2)));
    fields.push(("occupancy", Json::num(r.occupancy)));
}

/// JSON object carrying just a [`MeasureResult`] (wire responses).
pub fn result_to_json(r: &MeasureResult) -> Json {
    let mut fields = Vec::with_capacity(6);
    push_result_fields(&mut fields, r);
    Json::obj(fields)
}

/// Inverse of [`result_to_json`]; invalid results are restored with
/// infinite runtime whatever `seconds` holds.
pub fn result_from_json(v: &Json) -> Option<MeasureResult> {
    let valid = v.get_bool("valid")?;
    let seconds = if valid { v.get_f64("seconds")? } else { f64::INFINITY };
    Some(MeasureResult {
        seconds,
        cycles: v.get_f64("cycles").unwrap_or(0.0) as u64,
        gflops: v.get_f64("gflops").unwrap_or(0.0),
        area_mm2: v.get_f64("area_mm2").unwrap_or(0.0),
        occupancy: v.get_f64("occupancy").unwrap_or(0.0),
        valid,
    })
}

/// Full journal record: identity + payload on one object.
pub fn record_to_json(backend: &str, key: &PointKey, r: &MeasureResult) -> Json {
    let mut fields: Vec<(&'static str, Json)> = Vec::with_capacity(9);
    fields.push(("backend", Json::str(backend.to_string())));
    fields.push(("task", key.task.to_json()));
    fields.push(("values", values_to_json(&key.values)));
    push_result_fields(&mut fields, r);
    Json::obj(fields)
}

/// Inverse of [`record_to_json`].
pub fn record_from_json(v: &Json) -> Option<(String, PointKey, MeasureResult)> {
    let backend = v.get_str("backend")?.to_string();
    let task = Conv2dTask::from_json(v.get("task")?)?;
    let values = values_from_json(v.get("values")?)?;
    let result = result_from_json(v)?;
    Some((backend, PointKey { task, values }, result))
}

pub fn values_to_json(values: &[usize]) -> Json {
    Json::Arr(values.iter().map(|&v| Json::num(v as f64)).collect())
}

pub fn values_from_json(v: &Json) -> Option<Vec<usize>> {
    v.as_arr()?.iter().map(Json::as_usize).collect()
}

/// Map decoded knob values back to a point of `space`. `None` when the
/// arity is wrong or a value is not one of the knob's candidates (a
/// version-skewed peer, not a measurable configuration).
pub fn point_from_values(space: &ConfigSpace, values: &[usize]) -> Option<PointConfig> {
    if values.len() != space.num_knobs() {
        return None;
    }
    let idx = space
        .knobs
        .iter()
        .zip(values)
        .map(|(k, v)| k.values.iter().position(|x| x == v))
        .collect::<Option<Vec<usize>>>()?;
    Some(PointConfig(idx))
}

/// One client → server message.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Handshake: who are you, what model do you embed?
    Ping,
    /// Measure a batch of points of one task (decoded knob values).
    Measure { task: Conv2dTask, points: Vec<Vec<usize>> },
    /// Engine counters (diagnostics).
    Stats,
}

impl Request {
    pub fn to_json(&self) -> Json {
        match self {
            Request::Ping => Json::obj(vec![("op", Json::str("ping"))]),
            Request::Measure { task, points } => Json::obj(vec![
                ("op", Json::str("measure")),
                ("task", task.to_json()),
                ("points", Json::Arr(points.iter().map(|v| values_to_json(v)).collect())),
            ]),
            Request::Stats => Json::obj(vec![("op", Json::str("stats"))]),
        }
    }

    pub fn from_json(v: &Json) -> Option<Request> {
        match v.get_str("op")? {
            "ping" => Some(Request::Ping),
            "stats" => Some(Request::Stats),
            "measure" => {
                let task = Conv2dTask::from_json(v.get("task")?)?;
                let points = v
                    .get("points")?
                    .as_arr()?
                    .iter()
                    .map(values_from_json)
                    .collect::<Option<Vec<_>>>()?;
                Some(Request::Measure { task, points })
            }
            _ => None,
        }
    }
}

/// One server → client message.
#[derive(Debug, Clone, PartialEq)]
pub enum Response {
    /// Handshake reply. `preloaded` is the number of cache entries the
    /// shard's engine seeded from persistent history (its journal plus any
    /// `--warm-start` file) before accepting batches — inherited fleet
    /// coverage a client can log. Additive field: a peer that omits it is
    /// read as 0.
    Pong { backend: String, proto: u64, fingerprint: Fingerprint, preloaded: usize },
    /// Batch results, in request point order. `fresh[i]` reports whether
    /// the shard actually simulated point `i` for this request (`true`) or
    /// answered it from shared state — its cache, in-batch dedup, or a
    /// coalesced concurrent batch (`false`). Budget ledgers on the client
    /// side use this to tell fleet-fresh from fleet-cached work.
    /// `active_batches` piggybacks the shard's queue depth (batches still
    /// being measured for *other* requests as this reply was built), so
    /// weighted placement gets its load signal for free instead of paying
    /// one extra `stats` round trip per batch. Additive field: `None`
    /// from an older peer, and clients fall back to polling then.
    Results { results: Vec<MeasureResult>, fresh: Vec<bool>, active_batches: Option<usize> },
    /// Engine counters as a free-form object.
    Stats(Json),
    /// The request could not be served (malformed, unknown op, skew).
    Error(String),
}

impl Response {
    pub fn to_json(&self) -> Json {
        match self {
            Response::Pong { backend, proto, fingerprint, preloaded } => Json::obj(vec![
                ("ok", Json::Bool(true)),
                ("backend", Json::str(backend.clone())),
                ("proto", Json::num(*proto as f64)),
                ("fingerprint", fingerprint.to_json()),
                ("preloaded", Json::num(*preloaded as f64)),
            ]),
            Response::Results { results, fresh, active_batches } => {
                let mut fields = vec![
                    ("ok", Json::Bool(true)),
                    ("results", Json::Arr(results.iter().map(result_to_json).collect())),
                    ("fresh", Json::Arr(fresh.iter().map(|&f| Json::Bool(f)).collect())),
                ];
                if let Some(depth) = active_batches {
                    fields.push(("active_batches", Json::num(*depth as f64)));
                }
                Json::obj(fields)
            }
            Response::Stats(stats) => {
                Json::obj(vec![("ok", Json::Bool(true)), ("stats", stats.clone())])
            }
            Response::Error(msg) => Json::obj(vec![
                ("ok", Json::Bool(false)),
                ("error", Json::str(msg.clone())),
            ]),
        }
    }

    pub fn from_json(v: &Json) -> Option<Response> {
        if !v.get_bool("ok")? {
            return Some(Response::Error(v.get_str("error").unwrap_or("unspecified").to_string()));
        }
        if let Some(results) = v.get("results") {
            let rs = results
                .as_arr()?
                .iter()
                .map(result_from_json)
                .collect::<Option<Vec<_>>>()?;
            // Additive field: a peer that omits it (or sends a malformed
            // length) is treated as all-fresh, the conservative charge.
            let mut fresh: Vec<bool> = v
                .get("fresh")
                .and_then(Json::as_arr)
                .map(|a| a.iter().map(|b| b.as_bool().unwrap_or(true)).collect())
                .unwrap_or_default();
            fresh.resize(rs.len(), true);
            // Additive field: an older peer omits the piggybacked queue
            // depth and the client keeps polling `stats` instead.
            let active_batches = v.get_usize("active_batches");
            return Some(Response::Results { results: rs, fresh, active_batches });
        }
        if let Some(stats) = v.get("stats") {
            return Some(Response::Stats(stats.clone()));
        }
        if let Some(backend) = v.get_str("backend") {
            return Some(Response::Pong {
                backend: backend.to_string(),
                proto: v.get_usize("proto")? as u64,
                fingerprint: Fingerprint::from_json(v.get("fingerprint")?)?,
                // Additive field: absent (an older peer) means nothing
                // preloaded.
                preloaded: v.get_usize("preloaded").unwrap_or(0),
            });
        }
        None
    }
}

/// Write one message as a compact single-line JSON document.
pub fn write_frame(w: &mut impl Write, v: &Json) -> std::io::Result<()> {
    let mut line = v.dump();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.flush()
}

/// Read one message; `Ok(None)` on a clean EOF before any bytes.
pub fn read_frame(r: &mut impl BufRead) -> anyhow::Result<Option<Json>> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    let text = line.trim_end_matches(['\n', '\r']);
    if text.is_empty() {
        return Ok(Some(Json::Null));
    }
    let v = Json::parse(text).map_err(|e| anyhow::anyhow!("malformed frame: {e}"))?;
    Ok(Some(v))
}

// ---------------------------------------------------------------------------
// Streaming codec: the zero-copy hot path over the same schema.
// ---------------------------------------------------------------------------

/// Read one raw frame line without parsing it; `Ok(None)` on a clean EOF
/// before any bytes. Trailing `\n`/`\r` are stripped; hand the line to
/// [`request_from_line`] / [`response_from_line`] / [`record_from_line`].
pub fn read_frame_line(r: &mut impl BufRead) -> std::io::Result<Option<String>> {
    let mut line = String::new();
    let n = r.read_line(&mut line)?;
    if n == 0 {
        return Ok(None);
    }
    while line.ends_with('\n') || line.ends_with('\r') {
        line.pop();
    }
    Ok(Some(line))
}

/// Streaming twin of [`push_result_fields`], byte-identical except that
/// `cycles` is written exactly (full `u64` range, not via `f64`).
fn write_result_fields<W: Write>(
    sw: &mut StreamWriter<W>,
    r: &MeasureResult,
) -> std::io::Result<()> {
    sw.key("valid")?;
    sw.bool_val(r.valid)?;
    // Infinite runtimes (invalid configs) serialize as null.
    sw.key("seconds")?;
    sw.f64_val(r.seconds)?;
    sw.key("cycles")?;
    sw.u64_val(r.cycles)?;
    sw.key("gflops")?;
    sw.f64_val(r.gflops)?;
    sw.key("area_mm2")?;
    sw.f64_val(r.area_mm2)?;
    sw.key("occupancy")?;
    sw.f64_val(r.occupancy)
}

/// Serialize one record as a journal line (record + `\n`) straight into
/// `w`, no intermediate tree or string. Byte-identical to
/// `record_to_json(..).dump() + "\n"` for every value the tree can
/// represent exactly; `cycles` above 2^53 are written exactly where the
/// tree encoding would corrupt them.
pub fn write_record_line<W: Write>(
    w: &mut W,
    backend: &str,
    key: &PointKey,
    r: &MeasureResult,
) -> std::io::Result<()> {
    let mut sw = StreamWriter::new(&mut *w);
    sw.begin_obj()?;
    sw.key("backend")?;
    sw.str_val(backend)?;
    sw.key("task")?;
    key.task.write_stream(&mut sw)?;
    sw.key("values")?;
    sw.begin_arr()?;
    for &v in &key.values {
        sw.usize_val(v)?;
    }
    sw.end_arr()?;
    write_result_fields(&mut sw, r)?;
    sw.end_obj()?;
    w.write_all(b"\n")
}

/// Streaming decode of a full record line. Strict fast path for the shape
/// our writers emit (any field order, unknown fields skipped lazily);
/// falls back to the tree decoder so anything the old parser accepted
/// still parses. `None` means the line is not a record either way.
pub fn record_from_line(line: &str) -> Option<(String, PointKey, MeasureResult)> {
    if let Some(rec) = record_from_line_strict(line) {
        return Some(rec);
    }
    record_from_json(&Json::parse(line).ok()?)
}

/// Lazily extract just the `(backend, task, values)` identity of a record
/// line, skipping the payload subtrees without materializing them — the
/// dedup/routing hot path of journal replay, merge and compact.
pub fn record_identity_from_line(line: &str) -> Option<(String, PointKey)> {
    if let Some(id) = record_identity_from_line_strict(line) {
        return Some(id);
    }
    let (backend, key, _) = record_from_json(&Json::parse(line).ok()?)?;
    Some((backend, key))
}

fn record_from_line_strict(line: &str) -> Option<(String, PointKey, MeasureResult)> {
    let mut r = Reader::new(line);
    if !matches!(r.next_token()?, Token::ObjStart) {
        return None;
    }
    let mut backend: Option<String> = None;
    let mut task: Option<Conv2dTask> = None;
    let mut values: Option<Vec<usize>> = None;
    let mut valid: Option<bool> = None;
    let mut seconds: Option<f64> = None;
    let mut cycles = 0u64;
    let mut gflops = 0.0f64;
    let mut area_mm2 = 0.0f64;
    let mut occupancy = 0.0f64;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "backend" => match r.next_token()? {
                    Token::Str(s) => backend = Some(s.into_owned()),
                    _ => return None,
                },
                "task" => task = Some(Conv2dTask::from_stream(&mut r)?),
                "values" => values = Some(values_from_stream(&mut r)?),
                "valid" => match r.next_token()? {
                    Token::Bool(b) => valid = Some(b),
                    _ => return None,
                },
                "seconds" => match r.next_token()? {
                    Token::Num(n) => seconds = Some(n.as_f64()),
                    // Our writer spells the infinite runtime of invalid
                    // configs as null; the tree decoder reads it as
                    // "absent", which `valid: false` below restores.
                    Token::Null => {}
                    _ => return None,
                },
                "cycles" => match r.next_token()? {
                    // Exact for the full u64 range; saturating f64 cast
                    // for exotic spellings, matching the tree decoder.
                    Token::Num(n) => {
                        cycles = n.as_u64().unwrap_or_else(|| n.as_f64() as u64);
                    }
                    _ => return None,
                },
                "gflops" => match r.next_token()? {
                    Token::Num(n) => gflops = n.as_f64(),
                    _ => return None,
                },
                "area_mm2" => match r.next_token()? {
                    Token::Num(n) => area_mm2 = n.as_f64(),
                    _ => return None,
                },
                "occupancy" => match r.next_token()? {
                    Token::Num(n) => occupancy = n.as_f64(),
                    _ => return None,
                },
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    if !r.at_end() {
        return None;
    }
    let valid = valid?;
    let seconds = if valid { seconds? } else { f64::INFINITY };
    Some((
        backend?,
        PointKey { task: task?, values: values? },
        MeasureResult { seconds, cycles, gflops, area_mm2, occupancy, valid },
    ))
}

fn record_identity_from_line_strict(line: &str) -> Option<(String, PointKey)> {
    let mut r = Reader::new(line);
    if !matches!(r.next_token()?, Token::ObjStart) {
        return None;
    }
    let mut backend: Option<String> = None;
    let mut task: Option<Conv2dTask> = None;
    let mut values: Option<Vec<usize>> = None;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "backend" => match r.next_token()? {
                    Token::Str(s) => backend = Some(s.into_owned()),
                    _ => return None,
                },
                "task" => task = Some(Conv2dTask::from_stream(&mut r)?),
                "values" => values = Some(values_from_stream(&mut r)?),
                // Payload (and unknown) fields are skipped, never built.
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    if !r.at_end() {
        return None;
    }
    Some((backend?, PointKey { task: task?, values: values? }))
}

/// Streaming decode of a decoded-knob-values array, in value position.
pub fn values_from_stream(r: &mut Reader<'_>) -> Option<Vec<usize>> {
    if !matches!(r.next_token()?, Token::ArrStart) {
        return None;
    }
    values_rest_from_stream(r)
}

/// Elements + closing `]` of a values array whose `[` is already consumed.
fn values_rest_from_stream(r: &mut Reader<'_>) -> Option<Vec<usize>> {
    let mut out = Vec::with_capacity(8);
    loop {
        match r.next_token()? {
            Token::ArrEnd => return Some(out),
            Token::Num(n) => out.push(n.as_usize()?),
            _ => return None,
        }
    }
}

/// Serialize a request as one frame straight into the socket writer.
/// Byte-identical to `write_frame(w, &req.to_json())`; the hot `measure`
/// op never builds a tree.
pub fn write_request_frame<W: Write>(w: &mut W, req: &Request) -> std::io::Result<()> {
    match req {
        Request::Measure { task, points } => {
            let mut sw = StreamWriter::new(&mut *w);
            sw.begin_obj()?;
            sw.key("op")?;
            sw.str_val("measure")?;
            sw.key("task")?;
            task.write_stream(&mut sw)?;
            sw.key("points")?;
            sw.begin_arr()?;
            for values in points {
                sw.begin_arr()?;
                for &v in values {
                    sw.usize_val(v)?;
                }
                sw.end_arr()?;
            }
            sw.end_arr()?;
            sw.end_obj()?;
            w.write_all(b"\n")?;
            w.flush()
        }
        // Ping/Stats are tiny one-field objects, once per connection.
        _ => write_frame(w, &req.to_json()),
    }
}

/// Serialize a response as one frame straight into the socket writer.
/// Byte-identical to `write_frame(w, &resp.to_json())`; the hot `results`
/// frame never builds a tree.
pub fn write_response_frame<W: Write>(w: &mut W, resp: &Response) -> std::io::Result<()> {
    match resp {
        Response::Results { results, fresh, active_batches } => {
            let mut sw = StreamWriter::new(&mut *w);
            sw.begin_obj()?;
            sw.key("ok")?;
            sw.bool_val(true)?;
            sw.key("results")?;
            sw.begin_arr()?;
            for r in results {
                sw.begin_obj()?;
                write_result_fields(&mut sw, r)?;
                sw.end_obj()?;
            }
            sw.end_arr()?;
            sw.key("fresh")?;
            sw.begin_arr()?;
            for &f in fresh {
                sw.bool_val(f)?;
            }
            sw.end_arr()?;
            if let Some(depth) = active_batches {
                sw.key("active_batches")?;
                sw.usize_val(*depth)?;
            }
            sw.end_obj()?;
            w.write_all(b"\n")?;
            w.flush()
        }
        // Pong / Stats / Error are off the per-batch hot path.
        _ => write_frame(w, &resp.to_json()),
    }
}

/// Zero-copy request decode: strict streaming fast path for the hot
/// `measure` op, tree fallback for everything else (ping, stats, unknown
/// ops, odd spellings). `None` means not a request either way.
pub fn request_from_line(line: &str) -> Option<Request> {
    if let Some(req) = measure_request_from_line(line) {
        return Some(req);
    }
    Request::from_json(&Json::parse(line).ok()?)
}

fn measure_request_from_line(line: &str) -> Option<Request> {
    let mut r = Reader::new(line);
    if !matches!(r.next_token()?, Token::ObjStart) {
        return None;
    }
    let mut is_measure = false;
    let mut task: Option<Conv2dTask> = None;
    let mut points: Option<Vec<Vec<usize>>> = None;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "op" => match r.next_token()? {
                    Token::Str(s) if s == "measure" => is_measure = true,
                    _ => return None,
                },
                "task" => task = Some(Conv2dTask::from_stream(&mut r)?),
                "points" => {
                    if !matches!(r.next_token()?, Token::ArrStart) {
                        return None;
                    }
                    let mut ps: Vec<Vec<usize>> = Vec::new();
                    loop {
                        match r.next_token()? {
                            Token::ArrEnd => break,
                            Token::ArrStart => ps.push(values_rest_from_stream(&mut r)?),
                            _ => return None,
                        }
                    }
                    points = Some(ps);
                }
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    if !is_measure || !r.at_end() {
        return None;
    }
    Some(Request::Measure { task: task?, points: points? })
}

/// Zero-copy response decode: strict streaming fast path for the hot
/// `results` frame, tree fallback for pong / stats / error frames and any
/// unusual spelling. `None` means not a response either way.
pub fn response_from_line(line: &str) -> Option<Response> {
    if let Some(resp) = results_response_from_line(line) {
        return Some(resp);
    }
    Response::from_json(&Json::parse(line).ok()?)
}

fn results_response_from_line(line: &str) -> Option<Response> {
    let mut r = Reader::new(line);
    if !matches!(r.next_token()?, Token::ObjStart) {
        return None;
    }
    let mut ok: Option<bool> = None;
    let mut results: Option<Vec<MeasureResult>> = None;
    let mut fresh: Option<Vec<bool>> = None;
    let mut active_batches: Option<usize> = None;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "ok" => match r.next_token()? {
                    Token::Bool(b) => ok = Some(b),
                    _ => return None,
                },
                "results" => {
                    if !matches!(r.next_token()?, Token::ArrStart) {
                        return None;
                    }
                    let mut rs = Vec::new();
                    loop {
                        match r.next_token()? {
                            Token::ArrEnd => break,
                            Token::ObjStart => rs.push(result_rest_from_stream(&mut r)?),
                            _ => return None,
                        }
                    }
                    results = Some(rs);
                }
                "fresh" => {
                    if !matches!(r.next_token()?, Token::ArrStart) {
                        return None;
                    }
                    let mut fs = Vec::new();
                    loop {
                        match r.next_token()? {
                            Token::ArrEnd => break,
                            Token::Bool(b) => fs.push(b),
                            // The tree decoder charges malformed entries
                            // as fresh (the conservative reading).
                            Token::Num(_) | Token::Str(_) | Token::Null => fs.push(true),
                            _ => return None,
                        }
                    }
                    fresh = Some(fs);
                }
                "active_batches" => match r.next_token()? {
                    // Non-integer spellings read as absent, like the tree.
                    Token::Num(n) => active_batches = n.as_usize(),
                    _ => return None,
                },
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    if !r.at_end() || !ok? {
        return None;
    }
    let results = results?;
    let mut fresh = fresh.unwrap_or_default();
    fresh.resize(results.len(), true);
    Some(Response::Results { results, fresh, active_batches })
}

/// Fields + closing `}` of a result object whose `{` is already consumed.
fn result_rest_from_stream(r: &mut Reader<'_>) -> Option<MeasureResult> {
    let mut valid: Option<bool> = None;
    let mut seconds: Option<f64> = None;
    let mut cycles = 0u64;
    let mut gflops = 0.0f64;
    let mut area_mm2 = 0.0f64;
    let mut occupancy = 0.0f64;
    loop {
        match r.next_token()? {
            Token::ObjEnd => break,
            Token::Key(k) => match k.as_ref() {
                "valid" => match r.next_token()? {
                    Token::Bool(b) => valid = Some(b),
                    _ => return None,
                },
                "seconds" => match r.next_token()? {
                    Token::Num(n) => seconds = Some(n.as_f64()),
                    Token::Null => {}
                    _ => return None,
                },
                "cycles" => match r.next_token()? {
                    Token::Num(n) => {
                        cycles = n.as_u64().unwrap_or_else(|| n.as_f64() as u64);
                    }
                    _ => return None,
                },
                "gflops" => match r.next_token()? {
                    Token::Num(n) => gflops = n.as_f64(),
                    _ => return None,
                },
                "area_mm2" => match r.next_token()? {
                    Token::Num(n) => area_mm2 = n.as_f64(),
                    _ => return None,
                },
                "occupancy" => match r.next_token()? {
                    Token::Num(n) => occupancy = n.as_f64(),
                    _ => return None,
                },
                _ => r.skip_value().ok()?,
            },
            _ => return None,
        }
    }
    let valid = valid?;
    let seconds = if valid { seconds? } else { f64::INFINITY };
    Some(MeasureResult { seconds, cycles, gflops, area_mm2, occupancy, valid })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    #[test]
    fn fingerprint_roundtrips_and_detects_drift() {
        let fp = Fingerprint::current();
        assert_eq!(Fingerprint::from_json(&fp.to_json()), Some(fp.clone()));
        let mut other = fp.clone();
        other.cycle_model += 1;
        assert_ne!(fp, other);
        let mut other = fp.clone();
        other.analytical_model += 1;
        assert_ne!(fp, other);
        let mut other = fp.clone();
        other.wgt_buf_kib *= 2;
        assert_ne!(fp, other);
    }

    #[test]
    fn record_roundtrips_valid_and_invalid() {
        let s = space();
        let mut rng = Pcg32::seeded(6);
        for _ in 0..20 {
            let p = s.random_point(&mut rng);
            let key = PointKey::of(&s, &p);
            let r = crate::codegen::measure_point(&s, &p);
            let (backend, key2, r2) =
                record_from_json(&record_to_json("vta-sim", &key, &r)).unwrap();
            assert_eq!(backend, "vta-sim");
            assert_eq!(key2, key);
            if r.valid {
                assert_eq!(r2, r);
            } else {
                assert!(!r2.valid);
                assert!(r2.seconds.is_infinite());
            }
        }
    }

    #[test]
    fn point_values_roundtrip_through_wire_identity() {
        let s = space();
        let mut rng = Pcg32::seeded(8);
        for _ in 0..50 {
            let p = s.random_point(&mut rng);
            let key = PointKey::of(&s, &p);
            assert_eq!(point_from_values(&s, &key.values), Some(p));
        }
        // Wrong arity and non-candidate values are rejected.
        assert!(point_from_values(&s, &[1, 2]).is_none());
        let mut vals = PointKey::of(&s, &s.default_point()).values;
        vals[0] = 999;
        assert!(point_from_values(&s, &vals).is_none());
    }

    #[test]
    fn requests_roundtrip() {
        let s = space();
        let key = PointKey::of(&s, &s.default_point());
        for req in [
            Request::Ping,
            Request::Stats,
            Request::Measure { task: s.task, points: vec![key.values.clone(), key.values] },
        ] {
            assert_eq!(Request::from_json(&req.to_json()), Some(req));
        }
        assert_eq!(Request::from_json(&Json::obj(vec![("op", Json::str("nope"))])), None);
    }

    #[test]
    fn responses_roundtrip() {
        let s = space();
        let r = crate::codegen::measure_point(&s, &s.default_point());
        for resp in [
            Response::Pong {
                backend: "vta-sim".into(),
                proto: PROTO_VERSION,
                fingerprint: Fingerprint::current(),
                preloaded: 123,
            },
            Response::Results { results: vec![r, r], fresh: vec![true, false], active_batches: Some(2) },
            Response::Results { results: vec![r], fresh: vec![true], active_batches: None },
            Response::Stats(Json::obj(vec![("batches", Json::num(3.0))])),
            Response::Error("boom".into()),
        ] {
            assert_eq!(Response::from_json(&resp.to_json()), Some(resp));
        }
    }

    #[test]
    fn pong_without_preloaded_field_defaults_to_zero() {
        // Compatibility: `preloaded` is additive; an older peer that omits
        // it handshakes as cold.
        let pong = Response::Pong {
            backend: "vta-sim".into(),
            proto: PROTO_VERSION,
            fingerprint: Fingerprint::current(),
            preloaded: 99,
        };
        let mut json = pong.to_json();
        if let Json::Obj(fields) = &mut json {
            fields.retain(|(k, _)| k != "preloaded");
        }
        match Response::from_json(&json).unwrap() {
            Response::Pong { preloaded, .. } => assert_eq!(preloaded, 0),
            other => panic!("expected pong, got {other:?}"),
        }
    }

    #[test]
    fn results_without_fresh_field_default_to_all_fresh() {
        // Compatibility: a peer that omits the additive `fresh` array is
        // charged conservatively (everything fresh).
        let s = space();
        let r = crate::codegen::measure_point(&s, &s.default_point());
        let mut json = Response::Results {
            results: vec![r, r],
            fresh: vec![false, false],
            active_batches: Some(1),
        }
        .to_json();
        if let Json::Obj(fields) = &mut json {
            fields.retain(|(k, _)| k != "fresh" && k != "active_batches");
        }
        match Response::from_json(&json).unwrap() {
            Response::Results { results, fresh, active_batches } => {
                assert_eq!(results.len(), 2);
                assert_eq!(fresh, vec![true, true]);
                assert_eq!(active_batches, None, "older peers piggyback no queue depth");
            }
            other => panic!("expected results, got {other:?}"),
        }
        assert!(Origin::Fresh.is_fresh());
        for o in [
            Origin::Cached,
            Origin::Dedup,
            Origin::Coalesced,
            Origin::ShardCached,
            Origin::StoreServed,
        ] {
            assert!(!o.is_fresh());
        }
    }

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let mut buf: Vec<u8> = Vec::new();
        write_frame(&mut buf, &Request::Ping.to_json()).unwrap();
        write_frame(&mut buf, &Request::Stats.to_json()).unwrap();
        let mut r = std::io::BufReader::new(&buf[..]);
        assert_eq!(
            Request::from_json(&read_frame(&mut r).unwrap().unwrap()),
            Some(Request::Ping)
        );
        assert_eq!(
            Request::from_json(&read_frame(&mut r).unwrap().unwrap()),
            Some(Request::Stats)
        );
        assert!(read_frame(&mut r).unwrap().is_none());
    }
}
