//! The batched, cached, backend-abstracted measurement engine.

use super::backend::{BackendKind, MeasureBackend};
use super::cache::{CacheStats, MeasureCache, PointKey};
use super::journal::Journal;
use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::util::pool::parallel_map;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Engine construction settings (see [`crate::config::EvalSettings`] for
/// the file/CLI-facing mirror).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub backend: BackendKind,
    /// Worker threads for the measurement fan-out.
    pub workers: usize,
    /// Serve repeated points from a shared in-memory cache.
    pub cache: bool,
    /// Optional persistent journal; existing entries for the selected
    /// backend pre-seed the cache, new measurements are appended.
    pub journal: Option<PathBuf>,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            backend: BackendKind::VtaSim,
            workers: crate::util::pool::default_workers(),
            cache: true,
            journal: None,
        }
    }
}

/// Aggregate engine counters.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    /// Batches served.
    pub batches: usize,
    /// Backend invocations actually paid for (unique, uncached points).
    pub simulations: usize,
    /// Points answered by intra-batch deduplication.
    pub batch_dedup: usize,
    /// Cache lookups answered from memory.
    pub cache_hits: usize,
    /// Cache lookups that missed.
    pub cache_misses: usize,
    /// Entries currently cached.
    pub cache_entries: usize,
    /// Cache entries pre-seeded from the journal at construction.
    pub journal_seeded: usize,
}

/// The shared measurement service: every tuning-path `f[τ(Θ)]` evaluation
/// goes through [`Engine::measure_batch`].
///
/// The engine is `Sync`; one instance can serve many concurrent tuning
/// jobs (see `examples/compile_service.rs`) and results are deterministic
/// for a deterministic backend regardless of `workers`.
///
/// At-most-once guarantee: sequential batches never re-simulate a cached
/// point, and repeats *within* a batch are always coalesced. Two batches
/// racing on different threads can still each pay for the same brand-new
/// point (there is no in-flight miss coalescing yet — ROADMAP open item);
/// results remain correct, only the saving degrades.
pub struct Engine {
    backend: Box<dyn MeasureBackend>,
    workers: usize,
    cache: Option<MeasureCache>,
    journal: Option<Mutex<Journal>>,
    journal_seeded: usize,
    batches: AtomicUsize,
    simulations: AtomicUsize,
    batch_dedup: AtomicUsize,
}

impl Engine {
    pub fn new(config: EngineConfig) -> Engine {
        Engine::from_parts(config.backend.build(), config.workers, config.cache, config.journal)
    }

    /// Engine over a caller-provided backend (tests, custom oracles).
    pub fn with_backend(backend: Box<dyn MeasureBackend>, workers: usize, cache: bool) -> Engine {
        Engine::from_parts(backend, workers, cache, None)
    }

    /// The common case: cycle-accurate simulator backend, cache on, no
    /// journal.
    pub fn vta_sim(workers: usize) -> Engine {
        Engine::new(EngineConfig { workers, ..Default::default() })
    }

    fn from_parts(
        backend: Box<dyn MeasureBackend>,
        workers: usize,
        cache: bool,
        journal: Option<PathBuf>,
    ) -> Engine {
        let cache = cache.then(MeasureCache::new);
        if cache.is_none() && journal.is_some() {
            crate::log_warn!(
                "eval",
                "journal configured with the cache disabled: measurements are recorded \
                 (once per unique point) but nothing is reused; drop --no-cache to get \
                 journal reuse"
            );
        }
        let mut journal_seeded = 0usize;
        let journal = journal.map(|path| {
            let j = Journal::open(&path);
            if let Some(c) = &cache {
                for e in j.entries() {
                    if e.backend == backend.name() {
                        c.preload(e.key.clone(), e.result);
                        journal_seeded += 1;
                    }
                }
            }
            if journal_seeded > 0 {
                crate::log_info!(
                    "eval",
                    "journal {}: seeded {journal_seeded} cached measurements",
                    path.display()
                );
            }
            Mutex::new(j)
        });
        Engine {
            backend,
            workers: workers.max(1),
            cache,
            journal,
            journal_seeded,
            batches: AtomicUsize::new(0),
            simulations: AtomicUsize::new(0),
            batch_dedup: AtomicUsize::new(0),
        }
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Measure a batch of points, returning results in input order.
    ///
    /// Repeats within the batch are measured once; points seen in earlier
    /// batches (or seeded from the journal) come from the cache; the
    /// remaining unique misses fan out over the worker pool.
    pub fn measure_batch(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
    ) -> Vec<MeasureResult> {
        let n = points.len();
        if n == 0 {
            return Vec::new();
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        let keys: Vec<PointKey> = points.iter().map(|p| PointKey::of(space, p)).collect();
        let mut out: Vec<Option<MeasureResult>> = vec![None; n];

        // 1. Serve whatever the cache already knows.
        if let Some(cache) = &self.cache {
            for i in 0..n {
                out[i] = cache.get(&keys[i]);
            }
        }

        // 2. Deduplicate the misses within this batch.
        let mut first_slot: HashMap<&PointKey, usize> = HashMap::new();
        let mut uniq: Vec<usize> = Vec::new(); // input index of each unique miss
        let mut alias: Vec<(usize, usize)> = Vec::new(); // (input index, uniq slot)
        for i in 0..n {
            if out[i].is_some() {
                continue;
            }
            match first_slot.entry(&keys[i]) {
                Entry::Occupied(e) => alias.push((i, *e.get())),
                Entry::Vacant(v) => {
                    v.insert(uniq.len());
                    uniq.push(i);
                }
            }
        }
        drop(first_slot);

        // 3. Fan the unique misses out over the worker pool.
        let miss_points: Vec<PointConfig> = uniq.iter().map(|&i| points[i].clone()).collect();
        let results: Vec<MeasureResult> =
            parallel_map(&miss_points, self.workers, |_, p| self.backend.measure(space, p));
        self.simulations.fetch_add(results.len(), Ordering::Relaxed);
        self.batch_dedup.fetch_add(alias.len(), Ordering::Relaxed);

        // 4. Record and assemble in input order.
        for (slot, &i) in uniq.iter().enumerate() {
            let r = results[slot];
            if let Some(cache) = &self.cache {
                cache.insert(keys[i].clone(), r);
            }
            if let Some(journal) = &self.journal {
                journal.lock().unwrap().record(self.backend.name(), &keys[i], &r);
            }
            out[i] = Some(r);
        }
        for (i, slot) in alias {
            out[i] = Some(results[slot]);
        }
        if !uniq.is_empty() {
            self.flush_journal();
        }
        out.into_iter().map(|r| r.expect("every point measured")).collect()
    }

    /// Measure a single point (one-off probes; batches are cheaper).
    pub fn measure_one(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        self.measure_batch(space, std::slice::from_ref(point))[0]
    }

    /// Measure a planned batch and pair results back with their points —
    /// the exact shape [`crate::tuner::Strategy::observe`] consumes.
    pub fn measure_paired(
        &self,
        space: &ConfigSpace,
        points: Vec<PointConfig>,
    ) -> Vec<(PointConfig, MeasureResult)> {
        let results = self.measure_batch(space, &points);
        points.into_iter().zip(results).collect()
    }

    /// Persist any journal entries recorded since the last flush. Failures
    /// are logged, not fatal: a read-only results dir should not kill a
    /// tuning run.
    pub fn flush_journal(&self) {
        if let Some(journal) = &self.journal {
            if let Err(e) = journal.lock().unwrap().flush() {
                crate::log_warn!("eval", "journal flush failed: {e}");
            }
        }
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    pub fn stats(&self) -> EngineStats {
        let cs = self.cache_stats();
        EngineStats {
            batches: self.batches.load(Ordering::Relaxed),
            simulations: self.simulations.load(Ordering::Relaxed),
            batch_dedup: self.batch_dedup.load(Ordering::Relaxed),
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_entries: cs.entries,
            journal_seeded: self.journal_seeded,
        }
    }

    /// One-line diagnostic summary for logs and CLI output.
    pub fn summary(&self) -> String {
        let s = self.stats();
        format!(
            "backend={} workers={} batches={} simulations={} cache_hits={} batch_dedup={} journal_seeded={}",
            self.backend_name(),
            self.workers,
            s.batches,
            s.simulations,
            s.cache_hits,
            s.batch_dedup,
            s.journal_seeded
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    #[test]
    fn batch_dedup_measures_each_point_once() {
        let s = space();
        let e = Engine::vta_sim(2);
        let p = s.default_point();
        let batch = vec![p.clone(), p.clone(), p.clone()];
        let rs = e.measure_batch(&s, &batch);
        assert_eq!(rs[0], rs[1]);
        assert_eq!(rs[1], rs[2]);
        let st = e.stats();
        assert_eq!(st.simulations, 1);
        assert_eq!(st.batch_dedup, 2);
    }

    #[test]
    fn cache_serves_repeats_across_batches() {
        let s = space();
        let e = Engine::vta_sim(1);
        let p = s.default_point();
        let first = e.measure_one(&s, &p);
        let second = e.measure_one(&s, &p);
        assert_eq!(first, second);
        let st = e.stats();
        assert_eq!(st.simulations, 1);
        assert_eq!(st.cache_hits, 1);
    }

    #[test]
    fn results_in_input_order_and_worker_independent() {
        let s = space();
        let mut rng = Pcg32::seeded(9);
        let mut points = Vec::new();
        for _ in 0..15 {
            points.push(s.random_point(&mut rng));
        }
        // Sprinkle duplicates.
        points.push(points[0].clone());
        points.push(points[7].clone());
        let serial = Engine::with_backend(Box::new(super::super::VtaSimBackend), 1, false);
        let parallel = Engine::with_backend(Box::new(super::super::VtaSimBackend), 4, false);
        let a = serial.measure_batch(&s, &points);
        let b = parallel.measure_batch(&s, &points);
        assert_eq!(a, b);
        for (p, r) in points.iter().zip(&a) {
            assert_eq!(*r, crate::codegen::measure_point(&s, p));
        }
    }

    #[test]
    fn disabled_cache_still_dedups_within_batch() {
        let s = space();
        let e = Engine::with_backend(Box::new(super::super::VtaSimBackend), 2, false);
        let p = s.default_point();
        e.measure_batch(&s, &[p.clone(), p.clone()]);
        e.measure_batch(&s, &[p.clone()]);
        let st = e.stats();
        // Within a batch the duplicate is free; across batches it is not.
        assert_eq!(st.batch_dedup, 1);
        assert_eq!(st.simulations, 2);
        assert_eq!(st.cache_hits, 0);
    }

    #[test]
    fn empty_batch_is_free() {
        let s = space();
        let e = Engine::vta_sim(2);
        assert!(e.measure_batch(&s, &[]).is_empty());
        assert_eq!(e.stats().batches, 0);
    }
}
