//! The batched, cached, coalescing, backend-abstracted measurement engine.

use super::backend::{analytical_terms, BackendKind, BackendSpec, MeasureBackend, Placement,
    ShardPlacement};
use super::cache::{CacheStats, MeasureCache, PointKey};
use super::calib::Calibration;
use super::journal::Journal;
use super::proto::{Fingerprint, Origin};
use super::store::{MeasureStore, StoreConfig};
use super::sync;
use crate::codegen::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::util::json::Json;
use std::collections::hash_map::Entry;
use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Engine construction settings (see [`crate::config::EvalSettings`] for
/// the file/CLI-facing mirror).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    pub backend: BackendSpec,
    /// Worker threads for the measurement fan-out.
    pub workers: usize,
    /// Serve repeated points from a shared in-memory cache.
    pub cache: bool,
    /// Bound the cache to at most this many entries (LRU eviction).
    /// `None` keeps everything — right for one run, wrong for a fleet
    /// shard that lives for weeks.
    pub cache_capacity: Option<usize>,
    /// Optional persistent journal; existing entries for the selected
    /// backend pre-seed the cache, new measurements are appended.
    pub journal: Option<PathBuf>,
    /// Optional warm-start journal, opened read-only: its entries for the
    /// selected backend pre-seed the cache like `journal`'s do, but the
    /// file is never written. The fleet workflow: `arco journal merge`
    /// unions every shard's journal, and a new/revived shard points
    /// `serve-measure --warm-start` at the union to inherit the fleet's
    /// history before its first batch.
    pub warm_start: Option<PathBuf>,
    /// Optional shared measurement store (`serve-measure --store`): a
    /// directory of journal segments shared by every process pointed at
    /// it. Cache misses consult the store before the backend; fresh
    /// measurements are appended for every other tenant, forever.
    pub store: Option<StoreConfig>,
    /// How a remote fleet backend splits batches across shards (ignored by
    /// built-in local backends).
    pub placement: Placement,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            backend: BackendSpec::Builtin(BackendKind::VtaSim),
            workers: crate::util::pool::default_workers(),
            cache: true,
            cache_capacity: None,
            journal: None,
            warm_start: None,
            store: None,
            placement: Placement::default(),
        }
    }
}

/// Aggregate engine counters.
#[derive(Debug, Clone, Default)]
pub struct EngineStats {
    /// Batches served.
    pub batches: usize,
    /// Simulations actually paid for: unique uncached points the backend
    /// freshly ran (a remote shard answering from its own cache counts
    /// under [`shard_cached`](Self::shard_cached) instead).
    pub simulations: usize,
    /// Points answered by intra-batch deduplication.
    pub batch_dedup: usize,
    /// Points answered by waiting on another batch's in-flight
    /// measurement instead of re-measuring.
    pub coalesced: usize,
    /// Points a remote fleet answered from shard-side shared state
    /// (another tenant or an earlier run paid for the simulation).
    pub shard_cached: usize,
    /// Points answered from the shared measurement store (`--store`):
    /// some other process, possibly long dead, already paid for them.
    pub store_served: usize,
    /// Batches currently being measured (a queue-depth gauge: the
    /// `serve-measure` `stats` op exposes it so fleet clients can see how
    /// loaded each shard is).
    pub active_batches: usize,
    /// Cache lookups answered from memory.
    pub cache_hits: usize,
    /// Cache lookups that missed.
    pub cache_misses: usize,
    /// Entries currently cached.
    pub cache_entries: usize,
    /// Entries evicted to stay within the cache capacity bound.
    pub cache_evictions: usize,
    /// Cache entries pre-seeded from the journal at construction.
    pub journal_seeded: usize,
    /// Cache entries pre-seeded from the warm-start journal at
    /// construction (inherited fleet history).
    pub warm_seeded: usize,
    /// Candidates the multi-fidelity screening stage answered with the
    /// calibrated analytical model instead of this engine's backend
    /// (`--fidelity screen:...`; 0 in exact mode).
    pub screened: usize,
    /// Per-shard placement counters when the backend is a remote fleet
    /// (empty for local backends): points/batches served per shard, the
    /// service-time EWMA and queue depth behind weighted placement, and
    /// each shard's warm-start coverage.
    pub placement: Vec<ShardPlacement>,
}

impl EngineStats {
    /// JSON rendering (the `serve-measure` `stats` op).
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("batches", Json::num(self.batches as f64)),
            ("simulations", Json::num(self.simulations as f64)),
            ("batch_dedup", Json::num(self.batch_dedup as f64)),
            ("coalesced", Json::num(self.coalesced as f64)),
            ("shard_cached", Json::num(self.shard_cached as f64)),
            ("store_served", Json::num(self.store_served as f64)),
            ("active_batches", Json::num(self.active_batches as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("cache_misses", Json::num(self.cache_misses as f64)),
            ("cache_entries", Json::num(self.cache_entries as f64)),
            ("cache_evictions", Json::num(self.cache_evictions as f64)),
            ("journal_seeded", Json::num(self.journal_seeded as f64)),
            ("warm_seeded", Json::num(self.warm_seeded as f64)),
        ];
        if self.screened > 0 {
            fields.push(("screened", Json::num(self.screened as f64)));
        }
        if !self.placement.is_empty() {
            fields.push((
                "placement",
                Json::Arr(self.placement.iter().map(ShardPlacement::to_json).collect()),
            ));
        }
        Json::obj(fields)
    }
}

/// State of one in-flight measurement cell.
#[derive(Debug, Clone, Copy)]
enum CellState {
    /// The owner is still measuring.
    Pending,
    /// The owner published its result.
    Done(MeasureResult),
    /// The owner unwound (backend panic, fleet lost) before publishing;
    /// followers must measure for themselves.
    Abandoned,
}

/// Rendezvous for one in-flight measurement: the owning batch fills it,
/// coalesced batches wait on it.
struct InflightCell {
    slot: Mutex<CellState>,
    ready: Condvar,
}

impl InflightCell {
    fn new() -> InflightCell {
        InflightCell { slot: Mutex::new(CellState::Pending), ready: Condvar::new() }
    }

    fn fill(&self, r: MeasureResult) {
        *sync::lock_unpoisoned(&self.slot) = CellState::Done(r);
        self.ready.notify_all();
    }

    fn abandon(&self) {
        *sync::lock_unpoisoned(&self.slot) = CellState::Abandoned;
        self.ready.notify_all();
    }

    /// Block until the owner publishes; `None` when it abandoned instead.
    fn wait(&self) -> Option<MeasureResult> {
        let mut guard = sync::lock_unpoisoned(&self.slot);
        loop {
            match *guard {
                CellState::Done(r) => return Some(r),
                CellState::Abandoned => return None,
                CellState::Pending => guard = sync::wait_unpoisoned(&self.ready, guard),
            }
        }
    }
}

/// Decrements a gauge on drop, so the `active_batches` count survives a
/// panicking batch (the engine explicitly anticipates backend panics and
/// recovers via [`ClaimGuard`]; a long-lived shard must not report a
/// phantom busy batch forever after).
struct GaugeGuard<'a>(&'a AtomicUsize);

impl Drop for GaugeGuard<'_> {
    fn drop(&mut self) {
        self.0.fetch_sub(1, Ordering::Relaxed);
    }
}

/// Unwind guard for claimed in-flight keys: if the owning batch panics
/// between claiming and publishing (a backend panic, a lost remote fleet),
/// the claims are withdrawn and waiting followers are woken with
/// [`CellState::Abandoned`] instead of hanging forever.
struct ClaimGuard<'a> {
    inflight: &'a Mutex<HashMap<PointKey, Arc<InflightCell>>>,
    keys: Vec<PointKey>,
    armed: bool,
}

impl ClaimGuard<'_> {
    fn disarm(mut self) {
        self.armed = false;
    }
}

impl Drop for ClaimGuard<'_> {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        // Runs during unwinds: recover a poisoned registry rather than
        // leave followers hanging on claims nobody will ever fill.
        let mut map = sync::lock_unpoisoned(self.inflight);
        for k in &self.keys {
            if let Some(cell) = map.remove(k) {
                cell.abandon();
            }
        }
    }
}

/// The shared measurement service: every tuning-path `f[τ(Θ)]` evaluation
/// goes through [`Engine::measure_batch`].
///
/// The engine is `Sync`; one instance can serve many concurrent tuning
/// jobs (see `examples/compile_service.rs` and `arco serve-measure`) and
/// results are deterministic for a deterministic backend regardless of
/// `workers`.
///
/// At-most-once guarantee: repeats *within* a batch are always coalesced;
/// with the cache enabled, sequential batches never re-simulate a cached
/// point and concurrent batches racing on the same brand-new point claim
/// it atomically — exactly one measures, the others wait on the in-flight
/// cell. With the cache disabled only intra-batch and concurrent-in-flight
/// repeats are coalesced; sequential batches re-measure.
pub struct Engine {
    backend: Box<dyn MeasureBackend>,
    workers: usize,
    cache: Option<MeasureCache>,
    inflight: Mutex<HashMap<PointKey, Arc<InflightCell>>>,
    journal: Option<Mutex<Journal>>,
    store: Option<Mutex<MeasureStore>>,
    journal_seeded: usize,
    warm_seeded: usize,
    batches: AtomicUsize,
    simulations: AtomicUsize,
    batch_dedup: AtomicUsize,
    coalesced: AtomicUsize,
    shard_cached: AtomicUsize,
    store_served: AtomicUsize,
    active: AtomicUsize,
    /// Screened-out candidates tallied by [`Engine::note_screened`].
    screened: AtomicUsize,
    /// Online calibration of the analytical proxy, fed by every fresh
    /// backend measurement while attached (`--fidelity screen:...`).
    calibration: Mutex<Option<Arc<Calibration>>>,
}

/// Results of one batch plus per-point [`Origin`] provenance.
#[derive(Debug, Clone)]
pub struct TracedBatch {
    /// Measurement results in input order.
    pub results: Vec<MeasureResult>,
    /// Where each result came from, parallel to `results`.
    pub origins: Vec<Origin>,
}

/// A measured plan: the `(point, result)` pairs that
/// [`crate::tuner::Strategy::observe`] consumes, plus per-point provenance
/// for budget accounting.
#[derive(Debug, Clone)]
pub struct PairedBatch {
    /// `(planned point, its result)` in plan order.
    pub pairs: Vec<(PointConfig, MeasureResult)>,
    /// Where each result came from, parallel to `pairs`.
    pub origins: Vec<Origin>,
}

impl PairedBatch {
    /// Points whose simulation actually ran for this batch.
    pub fn fresh(&self) -> usize {
        self.origins.iter().filter(|o| o.is_fresh()).count()
    }

    /// Points served from shared state (cache, in-batch dedup, coalescing,
    /// fleet shard caches) — debited like fresh ones under the
    /// equal-budget protocol, but free of simulator wall-clock.
    pub fn cache_served(&self) -> usize {
        self.origins.len() - self.fresh()
    }
}

/// A batch submitted with [`Engine::submit_batch`] that is (or was) being
/// measured in the background: a join handle over the eventual
/// [`PairedBatch`]. `Err` on [`wait`](Self::wait) is the same whole-fleet
/// outage [`Engine::try_measure_paired`] reports
/// ([`super::remote::FleetLostError`]); a panicking backend resumes its
/// panic on the waiter, exactly as the synchronous path would.
pub struct PendingBatch<'scope> {
    handle: std::thread::ScopedJoinHandle<'scope, anyhow::Result<PairedBatch>>,
    len: usize,
}

impl PendingBatch<'_> {
    /// Points in the submitted batch.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Block until the batch is measured and take its results.
    pub fn wait(self) -> anyhow::Result<PairedBatch> {
        match self.handle.join() {
            Ok(out) => out,
            // A backend panic on the measurement thread is re-raised on
            // the waiting thread, matching the synchronous call's shape.
            Err(panic) => std::panic::resume_unwind(panic),
        }
    }
}

impl Engine {
    /// Build an engine from a full configuration. Fails fast when the
    /// journal or warm-start file cannot be opened safely (another writer
    /// holds the journal's lock, either was measured under a different
    /// simulator fingerprint, the warm-start file is missing) or when a
    /// remote fleet refuses the handshake.
    pub fn new(config: EngineConfig) -> anyhow::Result<Engine> {
        let backend = config.backend.build_with(config.placement)?;
        let journal = match &config.journal {
            Some(path) => Some(Journal::open(path)?),
            None => None,
        };
        let warm = match &config.warm_start {
            Some(path) => {
                if !path.exists() {
                    anyhow::bail!(
                        "warm-start journal {} does not exist (it should be the output of \
                         `arco journal merge`)",
                        path.display()
                    );
                }
                Some(Journal::open_read_only(path)?)
            }
            None => None,
        };
        let store = match &config.store {
            Some(cfg) => Some(MeasureStore::open(cfg)?),
            None => None,
        };
        Ok(Engine::from_parts(
            backend,
            config.workers,
            config.cache,
            config.cache_capacity,
            journal,
            warm,
            store,
        ))
    }

    /// Engine over a caller-provided backend (tests, custom oracles).
    pub fn with_backend(backend: Box<dyn MeasureBackend>, workers: usize, cache: bool) -> Engine {
        Engine::from_parts(backend, workers, cache, None, None, None, None)
    }

    /// The common case: cycle-accurate simulator backend, cache on, no
    /// journal.
    pub fn vta_sim(workers: usize) -> Engine {
        Engine::from_parts(BackendKind::VtaSim.build(), workers, true, None, None, None, None)
    }

    fn from_parts(
        backend: Box<dyn MeasureBackend>,
        workers: usize,
        cache: bool,
        cache_capacity: Option<usize>,
        journal: Option<Journal>,
        warm: Option<Journal>,
        mut store: Option<MeasureStore>,
    ) -> Engine {
        let cache = cache.then(|| MeasureCache::with_capacity(cache_capacity));
        if cache.is_none() && journal.is_some() {
            crate::log_warn!(
                "eval",
                "journal configured with the cache disabled: measurements are recorded \
                 (once per unique point) but nothing is reused; drop --no-cache to get \
                 journal reuse"
            );
        }
        if cache.is_none() && warm.is_some() {
            crate::log_warn!(
                "eval",
                "warm start configured with the cache disabled: the inherited history has \
                 nowhere to live and is ignored; drop --no-cache to get warm starts"
            );
        }
        let mut journal_seeded = 0usize;
        // Only needed to dedup warm-start coverage against the journal;
        // skip the per-entry clone+hash on the common no-warm-start path.
        let mut seeded_keys: std::collections::HashSet<PointKey> = std::collections::HashSet::new();
        if let (Some(c), Some(j)) = (&cache, &journal) {
            for e in j.entries() {
                if e.backend == backend.name() {
                    c.preload(e.key.clone(), e.result);
                    if warm.is_some() {
                        seeded_keys.insert(e.key.clone());
                    }
                    journal_seeded += 1;
                }
            }
            if journal_seeded > 0 {
                crate::log_info!(
                    "eval",
                    "journal {}: seeded {journal_seeded} cached measurements",
                    j.path().display()
                );
            }
        }
        // Warm start: same seeding as the journal, read-only source.
        // Entries the journal already seeded are not re-counted, so
        // `preloaded_entries` reports *distinct* inherited coverage even
        // when the merged fleet history contains this shard's own records
        // (the documented restart workflow). Overlap itself is harmless —
        // a shared fingerprint guarantees identical identities carry
        // identical results.
        let mut warm_seeded = 0usize;
        if let (Some(c), Some(w)) = (&cache, &warm) {
            for e in w.entries() {
                if e.backend == backend.name() && seeded_keys.insert(e.key.clone()) {
                    c.preload(e.key.clone(), e.result);
                    warm_seeded += 1;
                }
            }
            crate::log_info!(
                "eval",
                "warm start {}: inherited {warm_seeded} cached measurements",
                w.path().display()
            );
        }
        // The store inherits this process's local history: a shard started
        // with `--warm-start union.jsonl --store dir` imports the fleet's
        // merged journal into the shared tier (rotating and pruning as it
        // goes), so every other tenant sees it without its own warm start.
        if let Some(s) = store.as_mut() {
            let mut imported = 0usize;
            for j in journal.iter().chain(warm.iter()) {
                for e in j.entries() {
                    if e.backend == backend.name() && s.record(&e.backend, &e.key, &e.result) {
                        imported += 1;
                    }
                }
            }
            if imported > 0 {
                if let Err(e) = s.flush() {
                    crate::log_warn!("eval", "store flush failed: {e}");
                }
                crate::log_info!(
                    "eval",
                    "store {}: imported {imported} measurements from local history",
                    s.dir().display()
                );
            }
        }
        Engine {
            backend,
            workers: workers.max(1),
            cache,
            inflight: Mutex::new(HashMap::new()),
            journal: journal.map(Mutex::new),
            store: store.map(Mutex::new),
            journal_seeded,
            warm_seeded,
            batches: AtomicUsize::new(0),
            simulations: AtomicUsize::new(0),
            batch_dedup: AtomicUsize::new(0),
            coalesced: AtomicUsize::new(0),
            shard_cached: AtomicUsize::new(0),
            store_served: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            screened: AtomicUsize::new(0),
            calibration: Mutex::new(None),
        }
    }

    /// Cache entries seeded from persistent history at construction
    /// (journal + warm start) — what the `serve-measure` handshake reports
    /// to fleet clients as inherited coverage.
    pub fn preloaded_entries(&self) -> usize {
        self.journal_seeded + self.warm_seeded
    }

    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Measure a batch of points, returning results in input order.
    ///
    /// Repeats within the batch are measured once; points seen in earlier
    /// batches (or seeded from the journal) come from the cache; points
    /// currently being measured by a concurrent batch are waited on rather
    /// than re-measured; the remaining unique misses go to the backend
    /// (local worker fan-out, or a remote fleet).
    pub fn measure_batch(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
    ) -> Vec<MeasureResult> {
        self.measure_batch_traced(space, points).results
    }

    /// [`measure_batch`](Self::measure_batch), plus per-point [`Origin`]
    /// provenance — the hit/miss evidence budget ledgers need to tell
    /// freshly-simulated points from cache-served ones. Panics when the
    /// backend loses its measurement substrate (a whole remote fleet
    /// down); the tuning loop uses
    /// [`try_measure_batch_traced`](Self::try_measure_batch_traced) and
    /// fails cleanly instead.
    pub fn measure_batch_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
    ) -> TracedBatch {
        match self.try_measure_batch_traced(space, points) {
            Ok(batch) => batch,
            Err(e) => sync::raise(e),
        }
    }

    /// The fallible batch path: identical semantics to
    /// [`measure_batch_traced`](Self::measure_batch_traced), but a backend
    /// that loses its measurement substrate mid-batch (a remote fleet with
    /// no reachable shard: [`super::remote::FleetLostError`]) surfaces as
    /// `Err` instead of a panic, so a whole-fleet outage can fail a tuning
    /// run cleanly. In-flight claims held by this batch are withdrawn on
    /// the error path and waiting followers are woken to measure for
    /// themselves.
    pub fn try_measure_batch_traced(
        &self,
        space: &ConfigSpace,
        points: &[PointConfig],
    ) -> anyhow::Result<TracedBatch> {
        let n = points.len();
        if n == 0 {
            return Ok(TracedBatch { results: Vec::new(), origins: Vec::new() });
        }
        self.batches.fetch_add(1, Ordering::Relaxed);
        self.active.fetch_add(1, Ordering::Relaxed);
        let _active = GaugeGuard(&self.active);
        let keys: Vec<PointKey> = points.iter().map(|p| PointKey::of(space, p)).collect();
        let mut out: Vec<Option<MeasureResult>> = vec![None; n];
        let mut origins: Vec<Origin> = vec![Origin::Fresh; n];

        // 1. Serve whatever the cache already knows.
        if let Some(cache) = &self.cache {
            for ((slot, origin), key) in out.iter_mut().zip(origins.iter_mut()).zip(&keys) {
                *slot = cache.get(key);
                if slot.is_some() {
                    *origin = Origin::Cached;
                }
            }
        }

        // 2. Classify the misses under the in-flight registry lock:
        //    first occurrence of a brand-new key claims ownership (we will
        //    measure it), repeats alias the owner's slot, and keys some
        //    concurrent batch is already measuring become followers.
        //    A key absent from the registry may still have been published
        //    between our step-1 lookup and taking this lock (owners insert
        //    into the cache *before* clearing their in-flight entry), so a
        //    cache re-check under the lock closes the double-measure race.
        let mut first_slot: HashMap<&PointKey, usize> = HashMap::new();
        let mut uniq: Vec<usize> = Vec::new(); // input index of each owned miss
        let mut alias: Vec<(usize, usize)> = Vec::new(); // (input index, uniq slot)
        let mut follows: Vec<(usize, Arc<InflightCell>)> = Vec::new();
        {
            let mut inflight = sync::lock_unpoisoned(&self.inflight);
            for i in 0..n {
                if out[i].is_some() {
                    continue;
                }
                match first_slot.entry(&keys[i]) {
                    Entry::Occupied(e) => alias.push((i, *e.get())),
                    Entry::Vacant(v) => {
                        if let Some(cell) = inflight.get(&keys[i]) {
                            follows.push((i, Arc::clone(cell)));
                            continue;
                        }
                        if let Some(cache) = &self.cache {
                            // Hit-only: the miss was already counted above.
                            if let Some(r) = cache.get_hit_only(&keys[i]) {
                                out[i] = Some(r);
                                origins[i] = Origin::Cached;
                                continue;
                            }
                        }
                        v.insert(uniq.len());
                        inflight.insert(keys[i].clone(), Arc::new(InflightCell::new()));
                        uniq.push(i);
                    }
                }
            }
        }

        // 2b. Consult the shared store for the owned misses: a point any
        //     tenant ever measured under this fingerprint is answered from
        //     disk instead of the backend. Claims stay in place so a store
        //     hit still resolves followers through the normal publish path.
        let store_hits: Vec<Option<MeasureResult>> = match &self.store {
            Some(store) if !uniq.is_empty() => {
                let miss_keys: Vec<PointKey> = uniq.iter().map(|&i| keys[i].clone()).collect();
                sync::lock_unpoisoned(store).lookup_many(self.backend.name(), &miss_keys)
            }
            _ => vec![None; uniq.len()],
        };

        // 3. Measure the remaining misses (backend decides local vs remote
        //    parallelism). The guard withdraws our claims and wakes any
        //    followers if the backend unwinds before we publish.
        let guard = ClaimGuard {
            inflight: &self.inflight,
            keys: uniq.iter().map(|&i| keys[i].clone()).collect(),
            armed: true,
        };
        let miss_points: Vec<PointConfig> = uniq
            .iter()
            .enumerate()
            .filter(|&(slot, _)| store_hits[slot].is_none())
            .map(|(_, &i)| points[i].clone())
            .collect();
        // On a lost backend the armed guard withdraws this batch's claims
        // and wakes followers with `Abandoned` on the way out; the journal
        // is flushed first so measurements other batches already paid for
        // are not stranded in memory when the run exits on this error
        // (Journal's Drop releases the lock but never flushes).
        let (backend_results, backend_fresh): (Vec<MeasureResult>, Vec<bool>) =
            if miss_points.is_empty() {
                (Vec::new(), Vec::new())
            } else {
                match self.backend.try_measure_many_traced(space, &miss_points, self.workers) {
                    Ok(out) => out,
                    Err(e) => {
                        self.flush_journal();
                        return Err(e);
                    }
                }
            };
        // Stitch store hits and backend answers back into uniq-slot order:
        // the backend only saw the filtered misses, so its results are
        // consumed with a cursor wherever the store had no answer.
        let mut slot_results: Vec<MeasureResult> = Vec::with_capacity(uniq.len());
        let mut slot_origin: Vec<Origin> = Vec::with_capacity(uniq.len());
        let mut bi = 0usize;
        for hit in &store_hits {
            match hit {
                Some(r) => {
                    slot_results.push(*r);
                    slot_origin.push(Origin::StoreServed);
                }
                None => {
                    slot_results.push(backend_results[bi]);
                    slot_origin.push(if backend_fresh[bi] {
                        Origin::Fresh
                    } else {
                        Origin::ShardCached
                    });
                    bi += 1;
                }
            }
        }
        // Only freshly-run points count as simulations; a warm fleet shard
        // answering from its own cache did not re-simulate (those are
        // tallied under `shard_cached` instead of being double-counted),
        // and store-served points never left this process.
        self.simulations
            .fetch_add(backend_fresh.iter().filter(|&&f| f).count(), Ordering::Relaxed);
        self.shard_cached
            .fetch_add(backend_fresh.iter().filter(|&&f| !f).count(), Ordering::Relaxed);
        self.store_served
            .fetch_add(store_hits.iter().filter(|h| h.is_some()).count(), Ordering::Relaxed);
        self.batch_dedup.fetch_add(alias.len(), Ordering::Relaxed);

        // 4. Publish: cache and journal first (so late arrivals hit the
        //    cache), then resolve the in-flight cells for any followers.
        for (slot, &i) in uniq.iter().enumerate() {
            let r = slot_results[slot];
            match slot_origin[slot] {
                // A store-served point is already durable fleet-wide; only
                // the in-memory cache needs it (re-journaling would bloat
                // every tenant's local history with copies of the shared
                // tier).
                Origin::StoreServed => {
                    if let Some(cache) = &self.cache {
                        cache.insert(keys[i].clone(), r);
                    }
                }
                _ => self.publish_one(&keys[i], r),
            }
            out[i] = Some(r);
            origins[i] = slot_origin[slot];
        }
        // Feed the online calibration every point the oracle genuinely ran
        // this batch (fresh only: cached/store/shard answers were either
        // observed when first measured or predate this calibration).
        if let Some(calib) = self.calibration() {
            let task_id = space.task.short_id();
            for (slot, &i) in uniq.iter().enumerate() {
                if matches!(slot_origin[slot], Origin::Fresh) {
                    let terms = analytical_terms(space, &points[i]);
                    calib.observe(&task_id, &terms, slot_results[slot].cycles);
                }
            }
        }
        {
            let mut inflight = sync::lock_unpoisoned(&self.inflight);
            for (slot, &i) in uniq.iter().enumerate() {
                if let Some(cell) = inflight.remove(&keys[i]) {
                    cell.fill(slot_results[slot]);
                }
            }
        }
        guard.disarm();

        // 5. Collect coalesced results from the batches that own them.
        //    Fills happen before any batch starts waiting, so two batches
        //    following each other's points cannot deadlock. An abandoned
        //    cell (its owner panicked before publishing) is measured here
        //    instead of hanging.
        self.coalesced.fetch_add(follows.len(), Ordering::Relaxed);
        let mut recovered = false;
        for (i, cell) in follows {
            match cell.wait() {
                Some(r) => {
                    out[i] = Some(r);
                    origins[i] = Origin::Coalesced;
                }
                None => {
                    recovered = true;
                    let attempt = self.backend.try_measure_many_traced(
                        space,
                        std::slice::from_ref(&points[i]),
                        self.workers,
                    );
                    let (rs, fr) = match attempt {
                        Ok(out) => out,
                        Err(e) => {
                            // Points this batch already published must
                            // reach the journal before the run dies.
                            self.flush_journal();
                            return Err(e);
                        }
                    };
                    let r = rs[0];
                    if fr.first().copied().unwrap_or(true) {
                        self.simulations.fetch_add(1, Ordering::Relaxed);
                        origins[i] = Origin::Fresh;
                        if let Some(calib) = self.calibration() {
                            let terms = analytical_terms(space, &points[i]);
                            calib.observe(&space.task.short_id(), &terms, r.cycles);
                        }
                    } else {
                        self.shard_cached.fetch_add(1, Ordering::Relaxed);
                        origins[i] = Origin::ShardCached;
                    }
                    self.publish_one(&keys[i], r);
                    out[i] = Some(r);
                }
            }
        }
        for (i, slot) in alias {
            out[i] = Some(slot_results[slot]);
            origins[i] = Origin::Dedup;
        }
        if !uniq.is_empty() || recovered {
            self.flush_journal();
        }
        let mut results = Vec::with_capacity(n);
        for r in out {
            match r {
                Some(r) => results.push(r),
                None => anyhow::bail!(
                    "measurement engine bug: a point was neither measured, cached, nor coalesced"
                ),
            }
        }
        Ok(TracedBatch { results, origins })
    }

    /// Make one fresh measurement visible to every future lookup: the
    /// shared cache and the journal (both optional). The single publish
    /// seam for the owned-miss and abandoned-cell recovery paths.
    fn publish_one(&self, key: &PointKey, r: MeasureResult) {
        if let Some(cache) = &self.cache {
            cache.insert(key.clone(), r);
        }
        if let Some(journal) = &self.journal {
            sync::lock_unpoisoned(journal).record(self.backend.name(), key, &r);
        }
        if let Some(store) = &self.store {
            sync::lock_unpoisoned(store).record(self.backend.name(), key, &r);
        }
    }

    /// Measure a single point (one-off probes; batches are cheaper).
    pub fn measure_one(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
        self.measure_batch(space, std::slice::from_ref(point))[0]
    }

    /// Measure a planned batch and pair results back with their points.
    /// The returned [`PairedBatch`] carries the `(point, result)` pairs
    /// [`crate::tuner::Strategy::observe`] consumes plus per-point
    /// [`Origin`] provenance, so budget ledgers can distinguish fresh
    /// simulations from cache-served answers.
    pub fn measure_paired(&self, space: &ConfigSpace, points: Vec<PointConfig>) -> PairedBatch {
        let traced = self.measure_batch_traced(space, &points);
        PairedBatch {
            pairs: points.into_iter().zip(traced.results).collect(),
            origins: traced.origins,
        }
    }

    /// Fallible [`measure_paired`](Self::measure_paired) — what the tuning
    /// loop calls, so a whole-fleet outage
    /// ([`super::remote::FleetLostError`]) fails the run cleanly instead
    /// of panicking.
    pub fn try_measure_paired(
        &self,
        space: &ConfigSpace,
        points: Vec<PointConfig>,
    ) -> anyhow::Result<PairedBatch> {
        let traced = self.try_measure_batch_traced(space, &points)?;
        Ok(PairedBatch {
            pairs: points.into_iter().zip(traced.results).collect(),
            origins: traced.origins,
        })
    }

    /// Submit a batch for *asynchronous* measurement: the batch starts
    /// measuring on a scoped worker thread immediately and the caller gets
    /// a join-handle-style [`PendingBatch`] back, so it can keep computing
    /// (planning the next batch) while the hardware evaluates this one —
    /// the pipelined tuning loop's engine seam.
    ///
    /// Semantics are identical to
    /// [`try_measure_paired`](Self::try_measure_paired): the submitted
    /// batch rides the same cache, claim-registry and in-flight coalescing
    /// machinery (two concurrently submitted batches sharing a brand-new
    /// point never double-measure it — one owns, the other waits on the
    /// in-flight cell) and the same `util::pool`/fleet fan-out underneath.
    ///
    /// `ticket` is an arbitrary value dropped the moment the measurement
    /// returns, *before* [`PendingBatch::wait`] can observe the result —
    /// the hook the tuning loop uses to hold a dispatcher admission permit
    /// for exactly the batch's time in flight (per in-flight batch, not
    /// per tenant turn).
    pub fn submit_batch<'scope, 'env, T>(
        &'env self,
        scope: &'scope std::thread::Scope<'scope, 'env>,
        space: &ConfigSpace,
        points: Vec<PointConfig>,
        ticket: T,
    ) -> PendingBatch<'scope>
    where
        T: Send + 'scope,
    {
        let len = points.len();
        let space = space.clone();
        let handle = scope.spawn(move || {
            let out = self.try_measure_paired(&space, points);
            drop(ticket);
            out
        });
        PendingBatch { handle, len }
    }

    /// How many batches the backend can usefully serve at once (local:
    /// one; remote fleet: one per alive shard). The multi-tenant
    /// dispatcher re-reads this between batches, so shard death and
    /// revival shrink or grow admission on the fly.
    pub fn concurrent_batch_capacity(&self) -> usize {
        self.backend.concurrent_batch_capacity().max(1)
    }

    /// Per-shard `stats` snapshots when the backend is a remote fleet
    /// (empty for local backends) — the queue depths behind the
    /// dispatcher's scheduling diagnostics.
    pub fn fleet_stats(&self) -> Vec<(String, Json)> {
        self.backend.fleet_stats()
    }

    /// Persist any journal entries recorded since the last flush. Failures
    /// are logged, not fatal: a read-only results dir should not kill a
    /// tuning run.
    pub fn flush_journal(&self) {
        if let Some(journal) = &self.journal {
            if let Err(e) = sync::lock_unpoisoned(journal).flush() {
                crate::log_warn!("eval", "journal flush failed: {e}");
            }
        }
        if let Some(store) = &self.store {
            if let Err(e) = sync::lock_unpoisoned(store).flush() {
                crate::log_warn!("eval", "store flush failed: {e}");
            }
        }
    }

    /// Attach a shared [`Calibration`] (e.g. one resumed from a journal
    /// sidecar): from now on every fresh backend measurement feeds it.
    pub fn attach_calibration(&self, calib: Arc<Calibration>) {
        *sync::lock_unpoisoned(&self.calibration) = Some(calib);
    }

    /// The attached calibration, if any.
    pub fn calibration(&self) -> Option<Arc<Calibration>> {
        sync::lock_unpoisoned(&self.calibration).clone()
    }

    /// The attached calibration, creating a fresh seed-coefficient one
    /// (bound to the current measurement fingerprint) on first use — the
    /// screening tuning loop's entry point, so every tenant of a shared
    /// engine fits against the same state.
    pub fn ensure_calibration(&self) -> Arc<Calibration> {
        let mut slot = sync::lock_unpoisoned(&self.calibration);
        slot.get_or_insert_with(|| Arc::new(Calibration::new(Fingerprint::current()))).clone()
    }

    /// Tally candidates the screening stage answered analytically instead
    /// of submitting here (`screened` in [`EngineStats`]).
    pub fn note_screened(&self, n: usize) {
        self.screened.fetch_add(n, Ordering::Relaxed);
    }

    pub fn cache_stats(&self) -> CacheStats {
        self.cache.as_ref().map(|c| c.stats()).unwrap_or_default()
    }

    pub fn stats(&self) -> EngineStats {
        let cs = self.cache_stats();
        EngineStats {
            batches: self.batches.load(Ordering::Relaxed),
            simulations: self.simulations.load(Ordering::Relaxed),
            batch_dedup: self.batch_dedup.load(Ordering::Relaxed),
            coalesced: self.coalesced.load(Ordering::Relaxed),
            shard_cached: self.shard_cached.load(Ordering::Relaxed),
            store_served: self.store_served.load(Ordering::Relaxed),
            active_batches: self.active.load(Ordering::Relaxed),
            cache_hits: cs.hits,
            cache_misses: cs.misses,
            cache_entries: cs.entries,
            cache_evictions: cs.evictions,
            journal_seeded: self.journal_seeded,
            warm_seeded: self.warm_seeded,
            screened: self.screened.load(Ordering::Relaxed),
            placement: self.backend.placement_stats(),
        }
    }

    /// One-line diagnostic summary for logs and CLI output.
    pub fn summary(&self) -> String {
        let s = self.stats();
        let mut line = format!(
            "backend={} workers={} batches={} simulations={} shard_cached={} store_served={} \
             cache_hits={} batch_dedup={} coalesced={} evictions={} journal_seeded={} \
             warm_seeded={}",
            self.backend_name(),
            self.workers,
            s.batches,
            s.simulations,
            s.shard_cached,
            s.store_served,
            s.cache_hits,
            s.batch_dedup,
            s.coalesced,
            s.cache_evictions,
            s.journal_seeded,
            s.warm_seeded
        );
        if s.screened > 0 {
            line.push_str(&format!(" screened={}", s.screened));
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 32, 28, 28, 32, 3, 3, 1, 1), true)
    }

    #[test]
    fn batch_dedup_measures_each_point_once() {
        let s = space();
        let e = Engine::vta_sim(2);
        let p = s.default_point();
        let batch = vec![p.clone(), p.clone(), p.clone()];
        let rs = e.measure_batch(&s, &batch);
        assert_eq!(rs[0], rs[1]);
        assert_eq!(rs[1], rs[2]);
        let st = e.stats();
        assert_eq!(st.simulations, 1);
        assert_eq!(st.batch_dedup, 2);
        assert_eq!(st.coalesced, 0);
    }

    #[test]
    fn cache_serves_repeats_across_batches() {
        let s = space();
        let e = Engine::vta_sim(1);
        let p = s.default_point();
        let first = e.measure_one(&s, &p);
        let second = e.measure_one(&s, &p);
        assert_eq!(first, second);
        let st = e.stats();
        assert_eq!(st.simulations, 1);
        assert_eq!(st.cache_hits, 1);
    }

    #[test]
    fn fresh_measurements_feed_an_attached_calibration() {
        let s = space();
        let e = Engine::vta_sim(2);
        assert!(e.calibration().is_none(), "no calibration until asked for");
        let calib = e.ensure_calibration();
        assert!(Arc::ptr_eq(&calib, &e.ensure_calibration()), "one shared instance");
        let mut rng = Pcg32::seeded(5);
        let points: Vec<PointConfig> = (0..8).map(|_| s.random_point(&mut rng)).collect();
        e.measure_batch(&s, &points);
        assert!(calib.observations() > 0, "fresh points must feed the fit");
        // Cache-served repeats are not re-observed.
        let before = calib.observations();
        e.measure_batch(&s, &points);
        assert_eq!(calib.observations(), before);
        // Screened-candidate accounting is opt-in and additive.
        assert_eq!(e.stats().screened, 0);
        assert!(!e.summary().contains("screened="));
        e.note_screened(3);
        assert_eq!(e.stats().screened, 3);
        assert!(e.summary().contains("screened=3"));
    }

    #[test]
    fn results_in_input_order_and_worker_independent() {
        let s = space();
        let mut rng = Pcg32::seeded(9);
        let mut points = Vec::new();
        for _ in 0..15 {
            points.push(s.random_point(&mut rng));
        }
        // Sprinkle duplicates.
        points.push(points[0].clone());
        points.push(points[7].clone());
        let serial = Engine::with_backend(Box::new(super::super::VtaSimBackend), 1, false);
        let parallel = Engine::with_backend(Box::new(super::super::VtaSimBackend), 4, false);
        let a = serial.measure_batch(&s, &points);
        let b = parallel.measure_batch(&s, &points);
        assert_eq!(a, b);
        for (p, r) in points.iter().zip(&a) {
            assert_eq!(*r, crate::codegen::measure_point(&s, p));
        }
    }

    #[test]
    fn disabled_cache_still_dedups_within_batch() {
        let s = space();
        let e = Engine::with_backend(Box::new(super::super::VtaSimBackend), 2, false);
        let p = s.default_point();
        e.measure_batch(&s, &[p.clone(), p.clone()]);
        e.measure_batch(&s, &[p.clone()]);
        let st = e.stats();
        // Within a batch the duplicate is free; across batches it is not.
        assert_eq!(st.batch_dedup, 1);
        assert_eq!(st.simulations, 2);
        assert_eq!(st.cache_hits, 0);
    }

    #[test]
    fn empty_batch_is_free() {
        let s = space();
        let e = Engine::vta_sim(2);
        assert!(e.measure_batch(&s, &[]).is_empty());
        assert_eq!(e.stats().batches, 0);
    }

    #[test]
    fn no_inflight_entries_leak_after_batches() {
        let s = space();
        let e = Engine::vta_sim(2);
        let mut rng = Pcg32::seeded(13);
        for _ in 0..3 {
            let batch: Vec<_> = (0..8).map(|_| s.random_point(&mut rng)).collect();
            e.measure_batch(&s, &batch);
        }
        assert!(e.inflight.lock().unwrap().is_empty(), "in-flight registry must drain");
    }

    #[test]
    fn traced_origins_classify_fresh_dedup_and_cached() {
        let s = space();
        let e = Engine::vta_sim(2);
        let p = s.default_point();
        let mut rng = Pcg32::seeded(5);
        let q = loop {
            let q = s.random_point(&mut rng);
            if PointKey::of(&s, &q) != PointKey::of(&s, &p) {
                break q;
            }
        };
        let first = e.measure_batch_traced(&s, &[p.clone(), p.clone()]);
        assert_eq!(first.origins, vec![Origin::Fresh, Origin::Dedup]);
        let second = e.measure_batch_traced(&s, &[p.clone(), q.clone()]);
        assert_eq!(second.origins, vec![Origin::Cached, Origin::Fresh]);
        assert_eq!(e.stats().shard_cached, 0);
        assert_eq!(e.stats().active_batches, 0, "gauge must drain");
    }

    #[test]
    fn paired_batch_reports_provenance_counts() {
        let s = space();
        let e = Engine::vta_sim(2);
        let p = s.default_point();
        let a = e.measure_paired(&s, vec![p.clone(), p.clone()]);
        assert_eq!(a.pairs.len(), 2);
        assert_eq!(a.origins.len(), 2);
        assert_eq!((a.fresh(), a.cache_served()), (1, 1));
        let b = e.measure_paired(&s, vec![p.clone()]);
        assert_eq!((b.fresh(), b.cache_served()), (0, 1));
        for ((point, result), _origin) in b.pairs.iter().zip(&b.origins) {
            assert_eq!(*result, crate::codegen::measure_point(&s, point));
        }
    }

    #[test]
    fn warm_start_inherits_history_without_writing_it() {
        let s = space();
        let dir = std::path::PathBuf::from("target/tmp");
        std::fs::create_dir_all(&dir).unwrap();
        let warm_path = dir.join(format!("engine_warm_{}.jsonl", std::process::id()));
        let _ = std::fs::remove_file(&warm_path);

        // Build the history with a journaling engine.
        let mut rng = Pcg32::seeded(31);
        let points: Vec<_> = (0..6).map(|_| s.random_point(&mut rng)).collect();
        {
            let first = Engine::new(EngineConfig {
                backend: BackendKind::Analytical.into(),
                workers: 2,
                journal: Some(warm_path.clone()),
                ..Default::default()
            })
            .unwrap();
            first.measure_batch(&s, &points);
            first.flush_journal();
        }

        // A fresh engine warm-started from that journal answers the same
        // points without a single simulation, and reports the coverage.
        let warmed = Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            warm_start: Some(warm_path.clone()),
            ..Default::default()
        })
        .unwrap();
        let traced = warmed.measure_batch_traced(&s, &points);
        assert!(traced.origins.iter().all(|o| *o == Origin::Cached));
        let st = warmed.stats();
        assert_eq!(st.simulations, 0);
        assert!(st.warm_seeded > 0);
        assert_eq!(st.journal_seeded, 0);
        assert_eq!(warmed.preloaded_entries(), st.warm_seeded);
        // The warm-start file was never locked or rewritten.
        assert!(!std::path::Path::new(&format!("{}.lock", warm_path.display())).exists());

        // Journal + warm start over the same history (a revived shard fed
        // the merged union containing its own records): coverage counts
        // stay distinct, not doubled.
        {
            let both = Engine::new(EngineConfig {
                backend: BackendKind::Analytical.into(),
                workers: 2,
                journal: Some(warm_path.clone()),
                warm_start: Some(warm_path.clone()),
                ..Default::default()
            })
            .unwrap();
            let st = both.stats();
            assert!(st.journal_seeded > 0);
            assert_eq!(st.warm_seeded, 0, "overlapping warm entries must not double-count");
            assert_eq!(both.preloaded_entries(), st.journal_seeded);
        }

        // A missing warm-start file is an explicit construction error.
        let _ = std::fs::remove_file(&warm_path);
        let err = Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            warm_start: Some(warm_path.clone()),
            ..Default::default()
        })
        .unwrap_err()
        .to_string();
        assert!(err.contains("does not exist"), "unexpected error: {err}");
    }

    /// A backend whose substrate is permanently lost: the engine must
    /// propagate the typed error instead of panicking.
    struct LostBackend;

    impl MeasureBackend for LostBackend {
        fn name(&self) -> &'static str {
            "lost"
        }
        fn measure(&self, _space: &ConfigSpace, _point: &PointConfig) -> MeasureResult {
            unreachable!("engine must use the fallible path")
        }
        fn measure_many(
            &self,
            _space: &ConfigSpace,
            points: &[PointConfig],
            _workers: usize,
        ) -> Vec<MeasureResult> {
            panic!("infallible path must not be reached for {} points", points.len())
        }
        fn try_measure_many_traced(
            &self,
            _space: &ConfigSpace,
            _points: &[PointConfig],
            _workers: usize,
        ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
            Err(anyhow::Error::new(crate::eval::FleetLostError {
                undeliverable: 3,
                rounds: 4,
                last_error: "synthetic outage".into(),
            }))
        }
    }

    #[test]
    fn lost_backend_surfaces_typed_error_and_releases_claims() {
        let s = space();
        let e = Engine::with_backend(Box::new(LostBackend), 2, true);
        let p = s.default_point();
        let err = e.try_measure_batch_traced(&s, &[p.clone()]).unwrap_err();
        assert!(
            err.as_ref().downcast_ref::<crate::eval::FleetLostError>().is_some(),
            "expected FleetLostError, got: {err}"
        );
        assert!(err.to_string().contains("synthetic outage"));
        // The failed batch must withdraw its in-flight claims and drain
        // the active gauge, or the shard would wedge forever.
        assert!(e.inflight.lock().unwrap().is_empty(), "claims must be withdrawn");
        assert_eq!(e.stats().active_batches, 0, "gauge must drain");
        assert_eq!(e.stats().simulations, 0);
        // try_measure_paired carries the same error.
        assert!(e.try_measure_paired(&s, vec![p]).is_err());
    }

    #[test]
    fn submitted_batches_coalesce_instead_of_double_measuring() {
        let s = space();
        let e = Engine::vta_sim(2);
        let p = s.default_point();
        let mut rng = Pcg32::seeded(41);
        let q = loop {
            let q = s.random_point(&mut rng);
            if PointKey::of(&s, &q) != PointKey::of(&s, &p) {
                break q;
            }
        };
        let (a, b) = std::thread::scope(|scope| {
            // Both async batches share both points; the claim registry must
            // hand each point to exactly one owner whatever the interleave.
            let pending_a = e.submit_batch(scope, &s, vec![p.clone(), q.clone()], ());
            let pending_b = e.submit_batch(scope, &s, vec![p.clone(), q.clone()], ());
            assert_eq!(pending_a.len(), 2);
            assert!(!pending_a.is_empty());
            (pending_a.wait().unwrap(), pending_b.wait().unwrap())
        });
        assert_eq!(a.pairs[0].1, b.pairs[0].1);
        assert_eq!(a.pairs[1].1, b.pairs[1].1);
        assert_eq!(a.pairs[0].1, crate::codegen::measure_point(&s, &p));
        let st = e.stats();
        assert_eq!(st.simulations, 2, "concurrent submitted batches double-measured");
        assert!(e.inflight.lock().unwrap().is_empty(), "in-flight registry must drain");
    }

    #[test]
    fn submit_batch_drops_its_ticket_when_measurement_completes() {
        struct Flag(Arc<std::sync::atomic::AtomicBool>);
        impl Drop for Flag {
            fn drop(&mut self) {
                self.0.store(true, Ordering::SeqCst);
            }
        }
        let s = space();
        let e = Engine::vta_sim(2);
        let released = Arc::new(std::sync::atomic::AtomicBool::new(false));
        std::thread::scope(|scope| {
            let pending =
                e.submit_batch(scope, &s, vec![s.default_point()], Flag(Arc::clone(&released)));
            let out = pending.wait().unwrap();
            assert_eq!(out.pairs.len(), 1);
            // The ticket (a dispatcher permit in the tuning loop) was
            // released by the measurement thread, not by this wait().
            assert!(released.load(Ordering::SeqCst), "ticket must drop with the measurement");
        });
    }

    #[test]
    fn bounded_cache_config_caps_entries_and_counts_evictions() {
        let s = space();
        let e = Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            cache: true,
            cache_capacity: Some(8),
            ..Default::default()
        })
        .unwrap();
        let mut rng = Pcg32::seeded(21);
        let mut seen = std::collections::HashSet::new();
        let mut batch = Vec::new();
        while seen.len() < 24 {
            let p = s.random_point(&mut rng);
            if seen.insert(PointKey::of(&s, &p)) {
                batch.push(p);
            }
        }
        e.measure_batch(&s, &batch);
        let st = e.stats();
        assert!(st.cache_entries <= 8, "cache held {} entries", st.cache_entries);
        assert_eq!(st.cache_evictions, 24 - 8);
        assert_eq!(st.simulations, 24);
    }

    /// A backend that panics on its first batch and behaves normally
    /// afterwards — the regression shape for a worker thread dying while
    /// holding engine locks.
    struct PanicOnce {
        tripped: std::sync::atomic::AtomicBool,
        inner: super::super::AnalyticalBackend,
    }

    impl MeasureBackend for PanicOnce {
        fn name(&self) -> &'static str {
            "panic-once"
        }
        fn measure(&self, space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
            self.inner.measure(space, point)
        }
        fn try_measure_many_traced(
            &self,
            space: &ConfigSpace,
            points: &[PointConfig],
            workers: usize,
        ) -> anyhow::Result<(Vec<MeasureResult>, Vec<bool>)> {
            if !self.tripped.swap(true, Ordering::SeqCst) {
                panic!("backend crashed mid-batch");
            }
            self.inner.try_measure_many_traced(space, points, workers)
        }
    }

    #[test]
    fn panicking_backend_leaves_engine_usable() {
        let s = space();
        let e = Engine::with_backend(
            Box::new(PanicOnce {
                tripped: std::sync::atomic::AtomicBool::new(false),
                inner: super::super::AnalyticalBackend,
            }),
            2,
            true,
        );
        let p = s.default_point();
        let crashed = std::thread::scope(|scope| {
            scope.spawn(|| e.measure_batch(&s, std::slice::from_ref(&p))).join()
        });
        assert!(crashed.is_err(), "first batch must observe the backend panic");
        // The unwound batch must leave no residue: claims withdrawn, gauge
        // drained, and the locks it poisoned recoverable by the next batch.
        assert!(e.inflight.lock().unwrap().is_empty(), "claims must be withdrawn");
        assert_eq!(e.stats().active_batches, 0, "gauge must drain");
        let traced = e.measure_batch_traced(&s, &[p.clone()]);
        assert_eq!(traced.origins, vec![Origin::Fresh]);
        assert_eq!(traced.results[0], super::super::AnalyticalBackend.measure(&s, &p));
    }

    #[test]
    fn store_dedups_across_engine_instances() {
        let s = space();
        let dir =
            std::path::PathBuf::from(format!("target/tmp/engine_store_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let mut rng = Pcg32::seeded(77);
        let mut seen = std::collections::HashSet::new();
        let mut points = Vec::new();
        while points.len() < 5 {
            let p = s.random_point(&mut rng);
            if seen.insert(PointKey::of(&s, &p)) {
                points.push(p);
            }
        }
        let first = {
            let a = Engine::new(EngineConfig {
                backend: BackendKind::Analytical.into(),
                workers: 2,
                store: Some(StoreConfig::new(dir.clone())),
                ..Default::default()
            })
            .unwrap();
            let out = a.measure_batch(&s, &points);
            assert_eq!(a.stats().simulations, points.len());
            a.flush_journal();
            out
        };
        // A second engine (a different process in production) answers the
        // same batch from the shared store: bit-identical results, zero
        // simulations, every origin StoreServed so ledgers see fresh=false.
        let b = Engine::new(EngineConfig {
            backend: BackendKind::Analytical.into(),
            workers: 2,
            store: Some(StoreConfig::new(dir.clone())),
            ..Default::default()
        })
        .unwrap();
        let traced = b.measure_batch_traced(&s, &points);
        assert_eq!(traced.results, first);
        assert!(
            traced.origins.iter().all(|o| *o == Origin::StoreServed),
            "origins: {:?}",
            traced.origins
        );
        let st = b.stats();
        assert_eq!(st.simulations, 0);
        assert_eq!(st.store_served, points.len());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
