//! Client for the `arco serve-tune` daemon ([`super::tune_server`]).
//!
//! One TCP connection, one request → one response per line, exactly like
//! [`super::remote`] against `serve-measure` shards. [`TuneClient::connect`]
//! handshakes first — protocol version and simulator [`Fingerprint`] must
//! match the daemon, so a skewed binary is refused before it can submit a
//! job — and every later call is a blocking round trip. Server-side
//! refusals (`quota exhausted`, `unknown job`, stale cursors) surface as
//! `Err` with the daemon's exact error text.
//!
//! Traces stream through [`TuneClient::trace_page`]: the client holds its
//! position in the opaque cursor the daemon returned, so a 100k-point
//! trace arrives in bounded frames and several clients can follow the
//! same job independently. [`TuneClient::wait`] is the convenience loop:
//! page until the job is terminal and fully drained.

use super::proto::{read_frame_line, Fingerprint};
use super::tune_proto::{
    tune_response_from_line, write_tune_request_frame, JobOutcome, JobSpec, JobState, JobStatus,
    TuneRequest, TuneResponse, TUNE_PROTO_VERSION,
};
use crate::tuner::TraceEntry;
use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::time::Duration;

/// One page of a job's trace, as returned by [`TuneClient::trace_page`].
#[derive(Debug, Clone)]
pub struct TracePage {
    /// Entries after the request's cursor, in ordinal order (possibly
    /// empty: caught up with a live job).
    pub entries: Vec<TraceEntry>,
    /// Opaque resumption token for the next page.
    pub cursor: String,
    /// The job is terminal *and* this page reached the end of its trace.
    pub done: bool,
    /// Final outcome; rides the `done` page of a Done/Cancelled job.
    pub outcome: Option<JobOutcome>,
}

/// Everything [`TuneClient::wait`] collected about a finished job.
#[derive(Debug, Clone)]
pub struct WaitResult {
    /// The full trace as streamed (bounded by the daemon's `--trace-cap`:
    /// a capped daemon only retains the newest window).
    pub trace: Vec<TraceEntry>,
    /// Final outcome (None for a Failed job).
    pub outcome: Option<JobOutcome>,
    /// Terminal status (state, error text, latency counters).
    pub status: JobStatus,
}

/// A handshake-verified connection to one tuning daemon.
pub struct TuneClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    addr: String,
    client: String,
    backend: String,
    quota: usize,
}

impl TuneClient {
    /// Connect and handshake as `client` (the daemon's quota account key).
    /// Fails on an unreachable daemon, a protocol-version mismatch, or a
    /// foreign simulator fingerprint.
    pub fn connect(addr: &str, client: &str) -> anyhow::Result<TuneClient> {
        let stream = TcpStream::connect(addr)
            .map_err(|e| anyhow::anyhow!("connecting to tune daemon {addr}: {e}"))?;
        stream.set_nodelay(true).ok();
        let mut c = TuneClient {
            reader: BufReader::new(stream.try_clone()?),
            writer: BufWriter::new(stream),
            addr: addr.to_string(),
            client: client.to_string(),
            backend: String::new(),
            quota: usize::MAX,
        };
        let hello = TuneRequest::Hello {
            client: client.to_string(),
            proto: TUNE_PROTO_VERSION,
            fingerprint: Fingerprint::current(),
        };
        match c.call(&hello)? {
            TuneResponse::Hello { proto, backend, fingerprint, quota, .. } => {
                if proto != TUNE_PROTO_VERSION {
                    anyhow::bail!(
                        "daemon {addr} speaks tune-protocol v{proto}, this binary v{TUNE_PROTO_VERSION}"
                    );
                }
                let local = Fingerprint::current();
                if fingerprint != local {
                    anyhow::bail!(
                        "daemon {addr} embeds a different simulator — refusing to mix numbers.\n  \
                         daemon: {}\n  binary: {}",
                        fingerprint.describe(),
                        local.describe()
                    );
                }
                c.backend = backend;
                c.quota = quota;
                Ok(c)
            }
            other => anyhow::bail!("daemon {addr}: unexpected handshake reply {other:?}"),
        }
    }

    /// The daemon's measurement backend name (from the handshake).
    pub fn backend(&self) -> &str {
        &self.backend
    }

    /// The daemon's per-(client, task) quota (from the handshake).
    pub fn quota(&self) -> usize {
        self.quota
    }

    /// The identity this connection submits under.
    pub fn client(&self) -> &str {
        &self.client
    }

    /// One blocking round trip; an `Error` reply becomes `Err` carrying
    /// the daemon's exact refusal text.
    fn call(&mut self, req: &TuneRequest) -> anyhow::Result<TuneResponse> {
        write_tune_request_frame(&mut self.writer, req)?;
        let Some(line) = read_frame_line(&mut self.reader)? else {
            anyhow::bail!("tune daemon {} closed the connection", self.addr);
        };
        let resp = tune_response_from_line(&line)
            .ok_or_else(|| anyhow::anyhow!("unintelligible reply from {}: {line}", self.addr))?;
        match resp {
            TuneResponse::Error(msg) => anyhow::bail!("tune daemon {}: {msg}", self.addr),
            other => Ok(other),
        }
    }

    /// Submit one job; returns `(job id, queue position)`. The spec's
    /// `client` should normally be [`Self::client`] — the daemon meters
    /// whatever identity the spec carries.
    pub fn submit(&mut self, spec: JobSpec) -> anyhow::Result<(u64, usize)> {
        match self.call(&TuneRequest::Submit(spec))? {
            TuneResponse::Submitted { job, position } => Ok((job, position)),
            other => anyhow::bail!("unexpected submit reply: {other:?}"),
        }
    }

    /// Point-in-time status of one job.
    pub fn status(&mut self, job: u64) -> anyhow::Result<JobStatus> {
        match self.call(&TuneRequest::Status { job: Some(job), cursor: None, limit: 1 })? {
            TuneResponse::Status(status) => Ok(*status),
            other => anyhow::bail!("unexpected status reply: {other:?}"),
        }
    }

    /// One keyset page of the daemon's job table; `cursor: None` starts
    /// from the beginning. An empty page means the listing is exhausted.
    pub fn jobs_page(
        &mut self,
        cursor: Option<String>,
        limit: usize,
    ) -> anyhow::Result<(Vec<JobStatus>, String)> {
        match self.call(&TuneRequest::Status { job: None, cursor, limit })? {
            TuneResponse::Jobs { jobs, cursor } => Ok((jobs, cursor)),
            other => anyhow::bail!("unexpected listing reply: {other:?}"),
        }
    }

    /// The whole job table, paged `limit` at a time until an empty page
    /// terminates the listing.
    pub fn list_jobs(&mut self, limit: usize) -> anyhow::Result<Vec<JobStatus>> {
        let mut all = Vec::new();
        let mut cursor: Option<String> = None;
        loop {
            let (jobs, next) = self.jobs_page(cursor, limit)?;
            if jobs.is_empty() {
                return Ok(all);
            }
            all.extend(jobs);
            cursor = Some(next);
        }
    }

    /// One page of a job's trace; `cursor: None` starts from the first
    /// entry. Pass the returned cursor back to resume — pages are
    /// gap-free and monotone however many entries land in between.
    pub fn trace_page(
        &mut self,
        job: u64,
        cursor: Option<String>,
        limit: usize,
    ) -> anyhow::Result<TracePage> {
        match self.call(&TuneRequest::Results { job, cursor, limit })? {
            TuneResponse::Page { entries, cursor, done, outcome, .. } => {
                Ok(TracePage { entries, cursor, done, outcome })
            }
            other => anyhow::bail!("unexpected results reply: {other:?}"),
        }
    }

    /// Request cooperative cancellation; returns the job's state after
    /// the request (a finished job stays finished).
    pub fn cancel(&mut self, job: u64) -> anyhow::Result<JobState> {
        match self.call(&TuneRequest::Cancel { job })? {
            TuneResponse::Cancelled { state, .. } => Ok(state),
            other => anyhow::bail!("unexpected cancel reply: {other:?}"),
        }
    }

    /// Stream `job`'s trace to completion: page `page_size` entries at a
    /// time, sleeping `poll` between empty pages while the job still
    /// runs, until the terminal page drains. Returns the collected trace,
    /// the final outcome (None for a Failed job) and the terminal status.
    pub fn wait(
        &mut self,
        job: u64,
        page_size: usize,
        poll: Duration,
    ) -> anyhow::Result<WaitResult> {
        let mut trace = Vec::new();
        let mut cursor: Option<String> = None;
        let mut outcome = None;
        loop {
            let page = self.trace_page(job, cursor.take(), page_size)?;
            let advanced = !page.entries.is_empty();
            trace.extend(page.entries);
            if page.done {
                outcome = page.outcome;
                break;
            }
            if !advanced {
                // Caught up with a live (or still-queued) job: back off
                // instead of hammering the daemon with empty pages.
                std::thread::sleep(poll);
            }
            cursor = Some(page.cursor);
        }
        let status = self.status(job)?;
        Ok(WaitResult { trace, outcome, status })
    }
}
