//! Compute backend for the MARL networks: AOT/XLA (production path) or the
//! native mirror (artifact-free tests, CHAMELEON's single-agent RL).
//!
//! Both implement the same five entry points over flat f32 parameter
//! vectors; `rust/tests/runtime_parity.rs` pins them to each other.

use crate::ml::{clip_grad_norm, ppo, Adam, AdamParams, Mat, Mlp};
use crate::runtime::engine::{PolicyTrainOut, ValueTrainOut};
use crate::runtime::{Engine, ModelDims};
use std::cell::RefCell;
use std::path::Path;
use std::rc::Rc;

thread_local! {
    /// Per-thread engine cache: PJRT compilation of the five artifacts
    /// takes ~0.7 s, and a model tune instantiates one strategy per task —
    /// sharing the compiled engine across tasks removes that per-task
    /// startup entirely (EXPERIMENTS.md §Perf, L3 item 1). Thread-local
    /// (not global) because the PJRT client is not Sync.
    static ENGINE_CACHE: RefCell<Option<Rc<Engine>>> = const { RefCell::new(None) };
}

/// Which execution path serves the MARL networks.
pub enum Backend {
    /// AOT-compiled HLO on PJRT (the paper-faithful production path).
    /// Reference-counted so one compiled engine serves every task tuned on
    /// this thread.
    Xla(Rc<Engine>),
    /// Native rust mirror of the same graphs.
    Native(NativeBackend),
}

impl Backend {
    /// Load the XLA backend if artifacts exist, else fall back to native.
    pub fn auto(dims: ModelDims) -> Backend {
        let dir = crate::runtime::manifest::artifacts_dir();
        if dir.join("manifest.json").exists() {
            let cached = ENGINE_CACHE.with(|c| c.borrow().clone());
            if let Some(e) = cached {
                return Backend::Xla(e);
            }
            match Engine::load(&dir) {
                Ok(e) => {
                    let e = Rc::new(e);
                    ENGINE_CACHE.with(|c| *c.borrow_mut() = Some(e.clone()));
                    return Backend::Xla(e);
                }
                Err(err) => {
                    crate::log_warn!("backend", "XLA engine failed ({err}); using native");
                }
            }
        } else {
            crate::log_warn!("backend", "no artifacts at {}; using native backend", dir.display());
        }
        Backend::Native(NativeBackend::new(dims))
    }

    /// Force the native backend.
    pub fn native(dims: ModelDims) -> Backend {
        Backend::Native(NativeBackend::new(dims))
    }

    /// Force the XLA backend from a directory.
    pub fn xla(dir: &Path) -> anyhow::Result<Backend> {
        Ok(Backend::Xla(Rc::new(Engine::load(dir)?)))
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Xla(_) => "xla",
            Backend::Native(_) => "native",
        }
    }

    pub fn dims(&self) -> ModelDims {
        match self {
            Backend::Xla(e) => e.manifest.dims,
            Backend::Native(n) => n.dims,
        }
    }

    /// Masked log-probs; obs is (b_pol, obs_dim) row-major (caller pads).
    pub fn policy_forward(&self, params: &[f32], obs: &[f32], mask: &[f32]) -> Vec<f32> {
        match self {
            Backend::Xla(e) => e.policy_forward(params, obs, mask).expect("policy_forward"),
            Backend::Native(n) => n.policy_forward(params, obs, mask),
        }
    }

    /// Critic values; state is (b_pol, gstate_dim) row-major.
    pub fn value_forward(&self, params: &[f32], state: &[f32]) -> Vec<f32> {
        match self {
            Backend::Xla(e) => e.value_forward(params, state).expect("value_forward"),
            Backend::Native(n) => n.value_forward(params, state),
        }
    }

    /// GAE over the fixed t_gae horizon.
    pub fn gae(
        &self,
        rewards: &[f32],
        values: &[f32],
        bootstrap: f32,
        gamma: f32,
        lam: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        match self {
            Backend::Xla(e) => e.gae(rewards, values, bootstrap, gamma, lam).expect("gae"),
            Backend::Native(_) => ppo::gae(rewards, values, bootstrap, gamma, lam),
        }
    }

    /// One PPO-clip policy update (padded to b_train; weight=0 rows inert).
    #[allow(clippy::too_many_arguments)]
    pub fn policy_train(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        t: f32,
        obs: &[f32],
        mask: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        weight: &[f32],
    ) -> PolicyTrainOut {
        match self {
            Backend::Xla(e) => e
                .policy_train(params, m, v, t, obs, mask, actions, old_logp, adv, weight)
                .expect("policy_train"),
            Backend::Native(n) => {
                n.policy_train(params, m, v, t, obs, mask, actions, old_logp, adv, weight)
            }
        }
    }

    /// One critic MSE update.
    #[allow(clippy::too_many_arguments)]
    pub fn value_train(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        t: f32,
        state: &[f32],
        returns: &[f32],
        weight: &[f32],
    ) -> ValueTrainOut {
        match self {
            Backend::Xla(e) => {
                e.value_train(params, m, v, t, state, returns, weight).expect("value_train")
            }
            Backend::Native(n) => n.value_train(params, m, v, t, state, returns, weight),
        }
    }
}

/// Native implementation mirroring python/compile/model.py exactly
/// (same hyper-parameters, same weighted losses, same Adam).
pub struct NativeBackend {
    pub dims: ModelDims,
}

// Baked hyper-parameters — keep in sync with python/compile/model.py.
const CLIP_EPS: f32 = 0.2;
const ENTROPY_COEF: f32 = 0.01;
const LR_POLICY: f32 = 5e-3;
const LR_VALUE: f32 = 5e-3;
const MAX_GRAD_NORM: f32 = 10.0;

impl NativeBackend {
    pub fn new(dims: ModelDims) -> NativeBackend {
        NativeBackend { dims }
    }

    fn policy_mlp(&self, params: &[f32]) -> Mlp {
        let mut rng = crate::util::rng::Pcg32::seeded(0);
        let mut mlp = Mlp::policy(self.dims.obs_dim, self.dims.act_dim, &mut rng);
        mlp.unflatten(params);
        mlp
    }

    fn value_mlp(&self, params: &[f32]) -> Mlp {
        let mut rng = crate::util::rng::Pcg32::seeded(0);
        let mut mlp = Mlp::value(self.dims.gstate_dim, &mut rng);
        mlp.unflatten(params);
        mlp
    }

    pub fn policy_forward(&self, params: &[f32], obs: &[f32], mask: &[f32]) -> Vec<f32> {
        let d = self.dims;
        let mlp = self.policy_mlp(params);
        let x = Mat::from_vec(d.b_pol, d.obs_dim, obs.to_vec());
        let cache = mlp.forward(&x);
        ppo::masked_log_softmax(cache.output(), mask).data
    }

    pub fn value_forward(&self, params: &[f32], state: &[f32]) -> Vec<f32> {
        let d = self.dims;
        let mlp = self.value_mlp(params);
        let x = Mat::from_vec(d.b_pol, d.gstate_dim, state.to_vec());
        mlp.forward(&x).output().data.clone()
    }

    #[allow(clippy::too_many_arguments)]
    pub fn policy_train(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        t: f32,
        obs: &[f32],
        mask: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        weight: &[f32],
    ) -> PolicyTrainOut {
        let d = self.dims;
        let mlp = self.policy_mlp(params);
        let x = Mat::from_vec(d.b_train, d.obs_dim, obs.to_vec());
        let cache = mlp.forward(&x);

        // Weighted PPO loss: drop zero-weight rows from the mean by scaling
        // the per-row gradient; ml::ppo uses a plain mean over b rows, so we
        // re-weight to sum(w) by scaling adv rows and correcting after.
        let wsum: f32 = weight.iter().sum::<f32>().max(1.0);
        let acts: Vec<usize> = actions.iter().map(|&a| a as usize).collect();
        let (loss, mut d_logits, entropy, clip_frac) = ppo::ppo_policy_loss_grad(
            cache.output(),
            mask,
            &acts,
            old_logp,
            adv,
            CLIP_EPS,
            ENTROPY_COEF,
        );
        // Re-weight gradient rows: multiply row r by weight[r] * b / wsum.
        let scale_rows = d.b_train as f32 / wsum;
        for r in 0..d.b_train {
            let s = weight[r] * scale_rows;
            for c in 0..d.act_dim {
                *d_logits.at_mut(r, c) *= s;
            }
        }
        let grads = mlp.backward(&cache, &d_logits);
        let mut flat_grads = Mlp::flatten_grads(&grads);
        clip_grad_norm(&mut flat_grads, MAX_GRAD_NORM);

        let mut new_params = params.to_vec();
        let mut adam = Adam::new(new_params.len(), AdamParams { lr: LR_POLICY, ..Default::default() });
        restore_adam(&mut adam, m, v, t);
        adam.step(&mut new_params, &flat_grads);
        let (m_out, v_out, t_out) = extract_adam(&adam);
        PolicyTrainOut {
            params: new_params,
            m: m_out,
            v: v_out,
            t: t_out,
            loss,
            entropy,
            clip_frac,
        }
    }

    #[allow(clippy::too_many_arguments)]
    pub fn value_train(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        t: f32,
        state: &[f32],
        returns: &[f32],
        weight: &[f32],
    ) -> ValueTrainOut {
        let d = self.dims;
        let mlp = self.value_mlp(params);
        let x = Mat::from_vec(d.b_train, d.gstate_dim, state.to_vec());
        let cache = mlp.forward(&x);
        let pred = cache.output();
        let wsum: f32 = weight.iter().sum::<f32>().max(1.0);
        let mut loss = 0.0f32;
        let mut d_out = Mat::zeros(d.b_train, 1);
        for r in 0..d.b_train {
            let err = pred.at(r, 0) - returns[r];
            loss += err * err * weight[r];
            *d_out.at_mut(r, 0) = 2.0 * err * weight[r] / wsum;
        }
        loss /= wsum;
        let grads = mlp.backward(&cache, &d_out);
        let mut flat_grads = Mlp::flatten_grads(&grads);
        clip_grad_norm(&mut flat_grads, MAX_GRAD_NORM);
        let mut new_params = params.to_vec();
        let mut adam = Adam::new(new_params.len(), AdamParams { lr: LR_VALUE, ..Default::default() });
        restore_adam(&mut adam, m, v, t);
        adam.step(&mut new_params, &flat_grads);
        let (m_out, v_out, t_out) = extract_adam(&adam);
        ValueTrainOut { params: new_params, m: m_out, v: v_out, t: t_out, loss }
    }
}

// Adam state round-trips through flat (m, v, t) triples to match the HLO
// interface. The Adam struct does not expose its internals publicly, so we
// rebuild it here via a small shim.
fn restore_adam(adam: &mut Adam, m: &[f32], v: &[f32], t: f32) {
    adam.restore_state(m, v, t as u32);
}

fn extract_adam(adam: &Adam) -> (Vec<f32>, Vec<f32>, f32) {
    let (m, v, t) = adam.state();
    (m.to_vec(), v.to_vec(), t as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;

    fn dims() -> ModelDims {
        ModelDims::default()
    }

    fn rand_vec(n: usize, rng: &mut Pcg32) -> Vec<f32> {
        (0..n).map(|_| rng.gen_f32() * 0.2 - 0.1).collect()
    }

    #[test]
    fn native_policy_forward_shapes() {
        let d = dims();
        let b = Backend::native(d);
        let mut rng = Pcg32::seeded(2);
        let params = rand_vec(d.p_policy, &mut rng);
        let obs = rand_vec(d.b_pol * d.obs_dim, &mut rng);
        let mask = vec![1.0f32; d.act_dim];
        let lp = b.policy_forward(&params, &obs, &mask);
        assert_eq!(lp.len(), d.b_pol * d.act_dim);
        // Rows normalize.
        for r in 0..d.b_pol {
            let total: f32 =
                lp[r * d.act_dim..(r + 1) * d.act_dim].iter().map(|x| x.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4, "row {r} sums to {total}");
        }
    }

    #[test]
    fn native_train_reduces_policy_loss() {
        let d = dims();
        let b = Backend::native(d);
        let mut rng = Pcg32::seeded(3);
        let mut params = rand_vec(d.p_policy, &mut rng);
        let mut m = vec![0.0f32; d.p_policy];
        let mut v = vec![0.0f32; d.p_policy];
        let mut t = 0.0f32;
        let obs = rand_vec(d.b_train * d.obs_dim, &mut rng);
        let mask = vec![1.0f32; d.act_dim];
        let actions: Vec<i32> = (0..d.b_train).map(|_| rng.gen_range(d.act_dim) as i32).collect();
        // old_logp = uniform-ish log prob.
        let old_logp = vec![-(d.act_dim as f32).ln(); d.b_train];
        let adv: Vec<f32> = (0..d.b_train).map(|_| rng.gen_f32() * 2.0 - 1.0).collect();
        let weight = vec![1.0f32; d.b_train];
        let mut losses = Vec::new();
        for _ in 0..6 {
            let out =
                b.policy_train(&params, &m, &v, t, &obs, &mask, &actions, &old_logp, &adv, &weight);
            losses.push(out.loss);
            params = out.params;
            m = out.m;
            v = out.v;
            t = out.t;
        }
        assert!(losses.last().unwrap() < losses.first().unwrap(), "{losses:?}");
        assert_eq!(t, 6.0);
    }

    #[test]
    fn native_gae_matches_ppo_module() {
        let d = dims();
        let b = Backend::native(d);
        let mut rng = Pcg32::seeded(4);
        let rewards = rand_vec(d.t_gae, &mut rng);
        let values = rand_vec(d.t_gae, &mut rng);
        let (a1, r1) = b.gae(&rewards, &values, 0.1, 0.99, 0.95);
        let (a2, r2) = ppo::gae(&rewards, &values, 0.1, 0.99, 0.95);
        assert_eq!(a1, a2);
        assert_eq!(r1, r2);
    }
}
