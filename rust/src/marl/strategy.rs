//! ARCO as a [`Strategy`]: MARL exploration (Algorithm 1) + Confidence
//! Sampling (Algorithm 2) + the GBT surrogate, wired into the shared
//! tuning loop.

use super::backend::Backend;
use super::confidence::confidence_sampling;
use super::env::CoOptEnv;
use super::exploration::{ExploreParams, MarlExplorer, Visited};
use super::mappo::Mappo;
use crate::costmodel::{featurize, CostModel, Gbt, GbtParams};
use crate::eval::MeasureResult;
use crate::space::{ConfigSpace, PointConfig};
use crate::tuner::Strategy;
use crate::util::rng::Pcg32;
use std::collections::HashSet;

/// ARCO hyper-parameters (Table 4).
#[derive(Debug, Clone, Copy)]
pub struct ArcoParams {
    pub explore: ExploreParams,
    pub gbt: GbtParams,
    /// γ / λ of the GAE (Eq. 2).
    pub gamma: f32,
    pub lam: f32,
    /// Disable Confidence Sampling (ablation; Fig. 4 "before").
    pub use_cs: bool,
}

impl Default for ArcoParams {
    fn default() -> Self {
        ArcoParams {
            explore: ExploreParams::default(),
            gbt: GbtParams::default(),
            gamma: 0.99,
            lam: 0.95,
            use_cs: true,
        }
    }
}

impl ArcoParams {
    pub fn quick() -> ArcoParams {
        ArcoParams {
            explore: ExploreParams { episodes: 3, steps: 10, population: 16, ppo_epochs: 1 },
            ..Default::default()
        }
    }
}

/// The full ARCO strategy.
pub struct Arco {
    space: ConfigSpace,
    params: ArcoParams,
    backend: Backend,
    explorer: MarlExplorer,
    model: Gbt,
    rng: Pcg32,
    xs: Vec<Vec<f64>>,
    ys: Vec<f64>,
    seen: HashSet<usize>,
    /// Best measured points (seeds for the next exploration round).
    elite: Vec<(PointConfig, f64)>,
    last_cs_synth: usize,
}

impl Arco {
    /// Build with an explicit backend (XLA in production, native in tests).
    pub fn with_backend(
        space: ConfigSpace,
        params: ArcoParams,
        backend: Backend,
        seed: u64,
    ) -> Arco {
        let dims = backend.dims();
        let mut rng = Pcg32::seeded(seed);
        let mappo = Mappo::new(dims, params.gamma, params.lam, &mut rng);
        let explorer = MarlExplorer::new(mappo, params.explore, seed ^ 0x5eed);
        Arco {
            space,
            params,
            backend,
            explorer,
            model: Gbt::new(params.gbt),
            rng,
            xs: Vec::new(),
            ys: Vec::new(),
            seen: HashSet::new(),
            elite: Vec::new(),
            last_cs_synth: 0,
        }
    }

    /// Auto-select the backend (XLA when artifacts exist).
    pub fn new(space: ConfigSpace, params: ArcoParams, seed: u64) -> Arco {
        let backend = Backend::auto(crate::runtime::ModelDims::default());
        Self::with_backend(space, params, backend, seed)
    }

    /// Random unmeasured configurations, *constraint-aware*: the penalty
    /// term (Eq. 4) is free to evaluate, so ARCO never spends a hardware
    /// measurement on a configuration it can already tell is infeasible
    /// (area over budget or scratchpad overflow). This is the mechanism
    /// that keeps its invalid-measurement count near zero (§3.3).
    fn random_unseen(&mut self, n: usize) -> Vec<PointConfig> {
        let env = CoOptEnv::new(&self.space, self.backend.dims());
        let mut out = Vec::new();
        let mut attempts = 0;
        while out.len() < n && attempts < n * 200 {
            let p = self.space.random_point(&mut self.rng);
            attempts += 1;
            if env.penalty(&p) > 0.0 {
                continue;
            }
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
        }
        // Space nearly exhausted of feasible points: accept anything new.
        let mut fallback_attempts = 0;
        while out.is_empty() && fallback_attempts < n * 100 {
            let p = self.space.random_point(&mut self.rng);
            fallback_attempts += 1;
            if self.seen.insert(self.space.flat_index(&p)) {
                out.push(p);
            }
        }
        out
    }

}

impl Strategy for Arco {
    fn name(&self) -> &'static str {
        "arco"
    }

    fn plan(&mut self, batch: usize) -> Vec<PointConfig> {
        if !self.model.is_trained() {
            return self.random_unseen(batch);
        }
        let dims = self.backend.dims();
        let env = CoOptEnv::new(&self.space, dims);
        let seeds: Vec<PointConfig> =
            self.elite.iter().map(|(p, _)| p.clone()).take(8).collect();

        // Algorithm 1: MARL exploration over the surrogate (the GBT is a
        // few KB of tree nodes, so cloning it into the closure is cheap).
        let visited: Vec<Visited> = {
            let space = self.space.clone();
            let m = self.model.clone();
            let surrogate = move |p: &PointConfig| -> f64 {
                if m.is_trained() {
                    m.predict(&featurize(&space, p)).max(0.0)
                } else {
                    0.0
                }
            };
            self.explorer.explore(&env, &self.backend, &surrogate, &seeds)
        };

        let fresh: Vec<Visited> = visited
            .into_iter()
            .filter(|v| !self.seen.contains(&self.space.flat_index(&v.point)))
            .collect();
        if fresh.is_empty() {
            return self.random_unseen(batch);
        }
        let points: Vec<PointConfig> = fresh.iter().map(|v| v.point.clone()).collect();

        let mut selected = if self.params.use_cs {
            // Algorithm 2: critic-scored Confidence Sampling.
            let values = self.explorer.critic_scores(&env, &self.backend, &points);
            let out = confidence_sampling(&self.space, &points, &values, batch, &mut self.rng);
            self.last_cs_synth = out.synthesized;
            out.selected
        } else {
            // Ablation ("before CS", Fig. 4a): surrogate top-k plus uniform
            // fill to the full batch — the uniform-sampling behaviour CS
            // replaces, which measures a full batch every iteration.
            let mut scored = fresh;
            scored.sort_by(|a, b| {
                b.surrogate.partial_cmp(&a.surrogate).unwrap_or(std::cmp::Ordering::Equal)
            });
            let mut v: Vec<PointConfig> =
                scored.into_iter().take(batch).map(|v| v.point).collect();
            v.retain(|p| !self.seen.contains(&self.space.flat_index(p)));
            for p in &v {
                self.seen.insert(self.space.flat_index(p));
            }
            let fill = batch.saturating_sub(v.len());
            if fill > 0 {
                v.extend(self.random_unseen(fill));
            }
            return v;
        };

        // De-dup against measured history and drop constraint violators
        // (CS synthesis can combine knobs into an infeasible point; the
        // penalty check is free). Deliberately NO random backfill:
        // measuring fewer, higher-confidence configurations per iteration is
        // the CS mechanism that cuts compilation time (Fig. 4 / Fig. 6).
        selected.retain(|p| {
            !self.seen.contains(&self.space.flat_index(p)) && env.penalty(p) <= 0.0
        });
        for p in &selected {
            self.seen.insert(self.space.flat_index(p));
        }
        if selected.is_empty() {
            // Degenerate round (everything already measured): keep moving.
            return self.random_unseen(batch.min(8));
        }
        selected.truncate(batch);
        selected
    }

    fn observe(&mut self, results: &[(PointConfig, MeasureResult)]) {
        for (p, r) in results {
            self.seen.insert(self.space.flat_index(p));
            self.xs.push(featurize(&self.space, p));
            self.ys.push(r.fitness());
            self.explorer.note_measured_fitness(r.fitness());
            if r.valid {
                self.elite.push((p.clone(), r.fitness()));
            }
        }
        self.elite.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap_or(std::cmp::Ordering::Equal));
        self.elite.truncate(16);
        self.model.fit(&self.xs, &self.ys);
    }

    /// Safe at any pipeline depth: `seen` is updated at plan time (MARL
    /// exploration, CS selection and the random fallback all dedup
    /// against it before proposing), so in-flight points are never
    /// re-planned; observing a batch late only delays the GBT refit and
    /// the elite-seed refresh by one round — the same
    /// sample-efficiency-for-wall-clock trade Krishnan et al. exploit.
    fn max_pipeline_depth(&self) -> usize {
        usize::MAX
    }

    fn diag(&self) -> String {
        format!(
            "backend={} gbt_trees={} data={} elite={} cs_synth={} best_fit={:.3e}",
            self.backend.name(),
            self.model.num_trees(),
            self.ys.len(),
            self.elite.len(),
            self.last_cs_synth,
            self.explorer.best_fitness
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::eval::Engine;
    use crate::runtime::ModelDims;
    use crate::tuner::{tune_task, TuneBudget};
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1), true)
    }

    fn arco(s: &ConfigSpace) -> Arco {
        Arco::with_backend(
            s.clone(),
            ArcoParams::quick(),
            Backend::native(ModelDims::default()),
            11,
        )
    }

    #[test]
    fn plans_distinct_unmeasured_configs() {
        let s = space();
        let engine = Engine::vta_sim(2);
        let mut a = arco(&s);
        let mut all = HashSet::new();
        for _ in 0..3 {
            let plan = a.plan(16);
            assert!(!plan.is_empty());
            for p in &plan {
                assert!(all.insert(s.flat_index(p)), "duplicate planned config");
            }
            a.observe(&engine.measure_paired(&s, plan).pairs);
        }
    }

    #[test]
    fn explores_hardware_knobs() {
        // ARCO's whole point: it must actually propose non-default hardware.
        let s = space();
        let mut a = arco(&s);
        let engine = Engine::vta_sim(2);
        let mut saw_nondefault_hw = false;
        for _ in 0..4 {
            let plan = a.plan(16);
            for p in &plan {
                let (hw, _) = s.decode(p);
                if (hw.batch, hw.block_in, hw.block_out) != (1, 16, 16) {
                    saw_nondefault_hw = true;
                }
            }
            a.observe(&engine.measure_paired(&s, plan).pairs);
        }
        assert!(saw_nondefault_hw);
    }

    #[test]
    fn full_tune_converges_to_decent_config() {
        let s = space();
        let mut a = arco(&s);
        let budget = TuneBudget { total_measurements: 128, batch: 32, workers: 2, ..Default::default() };
        let r = tune_task(&s, &mut a, budget).unwrap();
        assert!(r.best.valid);
        assert!(r.best.gflops > 0.0);
        // Must beat the worst decile of random configs comfortably: check
        // it beats the default point.
        let default = Engine::vta_sim(1).measure_one(&s, &s.default_point());
        assert!(
            r.best.seconds <= default.seconds,
            "tuned {} should beat default {}",
            r.best.seconds,
            default.seconds
        );
    }

    #[test]
    fn cs_ablation_still_plans() {
        let s = space();
        let mut params = ArcoParams::quick();
        params.use_cs = false;
        let mut a =
            Arco::with_backend(s.clone(), params, Backend::native(ModelDims::default()), 4);
        let engine = Engine::vta_sim(2);
        let plan = a.plan(16);
        a.observe(&engine.measure_paired(&s, plan).pairs);
        let plan2 = a.plan(16);
        assert!(!plan2.is_empty());
    }

    #[test]
    fn diag_reports_backend() {
        let s = space();
        let a = arco(&s);
        assert!(a.diag().contains("backend=native"));
    }
}
