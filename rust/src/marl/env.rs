//! The co-optimization environment the three agents act in: observation
//! and global-state encodings, per-agent action spaces (knob steps), and
//! the constrained reward (Eqs. 4–5).

use crate::costmodel::featurize;
use crate::eval::MeasureResult;
use crate::runtime::ModelDims;
use crate::space::{ConfigSpace, KnobOwner, PointConfig};
use crate::vta::area::{default_area_budget_mm2, total_area_mm2};
use crate::vta::config::{ACC_BYTES, INP_BYTES, WGT_BYTES};

/// Agent roles (Table 1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Role {
    Hardware,
    Scheduling,
    Mapping,
}

pub const ROLES: [Role; 3] = [Role::Hardware, Role::Scheduling, Role::Mapping];

impl Role {
    pub fn owner(self) -> KnobOwner {
        match self {
            Role::Hardware => KnobOwner::Hardware,
            Role::Scheduling => KnobOwner::Scheduling,
            Role::Mapping => KnobOwner::Mapping,
        }
    }

    pub fn index(self) -> usize {
        match self {
            Role::Hardware => 0,
            Role::Scheduling => 1,
            Role::Mapping => 2,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Role::Hardware => "hardware",
            Role::Scheduling => "scheduling",
            Role::Mapping => "mapping",
        }
    }

    /// Number of knobs this agent owns.
    pub fn num_knobs(self) -> usize {
        match self {
            Role::Hardware => 3,
            Role::Scheduling | Role::Mapping => 2,
        }
    }

    /// Joint action count: 3^knobs directions ({dec, stay, inc} per knob).
    pub fn num_actions(self) -> usize {
        3usize.pow(self.num_knobs() as u32)
    }

    /// Action mask over the padded ACT_DIM space.
    pub fn action_mask(self, act_dim: usize) -> Vec<f32> {
        let n = self.num_actions();
        (0..act_dim).map(|a| if a < n { 1.0 } else { 0.0 }).collect()
    }

    /// Decode a joint action into per-knob deltas (-1, 0, +1), one per
    /// owned knob, base-3 little-endian.
    pub fn decode_action(self, action: usize) -> Vec<i32> {
        let mut a = action;
        (0..self.num_knobs())
            .map(|_| {
                let digit = (a % 3) as i32;
                a /= 3;
                digit - 1
            })
            .collect()
    }
}

/// Environment dynamics-free helper: applies agent actions to points and
/// encodes observations/states.
pub struct CoOptEnv<'a> {
    pub space: &'a ConfigSpace,
    pub dims: ModelDims,
    /// λ of Eq. 4.
    pub penalty_lambda: f64,
    /// area_max of Eq. 4 (mm²).
    pub area_max_mm2: f64,
}

impl<'a> CoOptEnv<'a> {
    pub fn new(space: &'a ConfigSpace, dims: ModelDims) -> CoOptEnv<'a> {
        CoOptEnv {
            space,
            dims,
            penalty_lambda: 1.0,
            area_max_mm2: default_area_budget_mm2(),
        }
    }

    /// Apply one agent's joint action to a point (clamped knob steps).
    /// Frozen hardware knobs are never moved.
    pub fn apply_action(&self, point: &PointConfig, role: Role, action: usize) -> PointConfig {
        let deltas = role.decode_action(action);
        let knob_idx = self.space.agent_knobs(role.owner());
        let mut q = point.clone();
        for (i, &k) in knob_idx.iter().enumerate() {
            if self.space.knob_frozen(k) {
                continue;
            }
            let arity = self.space.knobs[k].len() as i64;
            let cur = q.0[k] as i64;
            let next = (cur + deltas[i] as i64).clamp(0, arity - 1);
            q.0[k] = next as usize;
        }
        q
    }

    /// Per-agent observation (obs_dim floats): normalized knob vector,
    /// agent one-hot, episode dynamics, cheap config descriptors.
    pub fn observe(
        &self,
        point: &PointConfig,
        role: Role,
        last_reward: f32,
        best_fitness_norm: f32,
        step_frac: f32,
    ) -> Vec<f32> {
        let mut obs = Vec::with_capacity(self.dims.obs_dim);
        for f in self.space.normalized(point) {
            obs.push(f as f32); // 7 knobs
        }
        let mut one_hot = [0.0f32; 3];
        one_hot[role.index()] = 1.0;
        obs.extend_from_slice(&one_hot); // +3 = 10
        obs.push(last_reward.clamp(-4.0, 4.0));
        obs.push(best_fitness_norm.clamp(0.0, 4.0));
        obs.push(step_frac);
        let (hw, _) = self.space.decode(point);
        obs.push((total_area_mm2(&hw) / self.area_max_mm2) as f32);
        obs.push(self.memory_overflow_ratio(point) as f32);
        obs.resize(self.dims.obs_dim, 0.0);
        obs
    }

    /// Global state for the centralized critic: knobs + task descriptors +
    /// episode dynamics (gstate_dim floats).
    pub fn global_state(
        &self,
        point: &PointConfig,
        last_reward: f32,
        best_fitness_norm: f32,
        step_frac: f32,
    ) -> Vec<f32> {
        let t = &self.space.task;
        let mut s = Vec::with_capacity(self.dims.gstate_dim);
        for f in self.space.normalized(point) {
            s.push(f as f32); // 7
        }
        let lg = |v: usize| (v.max(1) as f32).log2() / 10.0;
        s.push(lg(t.ci));
        s.push(lg(t.co));
        s.push(lg(t.oh()));
        s.push(lg(t.ow()));
        s.push(t.kh as f32 / 11.0);
        s.push(t.stride as f32 / 4.0);
        s.push((t.arithmetic_intensity().ln() / 8.0) as f32); // 14
        let (hw, _) = self.space.decode(point);
        s.push((total_area_mm2(&hw) / self.area_max_mm2) as f32);
        s.push(self.memory_overflow_ratio(point) as f32);
        s.push(last_reward.clamp(-4.0, 4.0));
        s.push(best_fitness_norm.clamp(0.0, 4.0));
        s.push(step_frac); // 19
        s.resize(self.dims.gstate_dim, 0.0);
        s
    }

    /// memory(Θ) overflow as a ratio: how far the tile working sets exceed
    /// their scratchpad partitions (0 when everything fits).
    pub fn memory_overflow_ratio(&self, point: &PointConfig) -> f64 {
        memory_overflow_ratio(self.space, point)
    }

    /// Constraint penalty P(Θ) of Eq. 4 (area in units of the budget,
    /// memory as overflow ratio).
    pub fn penalty(&self, point: &PointConfig) -> f64 {
        let (hw, _) = self.space.decode(point);
        let area_ratio = total_area_mm2(&hw) / self.area_max_mm2;
        let area_term = (area_ratio - 1.0).max(0.0);
        let mem_term = self.memory_overflow_ratio(point);
        self.penalty_lambda * (area_term + mem_term)
    }

    /// Constrained step reward (Eq. 5) from a surrogate fitness estimate,
    /// normalized by the best measured fitness so far.
    pub fn reward(&self, point: &PointConfig, surrogate_fitness: f64, best_fitness: f64) -> f32 {
        let norm = if best_fitness > 0.0 { surrogate_fitness / best_fitness } else { 0.0 };
        (norm - self.penalty(point)) as f32
    }

    /// Reward from an actual measurement (Eq. 5 with real runtime).
    pub fn reward_measured(
        &self,
        point: &PointConfig,
        m: &MeasureResult,
        best_fitness: f64,
    ) -> f32 {
        self.reward(point, m.fitness(), best_fitness)
    }

    /// Cheap surrogate features for the GBT model.
    pub fn features(&self, point: &PointConfig) -> Vec<f64> {
        featurize(self.space, point)
    }
}

/// memory(Θ) overflow ratio of a point: 0 when every tile working set fits
/// its scratchpad partition. Free-standing so baselines can pre-filter
/// obviously-invalid configurations without paying a measurement.
pub fn memory_overflow_ratio(space: &ConfigSpace, point: &PointConfig) -> f64 {
    let (hw, sw) = space.decode(point);
    let t = &space.task;
    let in_h = (sw.tile_h.saturating_sub(1)) * t.stride + t.kh;
    let in_w = (sw.tile_w.saturating_sub(1)) * t.stride + t.kw;
    let vt = (sw.h_threading * sw.oc_threading).clamp(1, 2);
    let inp = (hw.batch * in_h * in_w * hw.block_in * INP_BYTES) as f64
        / (hw.inp_buf_bytes() / vt) as f64;
    let wgt = (hw.block_out * hw.block_in * t.kh * t.kw * WGT_BYTES) as f64
        / (hw.wgt_buf_bytes() / vt) as f64;
    let acc = (hw.batch * sw.tile_h * sw.tile_w * hw.block_out * ACC_BYTES) as f64
        / (hw.acc_buf_bytes() / vt) as f64;
    (inp - 1.0).max(0.0) + (wgt - 1.0).max(0.0) + (acc - 1.0).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1), true)
    }

    #[test]
    fn roles_cover_all_knobs() {
        let s = space();
        let total: usize = ROLES.iter().map(|r| s.agent_knobs(r.owner()).len()).sum();
        assert_eq!(total, s.num_knobs());
        assert_eq!(Role::Hardware.num_actions(), 27);
        assert_eq!(Role::Scheduling.num_actions(), 9);
        assert_eq!(Role::Mapping.num_actions(), 9);
    }

    #[test]
    fn action_decode_covers_all_deltas() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..Role::Hardware.num_actions() {
            let d = Role::Hardware.decode_action(a);
            assert_eq!(d.len(), 3);
            assert!(d.iter().all(|x| (-1..=1).contains(x)));
            seen.insert(d);
        }
        assert_eq!(seen.len(), 27);
    }

    #[test]
    fn stay_action_is_identity() {
        let s = space();
        let d = ModelDims::default();
        let env = CoOptEnv::new(&s, d);
        let p = s.default_point();
        // Joint action with all digits = 1 (stay): index 1 + 3 + 9 = 13.
        let q = env.apply_action(&p, Role::Hardware, 13);
        assert_eq!(p, q);
        let q = env.apply_action(&p, Role::Mapping, 4); // 1 + 3
        assert_eq!(p, q);
    }

    #[test]
    fn actions_only_touch_owned_knobs() {
        let s = space();
        let env = CoOptEnv::new(&s, ModelDims::default());
        let p = s.default_point();
        for role in ROLES {
            let owned = s.agent_knobs(role.owner());
            for a in 0..role.num_actions() {
                let q = env.apply_action(&p, role, a);
                for k in 0..s.num_knobs() {
                    if !owned.contains(&k) {
                        assert_eq!(p.0[k], q.0[k], "{role:?} action {a} moved knob {k}");
                    }
                }
                assert!(s.contains(&q));
            }
        }
    }

    #[test]
    fn clamping_at_bounds() {
        let s = space();
        let env = CoOptEnv::new(&s, ModelDims::default());
        let mut p = s.default_point();
        for k in s.agent_knobs(KnobOwner::Mapping) {
            p.0[k] = 0;
        }
        // All-decrement action (digits 0,0): index 0.
        let q = env.apply_action(&p, Role::Mapping, 0);
        for k in s.agent_knobs(KnobOwner::Mapping) {
            assert_eq!(q.0[k], 0);
        }
    }

    #[test]
    fn frozen_hw_ignores_hw_agent() {
        let t = Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1);
        let s = ConfigSpace::for_task(&t, false);
        let env = CoOptEnv::new(&s, ModelDims::default());
        let p = s.default_point();
        for a in 0..27 {
            assert_eq!(env.apply_action(&p, Role::Hardware, a), p);
        }
    }

    #[test]
    fn obs_and_state_have_contract_dims() {
        let s = space();
        let d = ModelDims::default();
        let env = CoOptEnv::new(&s, d);
        let p = s.default_point();
        for role in ROLES {
            let obs = env.observe(&p, role, 0.5, 1.0, 0.3);
            assert_eq!(obs.len(), d.obs_dim);
            assert!(obs.iter().all(|x| x.is_finite()));
        }
        let gs = env.global_state(&p, 0.5, 1.0, 0.3);
        assert_eq!(gs.len(), d.gstate_dim);
        assert!(gs.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn observations_distinguish_roles() {
        let s = space();
        let env = CoOptEnv::new(&s, ModelDims::default());
        let p = s.default_point();
        let o1 = env.observe(&p, Role::Hardware, 0.0, 0.0, 0.0);
        let o2 = env.observe(&p, Role::Mapping, 0.0, 0.0, 0.0);
        assert_ne!(o1, o2);
    }

    #[test]
    fn penalty_zero_for_default_positive_for_huge() {
        let s = space();
        let env = CoOptEnv::new(&s, ModelDims::default());
        assert_eq!(env.penalty(&s.default_point()), 0.0);
        // Max out every hardware knob and tile: should violate something.
        let mut p = s.default_point();
        for (i, k) in s.knobs.iter().enumerate() {
            p.0[i] = k.len() - 1;
        }
        assert!(env.penalty(&p) > 0.0, "max config should be penalized");
    }

    #[test]
    fn reward_decreases_with_penalty() {
        let s = space();
        let env = CoOptEnv::new(&s, ModelDims::default());
        let good = s.default_point();
        let mut bad = s.default_point();
        for (i, k) in s.knobs.iter().enumerate() {
            bad.0[i] = k.len() - 1;
        }
        let r_good = env.reward(&good, 1.0, 1.0);
        let r_bad = env.reward(&bad, 1.0, 1.0);
        assert!(r_good > r_bad);
    }

    #[test]
    fn masks_match_action_counts() {
        let d = ModelDims::default();
        for role in ROLES {
            let m = role.action_mask(d.act_dim);
            assert_eq!(m.len(), d.act_dim);
            let legal: usize = m.iter().filter(|&&x| x > 0.0).count();
            assert_eq!(legal, role.num_actions());
        }
    }

    #[test]
    fn memory_overflow_detects_big_tiles() {
        let s = space();
        let env = CoOptEnv::new(&s, ModelDims::default());
        let mut rng = Pcg32::seeded(10);
        let mut any_overflow = false;
        for _ in 0..200 {
            let p = s.random_point(&mut rng);
            if env.memory_overflow_ratio(&p) > 0.0 {
                any_overflow = true;
            }
        }
        assert!(any_overflow);
    }
}
