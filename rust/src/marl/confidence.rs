//! Confidence Sampling (§3.3, Algorithm 2).
//!
//! Replaces uniform (AutoTVM) / adaptive (CHAMELEON) sampling: the critic
//! scores every explored configuration, a softmax over the scores drives
//! probability-guided selection, a dynamic (median) threshold separates
//! high-confidence picks, and low-confidence picks are *synthesized away* —
//! replaced by combining each knob's most frequent setting among the
//! sampled configurations.

use crate::space::{ConfigSpace, PointConfig};
use crate::util::rng::Pcg32;
use crate::util::stats::{median, softmax};
use std::collections::HashSet;

/// Outcome of one Confidence Sampling pass.
#[derive(Debug, Clone)]
pub struct CsOutcome {
    /// Final configurations to measure (≤ n_configs, distinct).
    pub selected: Vec<PointConfig>,
    /// How many of the selected came from synthesis (line 6-7).
    pub synthesized: usize,
    /// The dynamic threshold used (median of value predictions).
    pub threshold: f64,
}

/// Algorithm 2: `ConfidenceSampling(S_Θ, value_network, N_configs)`.
///
/// `values[i]` is the critic's prediction for `candidates[i]`.
pub fn confidence_sampling(
    space: &ConfigSpace,
    candidates: &[PointConfig],
    values: &[f64],
    n_configs: usize,
    rng: &mut Pcg32,
) -> CsOutcome {
    assert_eq!(candidates.len(), values.len());
    if candidates.is_empty() || n_configs == 0 {
        return CsOutcome { selected: Vec::new(), synthesized: 0, threshold: 0.0 };
    }

    // Line 2-3: values -> probability distribution. Raw critic outputs
    // have data-dependent scale (often a fraction of a unit across the
    // whole candidate set), which would make the softmax near-uniform and
    // neuter the probability-guided selection; standardize to unit
    // variance and apply a fixed sharpness so "high-confidence regions"
    // actually dominate the draw.
    const SHARPNESS: f64 = 3.0;
    let mean = crate::util::stats::mean(values);
    let std = crate::util::stats::std_dev(values).max(1e-9);
    let scaled: Vec<f64> = values.iter().map(|v| SHARPNESS * (v - mean) / std).collect();
    let probs = softmax(&scaled);

    // Line 4 (Algorithm 2 lines 9-10): sample N_configs indices from the
    // distribution *with replacement*; duplicate draws collapse, so the
    // more concentrated the critic's confidence, the fewer distinct
    // configurations survive to be measured — this shrinkage is the
    // measurement reduction Fig. 4 shows.
    let n_draw = n_configs.min(candidates.len());
    let mut selected_idx: Vec<usize> = Vec::with_capacity(n_draw);
    let mut drawn: HashSet<usize> = HashSet::with_capacity(n_draw);
    for _ in 0..n_draw {
        let i = rng.gen_weighted(&probs);
        if drawn.insert(i) {
            selected_idx.push(i);
        }
    }

    // Line 5: dynamic threshold = median of all value predictions.
    let threshold = median(values);

    // Line 6: split by confidence.
    let (high, low): (Vec<usize>, Vec<usize>) =
        selected_idx.iter().partition(|&&i| values[i] > threshold);

    // Line 6-7: synthesize replacements for low-confidence picks by
    // combining each knob's modal value across the *sampled* set. The
    // synthesized configurations are variations of one modal point
    // (single-knob ±1 steps), and duplicates simply collapse — so the
    // final batch is typically *smaller* than N_configs. That shrinkage is
    // the measurement reduction Fig. 4 shows: low-confidence picks are
    // discarded, not replaced one-for-one.
    let mut out: Vec<PointConfig> = high.iter().map(|&i| candidates[i].clone()).collect();
    let mut seen: HashSet<usize> = out.iter().map(|p| space.flat_index(p)).collect();
    let modal = modal_point(space, &selected_idx.iter().map(|&i| &candidates[i]).collect::<Vec<_>>());
    let mut synthesized = 0usize;
    let synth_cap = low.len().min((n_configs / 8).max(1));
    let mut variants: Vec<PointConfig> = vec![modal.clone()];
    for k in 0..space.num_knobs() {
        // Frozen hardware knobs must stay at their (modal = default)
        // setting: synthesis is the one path that hand-rolls knob steps
        // instead of going through `space.neighbours`, and a software-only
        // framework must never be handed a varied hardware knob.
        if space.knob_frozen(k) {
            continue;
        }
        for delta in [-1i64, 1] {
            let arity = space.knobs[k].len() as i64;
            let v = (modal.0[k] as i64 + delta).clamp(0, arity - 1) as usize;
            if v != modal.0[k] {
                let mut q = modal.clone();
                q.0[k] = v;
                variants.push(q);
            }
        }
    }
    rng.shuffle(&mut variants[1..]);
    for candidate in variants {
        if synthesized >= synth_cap {
            break;
        }
        let key = space.flat_index(&candidate);
        if seen.insert(key) {
            out.push(candidate);
            synthesized += 1;
        }
    }

    CsOutcome { selected: out, synthesized, threshold }
}

/// Per-knob mode across a set of points.
fn modal_point(space: &ConfigSpace, points: &[&PointConfig]) -> PointConfig {
    assert!(!points.is_empty());
    let mut out = Vec::with_capacity(space.num_knobs());
    for k in 0..space.num_knobs() {
        let arity = space.knobs[k].len();
        let mut counts = vec![0usize; arity];
        for p in points {
            counts[p.0[k]] += 1;
        }
        let best = counts
            .iter()
            .enumerate()
            .max_by_key(|(_, &c)| c)
            .map(|(i, _)| i)
            .unwrap_or(0);
        out.push(best);
    }
    PointConfig(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1), true)
    }

    fn random_candidates(s: &ConfigSpace, n: usize, seed: u64) -> Vec<PointConfig> {
        let mut rng = Pcg32::seeded(seed);
        let mut seen = HashSet::new();
        let mut out = Vec::new();
        while out.len() < n {
            let p = s.random_point(&mut rng);
            if seen.insert(s.flat_index(&p)) {
                out.push(p);
            }
        }
        out
    }

    #[test]
    fn selects_at_most_n_distinct() {
        let s = space();
        let cands = random_candidates(&s, 200, 1);
        let values: Vec<f64> = (0..200).map(|i| (i % 17) as f64 / 17.0).collect();
        let mut rng = Pcg32::seeded(2);
        let out = confidence_sampling(&s, &cands, &values, 64, &mut rng);
        assert!(out.selected.len() <= 64);
        let keys: HashSet<usize> = out.selected.iter().map(|p| s.flat_index(p)).collect();
        assert_eq!(keys.len(), out.selected.len());
    }

    #[test]
    fn synthesis_respects_frozen_hardware_knobs() {
        // All-low confidence forces the synthesis path; in a frozen space
        // the synthesized variants must never step a hardware knob.
        let t = Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1);
        let s = ConfigSpace::for_task(&t, false);
        let cands = random_candidates(&s, 100, 9);
        let values = vec![0.0f64; cands.len()];
        for seed in 0..20u64 {
            let mut rng = Pcg32::seeded(seed);
            let out = confidence_sampling(&s, &cands, &values, 32, &mut rng);
            for p in &out.selected {
                let (hw, _) = s.decode(p);
                assert_eq!(
                    (hw.batch, hw.block_in, hw.block_out),
                    (1, 16, 16),
                    "synthesis varied a frozen hardware knob"
                );
            }
        }
    }

    #[test]
    fn prefers_high_value_candidates() {
        let s = space();
        let cands = random_candidates(&s, 300, 3);
        // First 30 candidates have much higher value.
        let values: Vec<f64> =
            (0..300).map(|i| if i < 30 { 10.0 } else { 0.0 }).collect();
        let high_keys: HashSet<usize> =
            cands[..30].iter().map(|p| s.flat_index(p)).collect();
        let mut rng = Pcg32::seeded(4);
        let out = confidence_sampling(&s, &cands, &values, 30, &mut rng);
        let hits = out
            .selected
            .iter()
            .filter(|p| high_keys.contains(&s.flat_index(p)))
            .count();
        assert!(
            hits >= 20,
            "only {hits}/30 selections were high-value candidates"
        );
    }

    #[test]
    fn low_confidence_replaced_by_synthesis() {
        // An uninformative critic (all values equal): nothing clears the
        // median threshold, so the output comes purely from synthesis —
        // bounded by the synthesis cap.
        let s = space();
        let cands = random_candidates(&s, 100, 5);
        let values = vec![0.5f64; 100];
        let mut rng = Pcg32::seeded(6);
        let out = confidence_sampling(&s, &cands, &values, 50, &mut rng);
        assert!(out.synthesized > 0, "expected synthesized configs");
        assert_eq!(out.selected.len(), out.synthesized);
        assert!(out.synthesized <= 50 / 8 + 1);
        assert!((out.threshold - 0.5).abs() < 1e-9);
    }

    #[test]
    fn with_replacement_draws_collapse() {
        // Concentrated values -> far fewer distinct selections than asked.
        let s = space();
        let cands = random_candidates(&s, 300, 11);
        let values: Vec<f64> =
            (0..300).map(|i| if i < 20 { 5.0 } else { 0.0 }).collect();
        let mut rng = Pcg32::seeded(12);
        let out = confidence_sampling(&s, &cands, &values, 64, &mut rng);
        assert!(
            out.selected.len() < 40,
            "peaked confidence should collapse the batch, got {}",
            out.selected.len()
        );
    }

    #[test]
    fn empty_inputs_safe() {
        let s = space();
        let mut rng = Pcg32::seeded(7);
        let out = confidence_sampling(&s, &[], &[], 64, &mut rng);
        assert!(out.selected.is_empty());
    }

    #[test]
    fn modal_point_is_knobwise_mode() {
        let s = space();
        let mut a = s.default_point();
        let b = s.default_point();
        let mut c = s.default_point();
        a.0[0] = 1;
        c.0[1] = 2;
        // knob0: [1, d, d] -> mode = default; knob1: [d, d, 2] -> default.
        let m = modal_point(&s, &[&a, &b, &c]);
        assert_eq!(m.0[0], s.default_point().0[0]);
        assert_eq!(m.0[1], s.default_point().0[1]);
    }

    #[test]
    fn reduces_measurements_vs_candidate_count() {
        // The whole point of CS (Fig 4): far fewer configs measured than
        // explored.
        let s = space();
        let cands = random_candidates(&s, 500, 8);
        let values: Vec<f64> = (0..500).map(|i| ((i * 37) % 100) as f64).collect();
        let mut rng = Pcg32::seeded(9);
        let out = confidence_sampling(&s, &cands, &values, 64, &mut rng);
        assert!(out.selected.len() <= 64);
        assert!(out.selected.len() >= 16, "CS should still fill most of the batch");
    }
}
