//! The paper's core contribution: MAPPO-based CTDE multi-agent exploration
//! (Algorithm 1) over the hardware/software co-design space, plus the
//! Confidence Sampling measurement filter (Algorithm 2).
//!
//! Three actors (hardware / scheduling / mapping, Table 1) share a
//! centralized critic during training and act independently during
//! execution. All network compute flows through [`backend::Backend`]:
//! AOT-compiled HLO on PJRT in production, native mirror in tests.

pub mod backend;
pub mod confidence;
pub mod env;
pub mod exploration;
pub mod mappo;

pub use backend::Backend;
pub use confidence::{confidence_sampling, CsOutcome};
pub use env::{CoOptEnv, Role, ROLES};
pub use exploration::{ExploreParams, MarlExplorer, Visited};
pub use mappo::Mappo;
pub mod strategy;
