//! MAPPO state and updates (CTDE): three decentralized actors, one
//! centralized critic, trained with PPO-clip (Eqs. 1–3) through the
//! [`Backend`] (AOT/XLA or native).

use super::backend::Backend;
use super::env::{Role, ROLES};
use crate::ml::Mlp;
use crate::runtime::ModelDims;
use crate::util::rng::Pcg32;

/// One actor: policy parameters + Adam state + action mask.
#[derive(Debug, Clone)]
pub struct Actor {
    pub role: Role,
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    pub mask: Vec<f32>,
}

/// Centralized critic: value parameters + Adam state.
#[derive(Debug, Clone)]
pub struct Critic {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
}

/// Full MAPPO learner state.
pub struct Mappo {
    pub dims: ModelDims,
    pub actors: Vec<Actor>,
    pub critic: Critic,
    pub gamma: f32,
    pub lam: f32,
}

/// One agent's view of one transition.
#[derive(Debug, Clone)]
pub struct AgentTransition {
    pub obs: Vec<f32>,
    pub action: usize,
    pub logp: f32,
}

/// One environment transition: per-agent records + shared reward/value.
#[derive(Debug, Clone)]
pub struct Transition {
    pub per_agent: Vec<AgentTransition>,
    pub gstate: Vec<f32>,
    pub reward: f32,
    pub value: f32,
}

/// Training statistics of one update round.
#[derive(Debug, Clone, Default)]
pub struct UpdateStats {
    pub policy_loss: f32,
    pub value_loss: f32,
    pub entropy: f32,
    pub clip_frac: f32,
    pub minibatches: usize,
}

impl Mappo {
    /// Fresh learner with randomly initialized networks.
    pub fn new(dims: ModelDims, gamma: f32, lam: f32, rng: &mut Pcg32) -> Mappo {
        let actors = ROLES
            .iter()
            .map(|&role| {
                let mlp = Mlp::policy(dims.obs_dim, dims.act_dim, rng);
                Actor {
                    role,
                    params: mlp.flatten(),
                    m: vec![0.0; dims.p_policy],
                    v: vec![0.0; dims.p_policy],
                    t: 0.0,
                    mask: role.action_mask(dims.act_dim),
                }
            })
            .collect();
        let vmlp = Mlp::value(dims.gstate_dim, rng);
        let critic = Critic {
            params: vmlp.flatten(),
            m: vec![0.0; dims.p_value],
            v: vec![0.0; dims.p_value],
            t: 0.0,
        };
        Mappo { dims, actors, critic, gamma, lam }
    }

    pub fn actor(&self, role: Role) -> &Actor {
        &self.actors[role.index()]
    }

    /// Batched masked log-probs for up to `b_pol` observations of one agent.
    /// `obs_rows` shorter than b_pol are zero-padded; only the first
    /// `obs_rows.len()` output rows are returned.
    pub fn policy_logp(
        &self,
        backend: &Backend,
        role: Role,
        obs_rows: &[Vec<f32>],
    ) -> Vec<Vec<f32>> {
        let d = self.dims;
        assert!(obs_rows.len() <= d.b_pol, "population exceeds b_pol");
        let mut flat = vec![0.0f32; d.b_pol * d.obs_dim];
        for (r, row) in obs_rows.iter().enumerate() {
            flat[r * d.obs_dim..(r + 1) * d.obs_dim].copy_from_slice(row);
        }
        let actor = self.actor(role);
        let out = backend.policy_forward(&actor.params, &flat, &actor.mask);
        obs_rows
            .iter()
            .enumerate()
            .map(|(r, _)| out[r * d.act_dim..(r + 1) * d.act_dim].to_vec())
            .collect()
    }

    /// Batched critic values for up to `b_pol` global states.
    pub fn values(&self, backend: &Backend, states: &[Vec<f32>]) -> Vec<f32> {
        let d = self.dims;
        assert!(states.len() <= d.b_pol);
        let mut flat = vec![0.0f32; d.b_pol * d.gstate_dim];
        for (r, row) in states.iter().enumerate() {
            flat[r * d.gstate_dim..(r + 1) * d.gstate_dim].copy_from_slice(row);
        }
        let out = backend.value_forward(&self.critic.params, &flat);
        out[..states.len()].to_vec()
    }

    /// GAE over one trajectory (padded to the artifact horizon).
    pub fn gae(
        &self,
        backend: &Backend,
        rewards: &[f32],
        values: &[f32],
        bootstrap: f32,
    ) -> (Vec<f32>, Vec<f32>) {
        let d = self.dims;
        let n = rewards.len();
        assert!(n <= d.t_gae, "trajectory longer than t_gae");
        let mut r_pad = rewards.to_vec();
        let mut v_pad = values.to_vec();
        r_pad.resize(d.t_gae, 0.0);
        v_pad.resize(d.t_gae, 0.0);
        // Padding correctness: set v[n..] = 0 and r[n..] = 0 with bootstrap
        // applied at the true horizon by folding it into r_pad[n-1].
        if n > 0 && n < d.t_gae {
            r_pad[n - 1] += self.gamma * bootstrap;
        }
        let boot = if n == d.t_gae { bootstrap } else { 0.0 };
        let (adv, ret) = backend.gae(&r_pad, &v_pad, boot, self.gamma, self.lam);
        (adv[..n].to_vec(), ret[..n].to_vec())
    }

    /// One PPO update over collected trajectories: shuffled minibatches of
    /// b_train for each actor and the critic, `epochs` passes.
    pub fn update(
        &mut self,
        backend: &Backend,
        trajectories: &[Vec<Transition>],
        epochs: usize,
        rng: &mut Pcg32,
    ) -> UpdateStats {
        let d = self.dims;
        // Flatten transitions and compute advantages per trajectory.
        let mut obs: Vec<Vec<Vec<f32>>> = vec![Vec::new(); 3];
        let mut acts: Vec<Vec<i32>> = vec![Vec::new(); 3];
        let mut logps: Vec<Vec<f32>> = vec![Vec::new(); 3];
        let mut advs: Vec<f32> = Vec::new();
        let mut rets: Vec<f32> = Vec::new();
        let mut gstates: Vec<Vec<f32>> = Vec::new();

        for traj in trajectories {
            if traj.is_empty() {
                continue;
            }
            let rewards: Vec<f32> = traj.iter().map(|t| t.reward).collect();
            let values: Vec<f32> = traj.iter().map(|t| t.value).collect();
            let (adv, ret) = self.gae(backend, &rewards, &values, 0.0);
            for (i, tr) in traj.iter().enumerate() {
                for role in ROLES {
                    let a = &tr.per_agent[role.index()];
                    obs[role.index()].push(a.obs.clone());
                    acts[role.index()].push(a.action as i32);
                    logps[role.index()].push(a.logp);
                }
                advs.push(adv[i]);
                rets.push(ret[i]);
                gstates.push(tr.gstate.clone());
            }
        }
        let n = advs.len();
        if n == 0 {
            return UpdateStats::default();
        }
        let mut advs_n = advs.clone();
        crate::ml::ppo::normalize_advantages(&mut advs_n);

        let mut stats = UpdateStats::default();
        let mut order: Vec<usize> = (0..n).collect();
        for _ in 0..epochs {
            rng.shuffle(&mut order);
            for chunk in order.chunks(d.b_train) {
                // Policy updates per agent.
                for role in ROLES {
                    let ai = role.index();
                    let mut obs_flat = vec![0.0f32; d.b_train * d.obs_dim];
                    let mut a_pad = vec![0i32; d.b_train];
                    let mut lp_pad = vec![0.0f32; d.b_train];
                    let mut adv_pad = vec![0.0f32; d.b_train];
                    let mut w = vec![0.0f32; d.b_train];
                    for (r, &i) in chunk.iter().enumerate() {
                        obs_flat[r * d.obs_dim..(r + 1) * d.obs_dim]
                            .copy_from_slice(&obs[ai][i]);
                        a_pad[r] = acts[ai][i];
                        lp_pad[r] = logps[ai][i];
                        adv_pad[r] = advs_n[i];
                        w[r] = 1.0;
                    }
                    let actor = &mut self.actors[ai];
                    let out = backend.policy_train(
                        &actor.params,
                        &actor.m,
                        &actor.v,
                        actor.t,
                        &obs_flat,
                        &actor.mask,
                        &a_pad,
                        &lp_pad,
                        &adv_pad,
                        &w,
                    );
                    actor.params = out.params;
                    actor.m = out.m;
                    actor.v = out.v;
                    actor.t = out.t;
                    stats.policy_loss += out.loss;
                    stats.entropy += out.entropy;
                    stats.clip_frac += out.clip_frac;
                }
                // Critic update.
                let mut st_flat = vec![0.0f32; d.b_train * d.gstate_dim];
                let mut ret_pad = vec![0.0f32; d.b_train];
                let mut w = vec![0.0f32; d.b_train];
                for (r, &i) in chunk.iter().enumerate() {
                    st_flat[r * d.gstate_dim..(r + 1) * d.gstate_dim]
                        .copy_from_slice(&gstates[i]);
                    ret_pad[r] = rets[i];
                    w[r] = 1.0;
                }
                let out = backend.value_train(
                    &self.critic.params,
                    &self.critic.m,
                    &self.critic.v,
                    self.critic.t,
                    &st_flat,
                    &ret_pad,
                    &w,
                );
                self.critic.params = out.params;
                self.critic.m = out.m;
                self.critic.v = out.v;
                self.critic.t = out.t;
                stats.value_loss += out.loss;
                stats.minibatches += 1;
            }
        }
        let mb = stats.minibatches.max(1) as f32;
        stats.policy_loss /= mb * 3.0;
        stats.entropy /= mb * 3.0;
        stats.clip_frac /= mb * 3.0;
        stats.value_loss /= mb;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ModelDims {
        ModelDims::default()
    }

    fn mappo_and_backend() -> (Mappo, Backend) {
        let mut rng = Pcg32::seeded(5);
        let d = dims();
        (Mappo::new(d, 0.99, 0.95, &mut rng), Backend::native(d))
    }

    #[test]
    fn three_actors_one_critic() {
        let (m, _) = mappo_and_backend();
        assert_eq!(m.actors.len(), 3);
        assert_eq!(m.actors[0].params.len(), dims().p_policy);
        assert_eq!(m.critic.params.len(), dims().p_value);
        // Masks differ between hardware (27) and software (9) agents.
        let hw_legal: usize = m.actor(Role::Hardware).mask.iter().filter(|&&x| x > 0.0).count();
        let sw_legal: usize = m.actor(Role::Mapping).mask.iter().filter(|&&x| x > 0.0).count();
        assert_eq!((hw_legal, sw_legal), (27, 9));
    }

    #[test]
    fn policy_logp_respects_masks() {
        let (m, b) = mappo_and_backend();
        let obs = vec![vec![0.1f32; dims().obs_dim]; 5];
        let rows = m.policy_logp(&b, Role::Scheduling, &obs);
        assert_eq!(rows.len(), 5);
        for row in rows {
            assert_eq!(row.len(), dims().act_dim);
            for (j, &lp) in row.iter().enumerate() {
                if j >= 9 {
                    assert!(lp < -1e20, "masked action {j} has logp {lp}");
                }
            }
            let total: f32 = row.iter().take(9).map(|x| x.exp()).sum();
            assert!((total - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn gae_padding_preserves_short_trajectories() {
        let (m, b) = mappo_and_backend();
        let rewards = vec![1.0f32, 0.5, -0.2, 2.0];
        let values = vec![0.1f32, 0.2, 0.3, 0.4];
        let (adv, ret) = m.gae(&b, &rewards, &values, 0.7);
        // Native reference on the unpadded trajectory.
        let (adv_ref, ret_ref) = crate::ml::ppo::gae(&rewards, &values, 0.7, 0.99, 0.95);
        assert_eq!(adv.len(), 4);
        for i in 0..4 {
            assert!(
                (adv[i] - adv_ref[i]).abs() < 1e-4,
                "adv[{i}] {} vs {}",
                adv[i],
                adv_ref[i]
            );
            assert!((ret[i] - ret_ref[i]).abs() < 1e-4);
        }
    }

    #[test]
    fn update_runs_and_changes_params() {
        let (mut m, b) = mappo_and_backend();
        let mut rng = Pcg32::seeded(9);
        let d = dims();
        // Build a synthetic trajectory batch.
        let mut trajs = Vec::new();
        for _ in 0..4 {
            let mut traj = Vec::new();
            for s in 0..10 {
                let per_agent = ROLES
                    .iter()
                    .map(|&role| AgentTransition {
                        obs: (0..d.obs_dim).map(|_| rng.gen_f32()).collect(),
                        action: rng.gen_range(role.num_actions()),
                        logp: -1.5,
                    })
                    .collect();
                traj.push(Transition {
                    per_agent,
                    gstate: (0..d.gstate_dim).map(|_| rng.gen_f32()).collect(),
                    reward: if s == 9 { 1.0 } else { 0.0 },
                    value: 0.0,
                });
            }
            trajs.push(traj);
        }
        let before = m.actors[0].params.clone();
        let critic_before = m.critic.params.clone();
        let stats = m.update(&b, &trajs, 2, &mut rng);
        assert!(stats.minibatches > 0);
        assert_ne!(m.actors[0].params, before);
        assert_ne!(m.critic.params, critic_before);
        assert!(m.actors[0].t > 0.0);
    }

    #[test]
    fn empty_update_is_noop() {
        let (mut m, b) = mappo_and_backend();
        let mut rng = Pcg32::seeded(2);
        let stats = m.update(&b, &[], 2, &mut rng);
        assert_eq!(stats.minibatches, 0);
    }
}
