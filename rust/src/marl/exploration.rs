//! The MARL Exploration module (Fig. 3, Algorithm 1).
//!
//! Runs CTDE episodes over a *population* of candidate configurations: at
//! every search step each agent observes its view of every candidate,
//! independently samples a knob-step action from its policy, and the three
//! actions jointly move the candidate through the design space. Rewards
//! come from the surrogate cost model (hardware measurements are reserved
//! for the configurations Confidence Sampling selects), shaped by the
//! constraint penalty of Eq. 4.

use super::backend::Backend;
use super::env::{CoOptEnv, ROLES};
use super::mappo::{AgentTransition, Mappo, Transition, UpdateStats};
use crate::space::PointConfig;
use crate::util::rng::Pcg32;
use crate::util::stats::argmax;
use std::collections::HashMap;

/// Exploration hyper-parameters (Table 4: episode_rl, step_rl).
#[derive(Debug, Clone, Copy)]
pub struct ExploreParams {
    /// Episodes per exploration round.
    pub episodes: usize,
    /// Search steps per episode.
    pub steps: usize,
    /// Candidate configurations evolved in parallel (≤ b_pol).
    pub population: usize,
    /// PPO epochs per episode's data.
    pub ppo_epochs: usize,
}

impl Default for ExploreParams {
    /// Scaled-down defaults for one exploration *round* (the paper's full
    /// budget, episode_rl=128 × step_rl=500, is spread over
    /// iteration_opt=16 rounds; per round that is 8 episodes, and we cap
    /// steps so a round stays sub-second on this testbed — configurable up
    /// to the paper values via configs/arco.json).
    fn default() -> Self {
        ExploreParams { episodes: 8, steps: 24, population: 32, ppo_epochs: 2 }
    }
}

/// A visited configuration with its latest surrogate score.
#[derive(Debug, Clone)]
pub struct Visited {
    pub point: PointConfig,
    pub surrogate: f64,
}

/// The exploration module: owns the MAPPO learner and episode machinery.
pub struct MarlExplorer {
    pub mappo: Mappo,
    pub params: ExploreParams,
    pub rng: Pcg32,
    /// Best *measured* fitness seen so far (reward normalizer).
    pub best_fitness: f64,
    pub last_stats: UpdateStats,
}

impl MarlExplorer {
    pub fn new(mappo: Mappo, params: ExploreParams, seed: u64) -> MarlExplorer {
        assert!(params.population <= mappo.dims.b_pol, "population exceeds b_pol");
        MarlExplorer {
            mappo,
            params,
            rng: Pcg32::seeded(seed),
            best_fitness: 0.0,
            last_stats: UpdateStats::default(),
        }
    }

    /// Record measured fitness (keeps the reward normalizer current).
    pub fn note_measured_fitness(&mut self, fitness: f64) {
        if fitness > self.best_fitness {
            self.best_fitness = fitness;
        }
    }

    /// One exploration round (Algorithm 1): returns the distinct visited
    /// configurations S_Θ scored by the surrogate.
    pub fn explore(
        &mut self,
        env: &CoOptEnv<'_>,
        backend: &Backend,
        surrogate: &dyn Fn(&PointConfig) -> f64,
        seeds: &[PointConfig],
    ) -> Vec<Visited> {
        let p = self.params;
        let mut visited: HashMap<usize, Visited> = HashMap::new();

        for _ep in 0..p.episodes {
            // Line 3: initialize S_Θ — seed points (best known) + random.
            let mut pop: Vec<PointConfig> = Vec::with_capacity(p.population);
            for s in seeds.iter().take(p.population / 2) {
                pop.push(s.clone());
            }
            while pop.len() < p.population {
                pop.push(env.space.random_point(&mut self.rng));
            }

            let mut trajs: Vec<Vec<Transition>> = vec![Vec::new(); p.population];
            let mut last_reward = vec![0.0f32; p.population];
            let norm = self.best_fitness.max(1e-12);

            for step in 0..p.steps {
                let step_frac = step as f32 / p.steps.max(1) as f32;

                // Critic values on the global states (lines 6, 9).
                let gstates: Vec<Vec<f32>> = pop
                    .iter()
                    .zip(&last_reward)
                    .map(|(pt, &lr)| {
                        env.global_state(pt, lr, (surrogate(pt) / norm) as f32, step_frac)
                    })
                    .collect();
                let values = self.mappo.values(backend, &gstates);

                // Each agent observes and independently picks actions
                // (lines 5-8, decentralized execution).
                let mut per_agent_all: Vec<Vec<AgentTransition>> =
                    (0..p.population).map(|_| Vec::with_capacity(3)).collect();
                let mut next_pop = pop.clone();
                for role in ROLES {
                    let obs_rows: Vec<Vec<f32>> = next_pop
                        .iter()
                        .zip(&last_reward)
                        .map(|(pt, &lr)| {
                            env.observe(pt, role, lr, (surrogate(pt) / norm) as f32, step_frac)
                        })
                        .collect();
                    let logp_rows = self.mappo.policy_logp(backend, role, &obs_rows);
                    for i in 0..p.population {
                        let probs: Vec<f64> = logp_rows[i]
                            .iter()
                            .map(|&lp| if lp > -1e20 { (lp as f64).exp() } else { 0.0 })
                            .collect();
                        let action = self.rng.gen_weighted(&probs);
                        let logp = logp_rows[i][action];
                        per_agent_all[i].push(AgentTransition {
                            obs: obs_rows[i].clone(),
                            action,
                            logp,
                        });
                        next_pop[i] = env.apply_action(&next_pop[i], role, action);
                    }
                }

                // Line 11: evaluate new configurations with the cost model.
                for i in 0..p.population {
                    let s = surrogate(&next_pop[i]);
                    let reward = env.reward(&next_pop[i], s, norm);
                    last_reward[i] = reward;
                    trajs[i].push(Transition {
                        per_agent: std::mem::take(&mut per_agent_all[i]),
                        gstate: gstates[i].clone(),
                        reward,
                        value: values[i],
                    });
                    let key = env.space.flat_index(&next_pop[i]);
                    let entry = visited.entry(key).or_insert_with(|| Visited {
                        point: next_pop[i].clone(),
                        surrogate: s,
                    });
                    entry.surrogate = s;
                }
                pop = next_pop;
            }

            // Lines 12-13: centralized critic + per-agent policy updates.
            self.last_stats = self.mappo.update(backend, &trajs, p.ppo_epochs, &mut self.rng);
        }

        // Deterministic order (flat index): HashMap iteration varies per
        // process, and Confidence Sampling downstream is order-sensitive —
        // two processes must plan identically from identical observations.
        let mut v: Vec<(usize, Visited)> = visited.into_iter().collect();
        v.sort_by_key(|&(k, _)| k);
        v.into_iter().map(|(_, vis)| vis).collect()
    }

    /// Critic scores for a candidate set (used by Confidence Sampling).
    pub fn critic_scores(
        &self,
        env: &CoOptEnv<'_>,
        backend: &Backend,
        points: &[PointConfig],
    ) -> Vec<f64> {
        let norm = self.best_fitness.max(1e-12);
        let mut out = Vec::with_capacity(points.len());
        for chunk in points.chunks(self.mappo.dims.b_pol) {
            let gstates: Vec<Vec<f32>> = chunk
                .iter()
                .map(|pt| env.global_state(pt, 0.0, (1.0 / norm.max(1.0)) as f32, 1.0))
                .collect();
            let vals = self.mappo.values(backend, &gstates);
            out.extend(vals.into_iter().map(|v| v as f64));
        }
        out
    }

    /// Best visited point by surrogate score.
    pub fn best_of(visited: &[Visited]) -> Option<&Visited> {
        let scores: Vec<f64> = visited.iter().map(|v| v.surrogate).collect();
        argmax(&scores).map(|i| &visited[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ModelDims;
    use crate::workload::Conv2dTask;

    fn setup() -> (crate::space::ConfigSpace, Backend, MarlExplorer) {
        let task = Conv2dTask::new(1, 64, 28, 28, 64, 3, 3, 1, 1);
        let space = crate::space::ConfigSpace::for_task(&task, true);
        let dims = ModelDims::default();
        let backend = Backend::native(dims);
        let mut rng = Pcg32::seeded(7);
        let mappo = Mappo::new(dims, 0.99, 0.95, &mut rng);
        let explorer = MarlExplorer::new(
            mappo,
            ExploreParams { episodes: 2, steps: 6, population: 8, ppo_epochs: 1 },
            42,
        );
        (space, backend, explorer)
    }

    #[test]
    fn explore_returns_distinct_configs() {
        let (space, backend, mut ex) = setup();
        let env = CoOptEnv::new(&space, ModelDims::default());
        let visited = ex.explore(&env, &backend, &|_| 0.5, &[]);
        assert!(!visited.is_empty());
        let keys: std::collections::HashSet<usize> =
            visited.iter().map(|v| space.flat_index(&v.point)).collect();
        assert_eq!(keys.len(), visited.len(), "visited set must be distinct");
        for v in &visited {
            assert!(space.contains(&v.point));
        }
    }

    #[test]
    fn explore_is_deterministic_for_seed() {
        let run = || {
            let (space, backend, mut ex) = setup();
            let env = CoOptEnv::new(&space, ModelDims::default());
            let mut visited = ex.explore(&env, &backend, &|p| {
                // Deterministic surrogate: prefer low flat index.
                1.0 / (1.0 + space.flat_index(p) as f64)
            }, &[]);
            visited.sort_by_key(|v| space.flat_index(&v.point));
            visited.iter().map(|v| space.flat_index(&v.point)).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn updates_happen_during_explore() {
        let (space, backend, mut ex) = setup();
        let env = CoOptEnv::new(&space, ModelDims::default());
        let before = ex.mappo.actors[0].params.clone();
        let _ = ex.explore(&env, &backend, &|_| 1.0, &[]);
        assert_ne!(ex.mappo.actors[0].params, before, "policies should train");
        assert!(ex.last_stats.minibatches > 0);
    }

    #[test]
    fn critic_scores_cover_all_points() {
        let (space, backend, ex) = setup();
        let env = CoOptEnv::new(&space, ModelDims::default());
        let mut rng = Pcg32::seeded(3);
        // More points than one b_pol batch to exercise chunking.
        let pts: Vec<PointConfig> =
            (0..150).map(|_| space.random_point(&mut rng)).collect();
        let scores = ex.critic_scores(&env, &backend, &pts);
        assert_eq!(scores.len(), pts.len());
        assert!(scores.iter().all(|s| s.is_finite()));
    }

    #[test]
    fn seeds_are_included_in_population() {
        let (space, backend, mut ex) = setup();
        let env = CoOptEnv::new(&space, ModelDims::default());
        let seed_pt = space.default_point();
        let visited = ex.explore(&env, &backend, &|_| 0.1, &[seed_pt.clone()]);
        // The seed (or a neighbour reached from it) must appear; at minimum
        // exploration should have visited many points.
        assert!(visited.len() >= 8);
    }
}
