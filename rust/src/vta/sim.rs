//! Cycle-level discrete-event simulator for the VTA++ pipeline.
//!
//! Three units (LOAD / COMPUTE / STORE) execute their instructions in
//! program order, synchronizing only through dependence-token queues, and
//! the two DMA engines contend for one shared DRAM bus. Latency model:
//!
//! - `LOAD/STORE bytes`: `dma_latency + ceil(bytes / dram_bytes_per_cycle)`,
//!   serialized on the shared bus.
//! - `GEMM uops`: one micro-op per cycle once the systolic array is full,
//!   plus a fixed pipeline-fill.
//! - `ALU elems`: `ceil(elems / alu_lanes)` plus fill.
//!
//! The simulator is deterministic and pure — "hardware measurement" in the
//! tuners is a call to [`simulate`], whose reported cycle count converts to
//! seconds at the configured clock. This mirrors how the paper evaluates on
//! the VTA++ *simulator* rather than silicon.

use super::config::VtaConfig;
use super::isa::{stream_stats, Instr, Op, Unit};
use std::collections::VecDeque;

/// Version of the cycle model's latency equations. Bump this whenever a
/// change to the simulator (or to the lowering it measures) can alter
/// reported cycle counts: measurement journals and remote-measurement
/// handshakes embed it in their fingerprint so numbers from different
/// models are never silently mixed.
pub const CYCLE_MODEL_VERSION: u32 = 1;

/// Fixed pipeline-fill overhead of a GEMM instruction (array depth).
pub const GEMM_PIPELINE_FILL: u64 = 16;
/// Fixed start overhead of an ALU instruction.
pub const ALU_PIPELINE_FILL: u64 = 4;

/// Simulation outcome.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SimReport {
    /// Total makespan in cycles.
    pub cycles: u64,
    /// Busy cycles per unit.
    pub load_busy: u64,
    pub compute_busy: u64,
    pub store_busy: u64,
    /// Cycles the compute unit spent waiting on tokens (starvation).
    pub compute_stall: u64,
    /// GEMM micro-ops executed.
    pub gemm_uops: u64,
    /// Bytes moved over the DRAM bus.
    pub dram_bytes: u64,
}

impl SimReport {
    /// Wall-clock seconds at the configured core frequency.
    pub fn seconds(&self, hw: &VtaConfig) -> f64 {
        self.cycles as f64 * hw.cycle_time()
    }

    /// Achieved GOPS given the stream's true MAC work.
    pub fn achieved_gops(&self, hw: &VtaConfig, macs: u64) -> f64 {
        let secs = self.seconds(hw);
        if secs <= 0.0 {
            0.0
        } else {
            2.0 * macs as f64 / secs / 1e9
        }
    }

    /// Fraction of the makespan the compute unit was busy.
    pub fn compute_utilization(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.compute_busy as f64 / self.cycles as f64
        }
    }
}

/// Simulation error (malformed stream).
#[derive(Debug, PartialEq, Eq)]
pub enum SimError {
    Deadlock { remaining: usize, heads: String },
}

impl std::fmt::Display for SimError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SimError::Deadlock { remaining, heads } => write!(
                f,
                "dependence deadlock: {remaining} instructions unscheduled (unit heads: {heads})"
            ),
        }
    }
}

impl std::error::Error for SimError {}

/// Latency of one instruction in cycles (excluding queueing/dependences).
fn latency(op: &Op, hw: &VtaConfig) -> u64 {
    match *op {
        Op::Load { bytes, .. } => hw.dma_latency as u64 + div_ceil_u64(bytes, hw.dram_bytes_per_cycle),
        Op::Gemm { uops, .. } => GEMM_PIPELINE_FILL + uops as u64,
        Op::Alu { elems } => ALU_PIPELINE_FILL + div_ceil_u64(elems, hw.alu_lanes),
        Op::Store { bytes } => hw.dma_latency as u64 + div_ceil_u64(bytes, hw.dram_bytes_per_cycle),
        Op::Sync => 1,
    }
}

fn div_ceil_u64(a: usize, b: usize) -> u64 {
    (a as u64).div_ceil(b as u64)
}

/// Does this op occupy the shared DRAM bus, and for how many beats?
fn bus_cycles(op: &Op, hw: &VtaConfig) -> u64 {
    match *op {
        Op::Load { bytes, .. } | Op::Store { bytes } => div_ceil_u64(bytes, hw.dram_bytes_per_cycle),
        _ => 0,
    }
}

#[derive(Default)]
struct TokenQueue(VecDeque<u64>);

impl TokenQueue {
    fn push(&mut self, time: u64) {
        self.0.push_back(time);
    }
    fn peek(&self) -> Option<u64> {
        self.0.front().copied()
    }
    fn pop(&mut self) -> u64 {
        self.0.pop_front().expect("pop on empty token queue")
    }
}

/// Run an instruction stream on a hardware instance.
pub fn simulate(stream: &[Instr], hw: &VtaConfig) -> Result<SimReport, SimError> {
    // Split into per-unit in-order queues (program order preserved per unit).
    let mut queues: [Vec<&Instr>; 3] = [Vec::new(), Vec::new(), Vec::new()];
    for i in stream {
        queues[unit_idx(i.unit())].push(i);
    }
    let mut head = [0usize; 3];
    let mut unit_free = [0u64; 3];
    let mut busy = [0u64; 3];
    let mut compute_stall = 0u64;

    // Token queues indexed by (producer unit perspective):
    //   l2c: LOAD push_next  -> COMPUTE pop_prev
    //   c2l: COMPUTE push_prev -> LOAD pop_next
    //   c2s: COMPUTE push_next -> STORE pop_prev
    //   s2c: STORE push_prev -> COMPUTE pop_next
    let mut l2c = TokenQueue::default();
    let mut c2l = TokenQueue::default();
    let mut c2s = TokenQueue::default();
    let mut s2c = TokenQueue::default();

    let mut bus_free = 0u64;
    let mut makespan = 0u64;

    let total = stream.len();
    let mut scheduled = 0usize;

    loop {
        let mut progressed = false;
        for u in 0..3 {
            let q = &queues[u];
            if head[u] >= q.len() {
                continue;
            }
            let instr = q[head[u]];
            // Determine which queues this instruction pops from, given its
            // unit's neighbours in the LOAD <-> COMPUTE <-> STORE chain.
            let (pop_a, pop_b): (Option<u64>, Option<u64>) = match instr.unit() {
                Unit::Load => (
                    None, // LOAD has no previous stage
                    if instr.deps.pop_next { Some(c2l.peek().unwrap_or(u64::MAX)) } else { None },
                ),
                Unit::Compute => (
                    if instr.deps.pop_prev { Some(l2c.peek().unwrap_or(u64::MAX)) } else { None },
                    if instr.deps.pop_next { Some(s2c.peek().unwrap_or(u64::MAX)) } else { None },
                ),
                Unit::Store => (
                    if instr.deps.pop_prev { Some(c2s.peek().unwrap_or(u64::MAX)) } else { None },
                    None, // STORE has no next stage
                ),
            };
            // Blocked on a token that does not exist yet?
            if pop_a == Some(u64::MAX) || pop_b == Some(u64::MAX) {
                continue;
            }

            // Consume tokens, compute start time.
            let mut ready = unit_free[u];
            match instr.unit() {
                Unit::Load => {
                    if instr.deps.pop_next {
                        ready = ready.max(c2l.pop());
                    }
                }
                Unit::Compute => {
                    if instr.deps.pop_prev {
                        ready = ready.max(l2c.pop());
                    }
                    if instr.deps.pop_next {
                        ready = ready.max(s2c.pop());
                    }
                }
                Unit::Store => {
                    if instr.deps.pop_prev {
                        ready = ready.max(c2s.pop());
                    }
                }
            }
            // Shared DRAM bus arbitration for DMAs.
            let beats = bus_cycles(&instr.op, hw);
            let start = if beats > 0 { ready.max(bus_free) } else { ready };
            let lat = latency(&instr.op, hw);
            let end = start + lat;
            if beats > 0 {
                bus_free = start + hw.dma_latency as u64 + beats;
            }
            if u == unit_idx(Unit::Compute) {
                compute_stall += start - unit_free[u].min(start);
            }
            busy[u] += lat;
            unit_free[u] = end;
            makespan = makespan.max(end);

            // Produce tokens.
            match instr.unit() {
                Unit::Load => {
                    if instr.deps.push_next {
                        l2c.push(end);
                    }
                }
                Unit::Compute => {
                    if instr.deps.push_prev {
                        c2l.push(end);
                    }
                    if instr.deps.push_next {
                        c2s.push(end);
                    }
                }
                Unit::Store => {
                    if instr.deps.push_prev {
                        s2c.push(end);
                    }
                }
            }
            head[u] += 1;
            scheduled += 1;
            progressed = true;
        }
        if scheduled == total {
            break;
        }
        if !progressed {
            let heads = (0..3)
                .filter(|&u| head[u] < queues[u].len())
                .map(|u| format!("{:?}:{:?}", idx_unit(u), queues[u][head[u]].op))
                .collect::<Vec<_>>()
                .join(", ");
            return Err(SimError::Deadlock { remaining: total - scheduled, heads });
        }
    }

    let stats = stream_stats(stream);
    Ok(SimReport {
        cycles: makespan,
        load_busy: busy[0],
        compute_busy: busy[1],
        store_busy: busy[2],
        compute_stall,
        gemm_uops: stats.gemm_uops as u64,
        dram_bytes: (stats.load_bytes + stats.store_bytes) as u64,
    })
}

fn unit_idx(u: Unit) -> usize {
    match u {
        Unit::Load => 0,
        Unit::Compute => 1,
        Unit::Store => 2,
    }
}

fn idx_unit(i: usize) -> Unit {
    match i {
        0 => Unit::Load,
        1 => Unit::Compute,
        _ => Unit::Store,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::isa::{Buffer, Deps};

    fn hw() -> VtaConfig {
        VtaConfig::default()
    }

    fn load(bytes: usize, deps: Deps) -> Instr {
        Instr::new(Op::Load { buffer: Buffer::Inp, bytes }, deps)
    }

    fn gemm(uops: usize, deps: Deps) -> Instr {
        Instr::new(Op::Gemm { uops, reset: false }, deps)
    }

    fn store(bytes: usize, deps: Deps) -> Instr {
        Instr::new(Op::Store { bytes }, deps)
    }

    #[test]
    fn single_load_latency_exact() {
        let hw = hw();
        let r = simulate(&[load(800, Deps::NONE)], &hw).unwrap();
        assert_eq!(r.cycles, 32 + 100); // dma_latency + 800/8
        assert_eq!(r.dram_bytes, 800);
    }

    #[test]
    fn single_gemm_latency_exact() {
        let r = simulate(&[gemm(1000, Deps::NONE)], &hw()).unwrap();
        assert_eq!(r.cycles, GEMM_PIPELINE_FILL + 1000);
        assert_eq!(r.gemm_uops, 1000);
    }

    #[test]
    fn alu_latency_uses_lanes() {
        let r = simulate(&[Instr::new(Op::Alu { elems: 160 }, Deps::NONE)], &hw()).unwrap();
        assert_eq!(r.cycles, ALU_PIPELINE_FILL + 10);
    }

    #[test]
    fn dependent_chain_serializes() {
        // load -> gemm -> store with explicit tokens: makespan = sum.
        let stream = vec![
            load(800, Deps::NONE.push_next()),
            gemm(100, Deps::NONE.pop_prev().push_next()),
            store(80, Deps::NONE.pop_prev()),
        ];
        let hw = hw();
        let r = simulate(&stream, &hw).unwrap();
        let expect = (32 + 100) + (GEMM_PIPELINE_FILL + 100) + (32 + 10);
        assert_eq!(r.cycles, expect);
    }

    #[test]
    fn independent_units_overlap() {
        // Without dependences, a long load and a long gemm run concurrently.
        let stream = vec![load(8000, Deps::NONE), gemm(5000, Deps::NONE)];
        let r = simulate(&stream, &hw()).unwrap();
        let load_lat = 32 + 1000;
        let gemm_lat = GEMM_PIPELINE_FILL + 5000;
        assert_eq!(r.cycles, gemm_lat.max(load_lat));
    }

    #[test]
    fn double_buffering_hides_dma() {
        // Two tiles, serial: L0 G0 L1 G1 with full serialization via tokens
        // vs. pipelined: L1 issued while G0 runs.
        let hw = hw();
        let serial = vec![
            load(8000, Deps::NONE.push_next()),
            gemm(1000, Deps::NONE.pop_prev().push_prev()),
            load(8000, Deps::NONE.pop_next().push_next()),
            gemm(1000, Deps::NONE.pop_prev()),
        ];
        // Pipelined: second load does not wait for compute's token.
        let pipelined = vec![
            load(8000, Deps::NONE.push_next()),
            load(8000, Deps::NONE.push_next()),
            gemm(1000, Deps::NONE.pop_prev()),
            gemm(1000, Deps::NONE.pop_prev()),
        ];
        let rs = simulate(&serial, &hw).unwrap();
        let rp = simulate(&pipelined, &hw).unwrap();
        assert!(
            rp.cycles < rs.cycles,
            "pipelined {} should beat serial {}",
            rp.cycles,
            rs.cycles
        );
    }

    #[test]
    fn bus_contention_serializes_dmas() {
        // A load and a store with no dependences still share the DRAM bus.
        let stream = vec![load(8000, Deps::NONE), store(8000, Deps::NONE)];
        let r = simulate(&stream, &hw()).unwrap();
        // Each needs 1000 beats; second DMA waits for the bus.
        assert!(r.cycles >= 2000, "cycles {}", r.cycles);
    }

    #[test]
    fn deadlock_detected() {
        let stream = vec![gemm(10, Deps::NONE.pop_prev())]; // no one pushes
        let err = simulate(&stream, &hw()).unwrap_err();
        match err {
            SimError::Deadlock { remaining, .. } => assert_eq!(remaining, 1),
        }
    }

    #[test]
    fn report_seconds_and_gops() {
        let hw = hw();
        let r = simulate(&[gemm(100_000, Deps::NONE)], &hw).unwrap();
        let secs = r.seconds(&hw);
        assert!((secs - (100_000 + GEMM_PIPELINE_FILL) as f64 * 1e-8).abs() < 1e-12);
        // 100k uops * 256 MACs at near-full utilization ~ 51.2 GOPS peak.
        let gops = r.achieved_gops(&hw, 100_000 * 256);
        assert!(gops > 50.0 && gops <= hw.peak_gops() + 1e-9, "{gops}");
    }

    #[test]
    fn empty_stream_is_zero_cycles() {
        let r = simulate(&[], &hw()).unwrap();
        assert_eq!(r.cycles, 0);
    }

    #[test]
    fn compute_utilization_bounds() {
        let r = simulate(&[gemm(100, Deps::NONE), load(80_000, Deps::NONE)], &hw()).unwrap();
        let u = r.compute_utilization();
        assert!((0.0..=1.0).contains(&u));
    }
}
