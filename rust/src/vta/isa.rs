//! The simulator's instruction set: a faithful abstraction of VTA's
//! task-level ISA.
//!
//! VTA decouples memory and compute with three concurrent units — LOAD,
//! COMPUTE, STORE — synchronized only through dependence token queues
//! (load→compute, compute→load, compute→store, store→compute). An
//! instruction may *pop* a token (wait) from a neighbour before starting and
//! *push* one (signal) after finishing. This is exactly the mechanism that
//! makes double-buffering / virtual threading work, so the cycle model keeps
//! it explicit rather than approximating overlap analytically.

/// Which on-chip scratchpad a LOAD targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Buffer {
    /// Input activations (int8).
    Inp,
    /// Weights (int8).
    Wgt,
    /// Accumulator (int32) — used to pre-load partial sums / biases.
    Acc,
    /// Micro-op kernel cache.
    Uop,
}

/// Functional unit that executes an instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Unit {
    Load,
    Compute,
    Store,
}

/// Dependence-token flags carried by every instruction (VTA's
/// pop_prev/pop_next/push_prev/push_next semantics, oriented per unit).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Deps {
    /// Wait for a token from the previous pipeline stage before starting.
    pub pop_prev: bool,
    /// Wait for a token from the next pipeline stage before starting.
    pub pop_next: bool,
    /// Signal the previous stage on completion.
    pub push_prev: bool,
    /// Signal the next stage on completion.
    pub push_next: bool,
}

impl Deps {
    pub const NONE: Deps = Deps { pop_prev: false, pop_next: false, push_prev: false, push_next: false };

    pub fn pop_prev(mut self) -> Self {
        self.pop_prev = true;
        self
    }
    pub fn pop_next(mut self) -> Self {
        self.pop_next = true;
        self
    }
    pub fn push_prev(mut self) -> Self {
        self.push_prev = true;
        self
    }
    pub fn push_next(mut self) -> Self {
        self.push_next = true;
        self
    }
}

/// One task-level instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    /// DMA `bytes` from DRAM into `buffer`.
    Load { buffer: Buffer, bytes: usize },
    /// Run `uops` GEMM micro-ops (each = one batch x block_in x block_out
    /// tile MAC, one per cycle when pipelined). `reset` marks accumulator
    /// initialization passes (same cost, kept for stream readability).
    Gemm { uops: usize, reset: bool },
    /// Vector ALU pass over `elems` accumulator elements (shift/min/max/add).
    Alu { elems: usize },
    /// DMA `bytes` of outputs back to DRAM.
    Store { bytes: usize },
    /// Pure synchronization (FINISH / NOP-with-deps).
    Sync,
}

/// Instruction = operation + dependence flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Instr {
    pub op: Op,
    pub deps: Deps,
}

impl Instr {
    pub fn new(op: Op, deps: Deps) -> Self {
        Instr { op, deps }
    }

    /// The unit this instruction executes on. Mirrors VTA: LOAD handles
    /// INP/WGT DMAs; UOP/ACC loads, GEMM and ALU run on COMPUTE; STORE
    /// handles output DMAs.
    pub fn unit(&self) -> Unit {
        match self.op {
            Op::Load { buffer: Buffer::Inp | Buffer::Wgt, .. } => Unit::Load,
            Op::Load { buffer: Buffer::Acc | Buffer::Uop, .. } => Unit::Compute,
            Op::Gemm { .. } | Op::Alu { .. } | Op::Sync => Unit::Compute,
            Op::Store { .. } => Unit::Store,
        }
    }
}

/// Aggregate statistics of an instruction stream (pre-simulation).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct StreamStats {
    pub instrs: usize,
    pub gemm_uops: usize,
    pub load_bytes: usize,
    pub store_bytes: usize,
    pub alu_elems: usize,
}

/// Summarize a stream.
pub fn stream_stats(stream: &[Instr]) -> StreamStats {
    let mut s = StreamStats { instrs: stream.len(), ..Default::default() };
    for i in stream {
        match i.op {
            Op::Load { bytes, .. } => s.load_bytes += bytes,
            Op::Gemm { uops, .. } => s.gemm_uops += uops,
            Op::Alu { elems } => s.alu_elems += elems,
            Op::Store { bytes } => s.store_bytes += bytes,
            Op::Sync => {}
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn unit_routing_matches_vta() {
        let i = Instr::new(Op::Load { buffer: Buffer::Inp, bytes: 8 }, Deps::NONE);
        assert_eq!(i.unit(), Unit::Load);
        let w = Instr::new(Op::Load { buffer: Buffer::Wgt, bytes: 8 }, Deps::NONE);
        assert_eq!(w.unit(), Unit::Load);
        let a = Instr::new(Op::Load { buffer: Buffer::Acc, bytes: 8 }, Deps::NONE);
        assert_eq!(a.unit(), Unit::Compute);
        let g = Instr::new(Op::Gemm { uops: 4, reset: false }, Deps::NONE);
        assert_eq!(g.unit(), Unit::Compute);
        let s = Instr::new(Op::Store { bytes: 8 }, Deps::NONE);
        assert_eq!(s.unit(), Unit::Store);
    }

    #[test]
    fn deps_builder() {
        let d = Deps::NONE.pop_prev().push_next();
        assert!(d.pop_prev && d.push_next && !d.pop_next && !d.push_prev);
    }

    #[test]
    fn stats_accumulate() {
        let stream = vec![
            Instr::new(Op::Load { buffer: Buffer::Inp, bytes: 100 }, Deps::NONE),
            Instr::new(Op::Load { buffer: Buffer::Wgt, bytes: 50 }, Deps::NONE),
            Instr::new(Op::Gemm { uops: 32, reset: false }, Deps::NONE),
            Instr::new(Op::Alu { elems: 64 }, Deps::NONE),
            Instr::new(Op::Store { bytes: 16 }, Deps::NONE),
        ];
        let s = stream_stats(&stream);
        assert_eq!(s.instrs, 5);
        assert_eq!(s.load_bytes, 150);
        assert_eq!(s.gemm_uops, 32);
        assert_eq!(s.alu_elems, 64);
        assert_eq!(s.store_bytes, 16);
    }
}
