//! VTA++ accelerator substrate: configuration, task-level ISA, cycle-level
//! pipeline simulator and area model.
//!
//! This is the "target hardware" of the reproduction. The paper evaluates on
//! the VTA++ *simulator*; this module is that simulator, rebuilt in rust
//! (see DESIGN.md §Substitutions).

pub mod area;
pub mod config;
pub mod isa;
pub mod sim;

pub use config::VtaConfig;
pub use isa::{Buffer, Deps, Instr, Op, Unit};
pub use sim::{simulate, SimError, SimReport, CYCLE_MODEL_VERSION};
