//! VTA++ hardware configuration.
//!
//! VTA++ (Banerjee et al., 2021) keeps VTA's architecture — a GEMM core fed
//! by on-chip INP/WGT/ACC scratchpads over a decoupled
//! load/compute/store pipeline — but exposes its geometry as build
//! parameters. The three the paper's hardware agent tunes ("hardware
//! knobs", §2.1) are the GEMM tile shape: `BATCH`, `BLOCK_IN`, `BLOCK_OUT`.
//! The rest (buffer sizes, clock, DRAM interface) stay at VTA++ defaults
//! but are modelled explicitly so constraint handling (Eq. 4) has real
//! area/memory numbers to penalize.

use crate::util::json::Json;

/// Data type widths used by VTA: int8 inputs/weights, int32 accumulators,
/// int8 outputs.
pub const INP_BYTES: usize = 1;
pub const WGT_BYTES: usize = 1;
pub const ACC_BYTES: usize = 4;
pub const OUT_BYTES: usize = 1;

/// Complete description of one VTA++ hardware instance.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VtaConfig {
    /// GEMM tile rows = data samples processed in parallel (BATCH).
    pub batch: usize,
    /// GEMM tile reduction width (BLOCK_IN).
    pub block_in: usize,
    /// GEMM tile output width (BLOCK_OUT).
    pub block_out: usize,
    /// Input scratchpad capacity in KiB.
    pub inp_buf_kib: usize,
    /// Weight scratchpad capacity in KiB.
    pub wgt_buf_kib: usize,
    /// Accumulator scratchpad capacity in KiB.
    pub acc_buf_kib: usize,
    /// Micro-op cache capacity in KiB.
    pub uop_buf_kib: usize,
    /// Core clock in MHz.
    pub freq_mhz: usize,
    /// DRAM bytes transferred per core cycle once a DMA burst is streaming.
    pub dram_bytes_per_cycle: usize,
    /// Fixed DMA setup latency in cycles (request to first beat).
    pub dma_latency: usize,
    /// ALU vector lanes (elements per cycle for post-GEMM ops).
    pub alu_lanes: usize,
}

impl Default for VtaConfig {
    /// VTA++ default specification — the hardware AutoTVM/CHAMELEON use
    /// (they cannot explore hardware, §4.1): 1x16x16 GEMM, 32 KiB INP,
    /// 256 KiB WGT, 128 KiB ACC, 32 KiB UOP.
    fn default() -> Self {
        VtaConfig {
            batch: 1,
            block_in: 16,
            block_out: 16,
            inp_buf_kib: 32,
            wgt_buf_kib: 256,
            acc_buf_kib: 128,
            uop_buf_kib: 32,
            freq_mhz: 100,
            dram_bytes_per_cycle: 8, // 64-bit AXI @ core clock
            dma_latency: 32,
            alu_lanes: 16,
        }
    }
}

impl VtaConfig {
    /// Hardware instance with a given GEMM geometry, VTA++ defaults
    /// elsewhere. This is the constructor the hardware agent drives.
    pub fn with_gemm(batch: usize, block_in: usize, block_out: usize) -> Self {
        VtaConfig { batch, block_in, block_out, ..Default::default() }
    }

    /// Multiply-accumulate units in the GEMM array.
    pub fn macs_per_cycle(&self) -> usize {
        self.batch * self.block_in * self.block_out
    }

    /// Peak GOPS (2 ops per MAC) at the configured clock.
    pub fn peak_gops(&self) -> f64 {
        2.0 * self.macs_per_cycle() as f64 * self.freq_mhz as f64 * 1e6 / 1e9
    }

    /// Input scratchpad capacity in bytes.
    pub fn inp_buf_bytes(&self) -> usize {
        self.inp_buf_kib * 1024
    }

    pub fn wgt_buf_bytes(&self) -> usize {
        self.wgt_buf_kib * 1024
    }

    pub fn acc_buf_bytes(&self) -> usize {
        self.acc_buf_kib * 1024
    }

    /// Sanity-check structural invariants (powers of two, non-zero).
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("batch", self.batch),
            ("block_in", self.block_in),
            ("block_out", self.block_out),
        ] {
            if v == 0 || !v.is_power_of_two() {
                return Err(format!("{name} must be a non-zero power of two, got {v}"));
            }
        }
        if self.batch > 16 {
            return Err(format!("batch {} exceeds VTA++ max of 16", self.batch));
        }
        if self.block_in > 128 || self.block_out > 128 {
            return Err(format!(
                "block_in/block_out {}x{} exceed VTA++ max of 128",
                self.block_in, self.block_out
            ));
        }
        if self.freq_mhz == 0 || self.dram_bytes_per_cycle == 0 || self.alu_lanes == 0 {
            return Err("freq/dram/alu parameters must be non-zero".into());
        }
        Ok(())
    }

    /// Seconds per cycle.
    pub fn cycle_time(&self) -> f64 {
        1.0 / (self.freq_mhz as f64 * 1e6)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::num(self.batch as f64)),
            ("block_in", Json::num(self.block_in as f64)),
            ("block_out", Json::num(self.block_out as f64)),
            ("inp_buf_kib", Json::num(self.inp_buf_kib as f64)),
            ("wgt_buf_kib", Json::num(self.wgt_buf_kib as f64)),
            ("acc_buf_kib", Json::num(self.acc_buf_kib as f64)),
            ("uop_buf_kib", Json::num(self.uop_buf_kib as f64)),
            ("freq_mhz", Json::num(self.freq_mhz as f64)),
            ("dram_bytes_per_cycle", Json::num(self.dram_bytes_per_cycle as f64)),
            ("dma_latency", Json::num(self.dma_latency as f64)),
            ("alu_lanes", Json::num(self.alu_lanes as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        let d = VtaConfig::default();
        Some(VtaConfig {
            batch: v.get_usize("batch")?,
            block_in: v.get_usize("block_in")?,
            block_out: v.get_usize("block_out")?,
            inp_buf_kib: v.get_usize("inp_buf_kib").unwrap_or(d.inp_buf_kib),
            wgt_buf_kib: v.get_usize("wgt_buf_kib").unwrap_or(d.wgt_buf_kib),
            acc_buf_kib: v.get_usize("acc_buf_kib").unwrap_or(d.acc_buf_kib),
            uop_buf_kib: v.get_usize("uop_buf_kib").unwrap_or(d.uop_buf_kib),
            freq_mhz: v.get_usize("freq_mhz").unwrap_or(d.freq_mhz),
            dram_bytes_per_cycle: v
                .get_usize("dram_bytes_per_cycle")
                .unwrap_or(d.dram_bytes_per_cycle),
            dma_latency: v.get_usize("dma_latency").unwrap_or(d.dma_latency),
            alu_lanes: v.get_usize("alu_lanes").unwrap_or(d.alu_lanes),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_vta_spec() {
        let c = VtaConfig::default();
        assert_eq!((c.batch, c.block_in, c.block_out), (1, 16, 16));
        assert_eq!(c.macs_per_cycle(), 256);
        assert!(c.validate().is_ok());
    }

    #[test]
    fn peak_gops_default() {
        let c = VtaConfig::default();
        // 256 MACs * 2 * 100 MHz = 51.2 GOPS.
        assert!((c.peak_gops() - 51.2).abs() < 1e-9);
    }

    #[test]
    fn validate_rejects_non_pow2() {
        let c = VtaConfig::with_gemm(1, 24, 16);
        assert!(c.validate().is_err());
        let c = VtaConfig::with_gemm(0, 16, 16);
        assert!(c.validate().is_err());
    }

    #[test]
    fn validate_rejects_oversize() {
        assert!(VtaConfig::with_gemm(32, 16, 16).validate().is_err());
        assert!(VtaConfig::with_gemm(1, 256, 16).validate().is_err());
    }

    #[test]
    fn json_roundtrip() {
        let c = VtaConfig::with_gemm(2, 32, 64);
        let back = VtaConfig::from_json(&c.to_json()).unwrap();
        assert_eq!(back, c);
    }

    #[test]
    fn cycle_time_inverse_of_freq() {
        let c = VtaConfig::default();
        assert!((c.cycle_time() - 1e-8).abs() < 1e-20);
    }
}
