//! Silicon area model for VTA++ instances.
//!
//! The paper's constraint mechanism (Eq. 4) penalizes configurations whose
//! `area(Θ)` exceeds `area_max`. We estimate area in a 16 nm-class process
//! from public accelerator datapoints: an int8 MAC plus its share of the
//! systolic interconnect ≈ 500 µm², SRAM ≈ 0.6 mm² per MiB for dense
//! single-port arrays, plus a fixed controller/DMA overhead. Absolute
//! numbers only need to be *consistent* — the penalty compares candidate
//! configs against a budget expressed in the same units.

use super::config::VtaConfig;

/// Area of one int8 MAC unit including pipeline registers (mm^2).
pub const MAC_AREA_MM2: f64 = 500.0e-6;
/// SRAM macro density (mm^2 per KiB).
pub const SRAM_AREA_MM2_PER_KIB: f64 = 0.6 / 1024.0;
/// Fixed overhead: fetch/decode, DMA engines, token queues (mm^2).
pub const CONTROL_AREA_MM2: f64 = 0.25;
/// Accumulator register-file density (mm^2 per KiB) — flop-heavier than SRAM.
pub const ACC_AREA_MM2_PER_KIB: f64 = 1.2 / 1024.0;

/// Area breakdown of a hardware instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AreaBreakdown {
    pub gemm_mm2: f64,
    pub sram_mm2: f64,
    pub acc_mm2: f64,
    pub control_mm2: f64,
}

impl AreaBreakdown {
    pub fn total_mm2(&self) -> f64 {
        self.gemm_mm2 + self.sram_mm2 + self.acc_mm2 + self.control_mm2
    }
}

/// Estimate the silicon area of a VTA++ instance.
pub fn area(hw: &VtaConfig) -> AreaBreakdown {
    let macs = hw.macs_per_cycle() as f64;
    // ALU lanes cost roughly one MAC each.
    let gemm_mm2 = (macs + hw.alu_lanes as f64) * MAC_AREA_MM2;
    let sram_kib = (hw.inp_buf_kib + hw.wgt_buf_kib + hw.uop_buf_kib) as f64;
    AreaBreakdown {
        gemm_mm2,
        sram_mm2: sram_kib * SRAM_AREA_MM2_PER_KIB,
        acc_mm2: hw.acc_buf_kib as f64 * ACC_AREA_MM2_PER_KIB,
        control_mm2: CONTROL_AREA_MM2,
    }
}

/// Total area in mm^2 (the `area(Θ)` of Eq. 4).
pub fn total_area_mm2(hw: &VtaConfig) -> f64 {
    area(hw).total_mm2()
}

/// Default area budget used by ARCO's constraint term: 1.25x the default
/// VTA++ instance. Tight enough that hardware exploration is a *shaping*
/// exercise (re-balancing BATCH/BLOCK_IN/BLOCK_OUT within roughly the same
/// silicon, like retargeting an FPGA overlay), not free compute scaling —
/// this keeps the co-design gains in the paper's 1.1-1.4x regime rather
/// than letting the agents buy arbitrarily large arrays.
pub fn default_area_budget_mm2() -> f64 {
    1.25 * total_area_mm2(&VtaConfig::default())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_area_is_plausible() {
        let a = total_area_mm2(&VtaConfig::default());
        // A 256-MAC int8 accelerator with ~450KiB SRAM: O(1) mm^2.
        assert!(a > 0.3 && a < 5.0, "{a}");
    }

    #[test]
    fn area_monotone_in_macs() {
        let small = total_area_mm2(&VtaConfig::with_gemm(1, 16, 16));
        let big = total_area_mm2(&VtaConfig::with_gemm(4, 32, 32));
        assert!(big > small);
    }

    #[test]
    fn breakdown_sums_to_total() {
        let hw = VtaConfig::with_gemm(2, 32, 16);
        let b = area(&hw);
        assert!((b.total_mm2() - (b.gemm_mm2 + b.sram_mm2 + b.acc_mm2 + b.control_mm2)).abs() < 1e-12);
    }

    #[test]
    fn budget_excludes_maximal_config() {
        // The largest VTA++-legal geometry must blow the default budget,
        // otherwise the constraint term never binds.
        let max = VtaConfig::with_gemm(16, 128, 128);
        assert!(total_area_mm2(&max) > default_area_budget_mm2());
        // ...but the default config fits comfortably.
        assert!(total_area_mm2(&VtaConfig::default()) < default_area_budget_mm2());
    }
}
