//! "Hardware measurement": decode a design-space point, lower it, simulate
//! it, and report fitness. This is the `f[τ(Θ)]` of §2.3 — the expensive
//! call every framework tries to minimize.
//!
//! [`measure_point`] is the *raw primitive*: one point, one simulation, no
//! caching, no parallelism. On the tuning path it is only ever invoked by
//! [`crate::eval::VtaSimBackend`]; everything else goes through
//! [`crate::eval::Engine`], which batches, deduplicates, caches and
//! parallelizes these calls (and can swap in other backends entirely).
//! Call it directly only from backend implementations, micro-benchmarks and
//! parity tests.

use crate::space::{ConfigSpace, PointConfig};
use crate::vta::area::total_area_mm2;
use crate::vta::{simulate, VtaConfig};

/// Outcome of measuring one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MeasureResult {
    /// Simulated execution time in seconds; `f64::INFINITY` if invalid.
    pub seconds: f64,
    /// Simulated cycles (0 if invalid).
    pub cycles: u64,
    /// Achieved GFLOPS on the task's true FLOPs (0 if invalid).
    pub gflops: f64,
    /// Accelerator area of the decoded hardware (mm^2).
    pub area_mm2: f64,
    /// GEMM array occupancy in [0,1].
    pub occupancy: f64,
    /// False when the config failed to lower (buffer overflow etc.).
    pub valid: bool,
}

impl MeasureResult {
    /// The paper's fitness: throughput, i.e. inverse execution time.
    pub fn fitness(&self) -> f64 {
        if self.valid && self.seconds > 0.0 {
            1.0 / self.seconds
        } else {
            0.0
        }
    }

    fn invalid(hw: &VtaConfig) -> MeasureResult {
        MeasureResult {
            seconds: f64::INFINITY,
            cycles: 0,
            gflops: 0.0,
            area_mm2: total_area_mm2(hw),
            occupancy: 0.0,
            valid: false,
        }
    }
}

/// Measure one point of a task's configuration space on the VTA++ simulator.
pub fn measure_point(space: &ConfigSpace, point: &PointConfig) -> MeasureResult {
    let (hw, sw) = space.decode(point);
    let kernel = match super::lower_conv(&space.task, &hw, &sw) {
        Ok(k) => k,
        Err(_) => return MeasureResult::invalid(&hw),
    };
    let report = match simulate(&kernel.stream, &hw) {
        Ok(r) => r,
        Err(_) => return MeasureResult::invalid(&hw),
    };
    let seconds = report.seconds(&hw);
    MeasureResult {
        seconds,
        cycles: report.cycles,
        gflops: space.task.flops() as f64 / seconds / 1e9,
        area_mm2: total_area_mm2(&hw),
        occupancy: kernel.occupancy(),
        valid: true,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg32;
    use crate::workload::Conv2dTask;

    fn space() -> ConfigSpace {
        ConfigSpace::for_task(&Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1), true)
    }

    #[test]
    fn default_point_measures_valid() {
        let s = space();
        let m = measure_point(&s, &s.default_point());
        assert!(m.valid);
        assert!(m.seconds > 0.0 && m.seconds.is_finite());
        assert!(m.gflops > 0.0);
        assert!(m.fitness() > 0.0);
    }

    #[test]
    fn invalid_points_get_zero_fitness() {
        let s = space();
        // Find an invalid point by brute force over random samples; the
        // space contains buffer-overflow configs (big tiles, big blocks).
        let mut rng = Pcg32::seeded(3);
        let mut found = false;
        for _ in 0..2000 {
            let p = s.random_point(&mut rng);
            let m = measure_point(&s, &p);
            if !m.valid {
                assert_eq!(m.fitness(), 0.0);
                assert!(m.seconds.is_infinite());
                found = true;
                break;
            }
        }
        assert!(found, "expected at least one invalid config in the space");
    }

    #[test]
    fn measurement_is_deterministic() {
        let s = space();
        let mut rng = Pcg32::seeded(8);
        for _ in 0..20 {
            let p = s.random_point(&mut rng);
            let a = measure_point(&s, &p);
            let b = measure_point(&s, &p);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn landscape_varies_with_software_knobs() {
        // The whole point of tuning: different points, different fitness.
        let s = space();
        let mut rng = Pcg32::seeded(5);
        let mut values = std::collections::HashSet::new();
        for _ in 0..100 {
            let p = s.random_point(&mut rng);
            let m = measure_point(&s, &p);
            if m.valid {
                values.insert(m.cycles);
            }
        }
        assert!(values.len() > 10, "landscape too flat: {} distinct", values.len());
    }

    #[test]
    fn gflops_below_peak() {
        let s = space();
        let mut rng = Pcg32::seeded(6);
        for _ in 0..50 {
            let p = s.random_point(&mut rng);
            let m = measure_point(&s, &p);
            if m.valid {
                let (hw, _) = s.decode(&p);
                assert!(
                    m.gflops <= hw.peak_gops() + 1e-9,
                    "gflops {} exceeds peak {}",
                    m.gflops,
                    hw.peak_gops()
                );
            }
        }
    }
}
