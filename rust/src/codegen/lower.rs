//! Convolution lowering: tiling, virtual threading and dependence-token
//! insertion.
//!
//! The schedule follows VTA's canonical conv2d template:
//!
//! ```text
//! for each virtual thread (round-robin interleaved):
//!   for each output tile (batch-block, co-chunk, tile_h, tile_w):
//!     LOAD.UOP  micro-kernel           (compute unit)
//!     for each ci-chunk:               # reduction over input channels
//!       LOAD.INP  input tile           (load unit)    [pop c2l if reusing buffer]
//!       LOAD.WGT  weight tile          (load unit)    pushes token to compute
//!       GEMM      tile matmuls         (compute)      pops load token; reset on first chunk
//!     GEMM of last chunk pushes buffer-free token back to load
//!     ALU       shift/clip (+ relu)    (compute)      pushes token to store
//!     STORE     output tile            (store unit)   pops compute token, pushes acc-free
//!     first GEMM of the thread's next tile pops the acc-free token
//! ```
//!
//! Two knobs (`h_threading`, `oc_threading`) split tiles across virtual
//! threads whose instruction sequences interleave in the stream; because the
//! scratchpads are partitioned per thread, thread B's loads overlap thread
//! A's compute — the dependence tokens expose exactly the double-buffering
//! the hardware supports (2 token-queue slots, so effective threads cap at 2).

use crate::space::SwConfig;
use crate::util::stats::ceil_div;
use crate::vta::config::{ACC_BYTES, INP_BYTES, OUT_BYTES, WGT_BYTES};
use crate::vta::{Buffer, Deps, Instr, Op, VtaConfig};
use crate::workload::Conv2dTask;

/// Why a configuration cannot be lowered (an *invalid* configuration in the
/// paper's terms — these waste a hardware measurement when sampled).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodegenError {
    BadHardware(String),
    TileTooLarge { tile_h: usize, tile_w: usize, oh: usize, ow: usize },
    InpOverflow { need: usize, have: usize },
    WgtOverflow { need: usize, have: usize },
    AccOverflow { need: usize, have: usize },
}

impl std::fmt::Display for CodegenError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CodegenError::BadHardware(why) => write!(f, "hardware config invalid: {why}"),
            CodegenError::TileTooLarge { tile_h, tile_w, oh, ow } => {
                write!(f, "spatial tile {tile_h}x{tile_w} exceeds output plane {oh}x{ow}")
            }
            CodegenError::InpOverflow { need, have } => {
                write!(f, "input tile of {need} B exceeds INP buffer partition of {have} B")
            }
            CodegenError::WgtOverflow { need, have } => {
                write!(f, "weight tile of {need} B exceeds WGT buffer partition of {have} B")
            }
            CodegenError::AccOverflow { need, have } => {
                write!(f, "accumulator tile of {need} B exceeds ACC buffer partition of {have} B")
            }
        }
    }
}

impl std::error::Error for CodegenError {}

/// A lowered kernel: the instruction stream plus bookkeeping the measurement
/// layer reports.
#[derive(Debug, Clone)]
pub struct LoweredKernel {
    pub stream: Vec<Instr>,
    /// True MACs of the convolution (not padded work).
    pub macs: u64,
    /// Padded MAC slots actually occupied on the array (>= macs).
    pub padded_macs: u64,
    /// Number of output tiles.
    pub tiles: usize,
    /// Effective virtual threads used.
    pub vthreads: usize,
}

impl LoweredKernel {
    /// GEMM array occupancy: true work / padded slots. Low values flag
    /// geometry mismatches (e.g. BLOCK_IN=64 on a 3-channel layer).
    pub fn occupancy(&self) -> f64 {
        if self.padded_macs == 0 {
            0.0
        } else {
            self.macs as f64 / self.padded_macs as f64
        }
    }
}

/// Per-thread emission state.
struct ThreadCtx {
    stream: Vec<Instr>,
    /// Tiles emitted so far (controls first-iteration token elision).
    tiles_emitted: usize,
}

/// Lower a convolution under (hardware, software) configs.
pub fn lower_conv(
    task: &Conv2dTask,
    hw: &VtaConfig,
    sw: &SwConfig,
) -> Result<LoweredKernel, CodegenError> {
    hw.validate().map_err(CodegenError::BadHardware)?;
    let oh = task.oh();
    let ow = task.ow();
    if sw.tile_h > oh || sw.tile_w > ow || sw.tile_h == 0 || sw.tile_w == 0 {
        return Err(CodegenError::TileTooLarge { tile_h: sw.tile_h, tile_w: sw.tile_w, oh, ow });
    }

    // Effective virtual threads: hardware supports two token-queue slots.
    let vthreads = (sw.h_threading * sw.oc_threading).min(2).max(1);

    // Blocked dimensions.
    let n_bblk = ceil_div(task.n, hw.batch); // batch blocks
    let n_ciblk = ceil_div(task.ci, hw.block_in); // reduction blocks
    let n_coblk = ceil_div(task.co, hw.block_out); // output-channel blocks

    // --- Buffer partitioning -------------------------------------------------
    // Each virtual thread owns 1/vthreads of every scratchpad; within a
    // thread the load/compute handshake double-buffers, so a tile's working
    // set must fit half the partition when threading is off, or the whole
    // partition when the interleave provides the overlap. We use the
    // conservative rule: working set <= partition.
    let inp_part = hw.inp_buf_bytes() / vthreads;
    let wgt_part = hw.wgt_buf_bytes() / vthreads;
    let acc_part = hw.acc_buf_bytes() / vthreads;

    // Accumulator working set: one output tile (all co-blocks of the chunk).
    // Choose co_chunk (in blocks) as the largest power-of-two count that
    // fits; at least 1 or the config is invalid.
    let acc_tile_one_blk =
        hw.batch * sw.tile_h * sw.tile_w * hw.block_out * ACC_BYTES;
    if acc_tile_one_blk > acc_part {
        return Err(CodegenError::AccOverflow { need: acc_tile_one_blk, have: acc_part });
    }
    let mut co_chunk_blks = 1usize;
    while co_chunk_blks * 2 <= n_coblk && acc_tile_one_blk * co_chunk_blks * 2 <= acc_part {
        co_chunk_blks *= 2;
    }

    // Input tile footprint for one ci-chunk (halo included).
    let in_h = (sw.tile_h - 1) * task.stride + task.kh;
    let in_w = (sw.tile_w - 1) * task.stride + task.kw;
    let inp_tile_one_blk = hw.batch * in_h * in_w * hw.block_in * INP_BYTES;
    if inp_tile_one_blk > inp_part {
        return Err(CodegenError::InpOverflow { need: inp_tile_one_blk, have: inp_part });
    }
    // Weight tile for one ci-chunk x co-chunk.
    let wgt_tile_one_blk =
        co_chunk_blks * hw.block_out * hw.block_in * task.kh * task.kw * WGT_BYTES;
    if wgt_tile_one_blk > wgt_part {
        return Err(CodegenError::WgtOverflow { need: wgt_tile_one_blk, have: wgt_part });
    }
    // ci chunking: as many reduction blocks per DMA round as fit both
    // input and weight partitions.
    let mut ci_chunk_blks = 1usize;
    while ci_chunk_blks * 2 <= n_ciblk
        && inp_tile_one_blk * ci_chunk_blks * 2 <= inp_part
        && wgt_tile_one_blk * ci_chunk_blks * 2 <= wgt_part
    {
        ci_chunk_blks *= 2;
    }

    // --- Tile enumeration ----------------------------------------------------
    let tiles_h = ceil_div(oh, sw.tile_h);
    let tiles_w = ceil_div(ow, sw.tile_w);
    let co_chunks = ceil_div(n_coblk, co_chunk_blks);
    let ci_chunks = ceil_div(n_ciblk, ci_chunk_blks);

    let mut threads: Vec<ThreadCtx> =
        (0..vthreads).map(|_| ThreadCtx { stream: Vec::new(), tiles_emitted: 0 }).collect();

    let mut macs: u64 = 0;
    let mut padded_macs: u64 = 0;
    let mut tiles = 0usize;

    for b in 0..n_bblk {
        let cur_batch = (task.n - b * hw.batch).min(hw.batch);
        for cc in 0..co_chunks {
            let cur_co_blks = (n_coblk - cc * co_chunk_blks).min(co_chunk_blks);
            let cur_co = (task.co - cc * co_chunk_blks * hw.block_out)
                .min(cur_co_blks * hw.block_out);
            for th in 0..tiles_h {
                let cur_th = (oh - th * sw.tile_h).min(sw.tile_h);
                for tw in 0..tiles_w {
                    let cur_tw = (ow - tw * sw.tile_w).min(sw.tile_w);
                    // Thread assignment: height stripes and co stripes.
                    let tid = ((th % sw.h_threading.max(1))
                        + sw.h_threading.max(1) * (cc % sw.oc_threading.max(1)))
                        % vthreads;
                    emit_tile(
                        &mut threads[tid],
                        task,
                        hw,
                        TileShape {
                            th: cur_th,
                            tw: cur_tw,
                            co_blks: cur_co_blks,
                            ci_chunks,
                            ci_chunk_blks,
                            n_ciblk,
                        },
                    );
                    tiles += 1;
                    // Work accounting.
                    let tile_out = cur_th * cur_tw;
                    macs += (cur_batch * cur_co * tile_out) as u64
                        * (task.ci * task.kh * task.kw) as u64;
                    padded_macs += (hw.batch * cur_co_blks * hw.block_out * tile_out) as u64
                        * (n_ciblk * hw.block_in * task.kh * task.kw) as u64;
                }
            }
        }
    }

    // Interleave per-thread streams round-robin at tile granularity so the
    // simulator's in-order unit queues see alternating threads.
    let stream = interleave(threads);

    Ok(LoweredKernel { stream, macs, padded_macs, tiles, vthreads })
}

struct TileShape {
    th: usize,
    tw: usize,
    co_blks: usize,
    ci_chunks: usize,
    ci_chunk_blks: usize,
    n_ciblk: usize,
}

/// Emit one output tile's instruction sequence into a thread context.
fn emit_tile(ctx: &mut ThreadCtx, task: &Conv2dTask, hw: &VtaConfig, t: TileShape) {
    let first_tile = ctx.tiles_emitted == 0;
    let s = &mut ctx.stream;

    // Micro-kernel load: one uop per (output pixel x kernel position),
    // 4 bytes each, capped by the uop cache.
    let uop_bytes =
        (t.th * t.tw * task.kh * task.kw * 4).min(hw.uop_buf_kib * 1024);
    s.push(Instr::new(Op::Load { buffer: Buffer::Uop, bytes: uop_bytes }, Deps::NONE));

    let in_h = (t.th - 1) * task.stride + task.kh;
    let in_w = (t.tw - 1) * task.stride + task.kw;

    for chunk in 0..t.ci_chunks {
        let cur_ci_blks = (t.n_ciblk - chunk * t.ci_chunk_blks).min(t.ci_chunk_blks);
        let inp_bytes = hw.batch * in_h * in_w * cur_ci_blks * hw.block_in * INP_BYTES;
        let wgt_bytes =
            t.co_blks * hw.block_out * cur_ci_blks * hw.block_in * task.kh * task.kw * WGT_BYTES;

        // Loads: after the first round, re-using the buffer requires the
        // compute unit to have signalled it is done with the previous
        // contents (c2l token).
        let reuse = !(first_tile && chunk == 0);
        s.push(Instr::new(
            Op::Load { buffer: Buffer::Inp, bytes: inp_bytes },
            if reuse { Deps::NONE.pop_next() } else { Deps::NONE },
        ));
        // The last load of the round signals compute.
        s.push(Instr::new(Op::Load { buffer: Buffer::Wgt, bytes: wgt_bytes }, Deps::NONE.push_next()));

        // GEMM over the chunk: one uop per (batch-block x pixel x kernel pos
        // x ci-blk x co-blk).
        let uops = t.th * t.tw * task.kh * task.kw * cur_ci_blks * t.co_blks;
        let mut deps = Deps::NONE.pop_prev().push_prev(); // consume loads, free buffer
        if chunk == 0 && !first_tile {
            // Re-using the acc partition: wait for the previous tile's store.
            deps = deps.pop_next();
        }
        s.push(Instr::new(Op::Gemm { uops, reset: chunk == 0 }, deps));
    }

    // Post-GEMM ALU: shift/clip quantization over the tile's accumulators.
    let elems = hw.batch * t.th * t.tw * t.co_blks * hw.block_out;
    s.push(Instr::new(Op::Alu { elems }, Deps::NONE.push_next()));

    // Store the quantized outputs; free the acc partition for the next tile.
    let out_bytes = elems * OUT_BYTES;
    s.push(Instr::new(Op::Store { bytes: out_bytes }, Deps::NONE.pop_prev().push_prev()));

    ctx.tiles_emitted += 1;
}

/// Round-robin interleave per-thread streams at tile boundaries (a tile ends
/// after its STORE instruction).
fn interleave(threads: Vec<ThreadCtx>) -> Vec<Instr> {
    if threads.len() == 1 {
        return threads.into_iter().next().unwrap().stream;
    }
    // Split each thread stream into tile-sized chunks.
    let mut chunked: Vec<std::vec::IntoIter<Vec<Instr>>> = threads
        .into_iter()
        .map(|t| {
            let mut chunks = Vec::new();
            let mut cur = Vec::new();
            for i in t.stream {
                let is_store = matches!(i.op, Op::Store { .. });
                cur.push(i);
                if is_store {
                    chunks.push(std::mem::take(&mut cur));
                }
            }
            if !cur.is_empty() {
                chunks.push(cur);
            }
            chunks.into_iter()
        })
        .collect();

    let mut out = Vec::new();
    loop {
        let mut any = false;
        for it in &mut chunked {
            if let Some(chunk) = it.next() {
                out.extend(chunk);
                any = true;
            }
        }
        if !any {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::vta::simulate;

    fn task() -> Conv2dTask {
        Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1)
    }

    fn sw(tile_h: usize, tile_w: usize, ht: usize, ot: usize) -> SwConfig {
        SwConfig { tile_h, tile_w, h_threading: ht, oc_threading: ot }
    }

    #[test]
    fn lowering_runs_and_simulates() {
        let hw = VtaConfig::default();
        let k = lower_conv(&task(), &hw, &sw(8, 8, 1, 1)).unwrap();
        assert!(!k.stream.is_empty());
        let r = simulate(&k.stream, &hw).unwrap();
        assert!(r.cycles > 0);
        assert_eq!(k.macs, task().macs());
    }

    #[test]
    fn padded_macs_at_least_true_macs() {
        let hw = VtaConfig::default();
        let k = lower_conv(&task(), &hw, &sw(8, 8, 1, 1)).unwrap();
        assert!(k.padded_macs >= k.macs);
        assert!(k.occupancy() <= 1.0 && k.occupancy() > 0.0);
    }

    #[test]
    fn low_occupancy_on_mismatched_geometry() {
        // 3 input channels on a BLOCK_IN=64 array: occupancy must crater.
        let t = Conv2dTask::new(1, 3, 56, 56, 64, 3, 3, 1, 1);
        let hw = VtaConfig::with_gemm(1, 64, 16);
        let k = lower_conv(&t, &hw, &sw(8, 8, 1, 1)).unwrap();
        assert!(k.occupancy() < 0.1, "occupancy {}", k.occupancy());
    }

    #[test]
    fn threading_improves_makespan() {
        let hw = VtaConfig::default();
        let t = task();
        let k1 = lower_conv(&t, &hw, &sw(8, 8, 1, 1)).unwrap();
        let k2 = lower_conv(&t, &hw, &sw(8, 8, 2, 1)).unwrap();
        let r1 = simulate(&k1.stream, &hw).unwrap();
        let r2 = simulate(&k2.stream, &hw).unwrap();
        assert!(
            r2.cycles < r1.cycles,
            "2 vthreads {} should beat 1 vthread {}",
            r2.cycles,
            r1.cycles
        );
    }

    #[test]
    fn oversize_tile_rejected() {
        let hw = VtaConfig::default();
        let err = lower_conv(&task(), &hw, &sw(128, 8, 1, 1)).unwrap_err();
        assert!(matches!(err, CodegenError::TileTooLarge { .. }));
    }

    #[test]
    fn giant_tile_overflows_buffers() {
        // Full-plane tile on a big layer: input tile alone is
        // 224x224x16 = 802816 B >> 32 KiB.
        let t = Conv2dTask::new(1, 64, 224, 224, 64, 3, 3, 1, 1);
        let hw = VtaConfig::default();
        let err = lower_conv(&t, &hw, &sw(224, 224, 1, 1)).unwrap_err();
        assert!(
            matches!(err, CodegenError::InpOverflow { .. } | CodegenError::AccOverflow { .. }),
            "{err:?}"
        );
    }

    #[test]
    fn bad_hardware_rejected() {
        let hw = VtaConfig::with_gemm(3, 16, 16); // not a power of two
        let err = lower_conv(&task(), &hw, &sw(8, 8, 1, 1)).unwrap_err();
        assert!(matches!(err, CodegenError::BadHardware(_)));
    }

    #[test]
    fn all_streams_simulate_without_deadlock() {
        // Sweep a grid of configs; every successfully lowered stream must
        // simulate cleanly (token discipline is consistent).
        let t = task();
        for &(b, ci, co) in &[(1usize, 16usize, 16usize), (2, 32, 16), (1, 8, 64)] {
            let hw = VtaConfig::with_gemm(b, ci, co);
            for &(thh, tww) in &[(1usize, 1usize), (4, 4), (8, 14), (56, 56)] {
                for &(ht, ot) in &[(1usize, 1usize), (2, 1), (1, 2), (2, 2)] {
                    if let Ok(k) = lower_conv(&t, &hw, &sw(thh, tww, ht, ot)) {
                        let r = simulate(&k.stream, &hw);
                        assert!(r.is_ok(), "deadlock at hw={hw:?} sw={thh}x{tww} t{ht}/{ot}");
                    }
                }
            }
        }
    }

    #[test]
    fn bigger_array_fewer_cycles_when_utilized() {
        let t = Conv2dTask::new(1, 256, 14, 14, 256, 3, 3, 1, 1);
        let small = VtaConfig::with_gemm(1, 16, 16);
        let big = VtaConfig::with_gemm(1, 32, 32);
        let ks = lower_conv(&t, &small, &sw(7, 7, 2, 1)).unwrap();
        let kb = lower_conv(&t, &big, &sw(7, 7, 2, 1)).unwrap();
        let rs = simulate(&ks.stream, &small).unwrap();
        let rb = simulate(&kb.stream, &big).unwrap();
        assert!(rb.cycles < rs.cycles, "32x32 {} vs 16x16 {}", rb.cycles, rs.cycles);
    }

    #[test]
    fn vthreads_capped_at_two() {
        let hw = VtaConfig::default();
        let k = lower_conv(&task(), &hw, &sw(8, 8, 2, 2)).unwrap();
        assert_eq!(k.vthreads, 2);
    }

    #[test]
    fn edge_tiles_reduce_work() {
        // 56 not divisible by 10: edge tiles are partial; true macs must
        // still equal the task's exact MAC count.
        let hw = VtaConfig::default();
        let k = lower_conv(&task(), &hw, &sw(10, 10, 1, 1)).unwrap();
        assert_eq!(k.macs, task().macs());
    }
}
