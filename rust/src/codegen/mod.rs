//! The "MARL Code Generator" (Fig. 2): lower a convolution task plus a
//! decoded configuration Θ into an executable VTA++ instruction stream
//! τ(Θ), ready for the cycle simulator.

pub mod lower;
pub mod measure;

pub use lower::{lower_conv, CodegenError, LoweredKernel};
pub use measure::{measure_point, MeasureResult};
