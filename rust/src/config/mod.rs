//! Run configuration: JSON config files (Tables 4/5) + CLI overrides.
//!
//! `configs/arco.json`, `configs/autotvm.json` and `configs/chameleon.json`
//! ship the paper's hyper-parameters; every field is optional and falls
//! back to the compiled defaults, so a config file can pin just the knobs
//! an experiment cares about.

use crate::baselines::autotvm::AutoTvmParams;
use crate::baselines::chameleon::ChameleonParams;
use crate::costmodel::GbtParams;
use crate::marl::exploration::ExploreParams;
use crate::marl::strategy::ArcoParams;
use crate::tuner::TuneBudget;
use crate::util::json::{read_json_file, Json};
use std::path::Path;

/// Everything a tuning run needs.
#[derive(Debug, Clone, Copy)]
pub struct RunConfig {
    pub budget: TuneBudget,
    pub arco: ArcoParams,
    pub autotvm: AutoTvmParams,
    pub chameleon: ChameleonParams,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            budget: TuneBudget::default(),
            arco: ArcoParams::default(),
            autotvm: AutoTvmParams::default(),
            chameleon: ChameleonParams::default(),
            seed: 0xA2C0,
        }
    }
}

fn gbt_from_json(v: &Json, base: GbtParams) -> GbtParams {
    GbtParams {
        n_trees: v.get_usize("n_trees").unwrap_or(base.n_trees),
        max_depth: v.get_usize("max_depth").unwrap_or(base.max_depth),
        learning_rate: v.get_f64("learning_rate").unwrap_or(base.learning_rate),
        min_leaf: v.get_usize("min_leaf").unwrap_or(base.min_leaf),
        lambda: v.get_f64("lambda").unwrap_or(base.lambda),
    }
}

fn explore_from_json(v: &Json, base: ExploreParams) -> ExploreParams {
    ExploreParams {
        episodes: v.get_usize("episode_rl").unwrap_or(base.episodes),
        steps: v.get_usize("step_rl").unwrap_or(base.steps),
        population: v.get_usize("population").unwrap_or(base.population),
        ppo_epochs: v.get_usize("ppo_epochs").unwrap_or(base.ppo_epochs),
    }
}

impl RunConfig {
    /// Overlay one JSON config document onto `self`.
    pub fn apply_json(&mut self, doc: &Json) {
        if let Some(b) = doc.get("budget") {
            self.budget.total_measurements = b
                .get_usize("total_measurements")
                .unwrap_or(self.budget.total_measurements);
            self.budget.batch = b.get_usize("batch").unwrap_or(self.budget.batch);
            self.budget.workers = b.get_usize("workers").unwrap_or(self.budget.workers);
        }
        if let Some(a) = doc.get("arco") {
            self.arco.explore = explore_from_json(a, self.arco.explore);
            if let Some(g) = a.get("gbt") {
                self.arco.gbt = gbt_from_json(g, self.arco.gbt);
            }
            self.arco.gamma = a.get_f64("gamma").map(|x| x as f32).unwrap_or(self.arco.gamma);
            self.arco.lam = a.get_f64("lambda_gae").map(|x| x as f32).unwrap_or(self.arco.lam);
            self.arco.use_cs = a.get_bool("use_cs").unwrap_or(self.arco.use_cs);
        }
        if let Some(a) = doc.get("autotvm") {
            self.autotvm.n_sa = a.get_usize("n_sa").unwrap_or(self.autotvm.n_sa);
            self.autotvm.step_sa = a.get_usize("step_sa").unwrap_or(self.autotvm.step_sa);
            self.autotvm.eps_random =
                a.get_f64("eps_random").unwrap_or(self.autotvm.eps_random);
            if let Some(g) = a.get("gbt") {
                self.autotvm.gbt = gbt_from_json(g, self.autotvm.gbt);
            }
        }
        if let Some(c) = doc.get("chameleon") {
            self.chameleon.episodes = c.get_usize("episode_rl").unwrap_or(self.chameleon.episodes);
            self.chameleon.steps = c.get_usize("step_rl").unwrap_or(self.chameleon.steps);
            self.chameleon.population =
                c.get_usize("population").unwrap_or(self.chameleon.population);
            if let Some(g) = c.get("gbt") {
                self.chameleon.gbt = gbt_from_json(g, self.chameleon.gbt);
            }
        }
        if let Some(s) = doc.get("seed").and_then(Json::as_usize) {
            self.seed = s as u64;
        }
    }

    /// Load defaults then overlay a config file.
    pub fn from_file(path: &Path) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let doc = read_json_file(path)?;
        cfg.apply_json(&doc);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_tables_4_and_5() {
        let c = RunConfig::default();
        // Table 4/5: Σb = 1000 measurements, batch 64.
        assert_eq!(c.budget.total_measurements, 1000);
        assert_eq!(c.budget.batch, 64);
        // Table 5: n_sa = 128 parallel chains, step_sa = 500.
        assert_eq!(c.autotvm.n_sa, 128);
        assert_eq!(c.autotvm.step_sa, 500);
        // GBT batch-planning mode: xgb-reg equivalent with 64 trees.
        assert_eq!(c.autotvm.gbt.n_trees, 64);
    }

    #[test]
    fn json_overlay_partial() {
        let mut c = RunConfig::default();
        let doc = Json::parse(
            r#"{"budget": {"total_measurements": 256},
                "arco": {"episode_rl": 4, "use_cs": false},
                "autotvm": {"n_sa": 16},
                "seed": 7}"#,
        )
        .unwrap();
        c.apply_json(&doc);
        assert_eq!(c.budget.total_measurements, 256);
        assert_eq!(c.budget.batch, 64); // untouched
        assert_eq!(c.arco.explore.episodes, 4);
        assert!(!c.arco.use_cs);
        assert_eq!(c.autotvm.n_sa, 16);
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn shipped_configs_parse() {
        for name in ["arco", "autotvm", "chameleon", "quick"] {
            let path = std::path::Path::new("configs").join(format!("{name}.json"));
            if path.exists() {
                RunConfig::from_file(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
            }
        }
    }
}
