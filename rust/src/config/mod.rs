//! Run configuration: JSON config files (Tables 4/5) + CLI overrides.
//!
//! The compiled defaults are the paper's hyper-parameters; every JSON field
//! is optional, so a config file pins just the knobs an experiment cares
//! about. `configs/quick.json` (CI-scale budgets, cached simulator) and
//! `configs/smoke.json` (analytical backend) ship in-tree; an `"eval"`
//! section selects the measurement backend, cache and journal
//! (see [`EvalSettings`]).

use crate::baselines::autotvm::AutoTvmParams;
use crate::baselines::chameleon::ChameleonParams;
use crate::costmodel::GbtParams;
use crate::eval::{BackendKind, BackendSpec, EngineConfig, Placement};
use crate::marl::exploration::ExploreParams;
use crate::marl::strategy::ArcoParams;
use crate::tuner::{DriverOptions, Fidelity, TuneBudget};
use crate::util::json::{read_json_file, Json};
use std::path::{Path, PathBuf};

/// Measurement-engine settings (the file/CLI mirror of
/// [`crate::eval::EngineConfig`]; worker count lives in the budget).
#[derive(Debug, Clone)]
pub struct EvalSettings {
    /// Which [`crate::eval::MeasureBackend`] serves measurements: a
    /// built-in kind, or `remote:host:port[,...]` for a measurement fleet.
    pub backend: BackendSpec,
    /// Serve repeated configurations from the in-memory cache.
    pub cache: bool,
    /// Bound the cache to at most this many entries (LRU eviction);
    /// `None` keeps everything.
    pub cache_capacity: Option<usize>,
    /// Optional persistent measurement journal (JSONL), reused across runs.
    pub journal: Option<PathBuf>,
    /// How a remote measurement fleet splits batches across shards:
    /// `uniform` (reproducible default) or `weighted`
    /// (throughput-proportional, for heterogeneous fleets). Ignored by
    /// built-in local backends.
    pub placement: Placement,
}

impl Default for EvalSettings {
    fn default() -> Self {
        EvalSettings {
            backend: BackendSpec::Builtin(BackendKind::VtaSim),
            cache: true,
            cache_capacity: None,
            journal: None,
            placement: Placement::default(),
        }
    }
}

impl EvalSettings {
    /// Concrete engine configuration with the run's worker count.
    pub fn engine_config(&self, workers: usize) -> EngineConfig {
        EngineConfig {
            backend: self.backend.clone(),
            workers,
            cache: self.cache,
            cache_capacity: self.cache_capacity,
            journal: self.journal.clone(),
            warm_start: None,
            store: None,
            placement: self.placement,
        }
    }
}

/// Everything a tuning run needs.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub budget: TuneBudget,
    pub arco: ArcoParams,
    pub autotvm: AutoTvmParams,
    pub chameleon: ChameleonParams,
    pub eval: EvalSettings,
    /// Comparison-driver scheduling (serial vs concurrent multi-tenant,
    /// shared equal-budget ledger). CLI `--shared-budget` turns both on.
    pub driver: DriverOptions,
    pub seed: u64,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            budget: TuneBudget::default(),
            arco: ArcoParams::default(),
            autotvm: AutoTvmParams::default(),
            chameleon: ChameleonParams::default(),
            eval: EvalSettings::default(),
            driver: DriverOptions::default(),
            seed: 0xA2C0,
        }
    }
}

fn gbt_from_json(v: &Json, base: GbtParams) -> GbtParams {
    GbtParams {
        n_trees: v.get_usize("n_trees").unwrap_or(base.n_trees),
        max_depth: v.get_usize("max_depth").unwrap_or(base.max_depth),
        learning_rate: v.get_f64("learning_rate").unwrap_or(base.learning_rate),
        min_leaf: v.get_usize("min_leaf").unwrap_or(base.min_leaf),
        lambda: v.get_f64("lambda").unwrap_or(base.lambda),
    }
}

fn explore_from_json(v: &Json, base: ExploreParams) -> ExploreParams {
    ExploreParams {
        episodes: v.get_usize("episode_rl").unwrap_or(base.episodes),
        steps: v.get_usize("step_rl").unwrap_or(base.steps),
        population: v.get_usize("population").unwrap_or(base.population),
        ppo_epochs: v.get_usize("ppo_epochs").unwrap_or(base.ppo_epochs),
    }
}

impl RunConfig {
    /// Overlay one JSON config document onto `self`.
    pub fn apply_json(&mut self, doc: &Json) {
        if let Some(b) = doc.get("budget") {
            self.budget.total_measurements = b
                .get_usize("total_measurements")
                .unwrap_or(self.budget.total_measurements);
            self.budget.batch = b.get_usize("batch").unwrap_or(self.budget.batch);
            self.budget.workers = b.get_usize("workers").unwrap_or(self.budget.workers);
            self.budget.pipeline_depth = b
                .get_usize("pipeline_depth")
                .unwrap_or(self.budget.pipeline_depth)
                .max(1);
            if let Some(name) = b.get_str("fidelity") {
                if let Some(f) = Fidelity::parse(name) {
                    self.budget.fidelity = f;
                } else {
                    crate::log_warn!(
                        "config",
                        "bad budget fidelity '{name}' (expected exact | \
                         screen:<keep>[:<explore>]); keeping {}",
                        self.budget.fidelity.describe()
                    );
                }
            }
        }
        if let Some(a) = doc.get("arco") {
            self.arco.explore = explore_from_json(a, self.arco.explore);
            if let Some(g) = a.get("gbt") {
                self.arco.gbt = gbt_from_json(g, self.arco.gbt);
            }
            self.arco.gamma = a.get_f64("gamma").map(|x| x as f32).unwrap_or(self.arco.gamma);
            self.arco.lam = a.get_f64("lambda_gae").map(|x| x as f32).unwrap_or(self.arco.lam);
            self.arco.use_cs = a.get_bool("use_cs").unwrap_or(self.arco.use_cs);
        }
        if let Some(a) = doc.get("autotvm") {
            self.autotvm.n_sa = a.get_usize("n_sa").unwrap_or(self.autotvm.n_sa);
            self.autotvm.step_sa = a.get_usize("step_sa").unwrap_or(self.autotvm.step_sa);
            self.autotvm.eps_random =
                a.get_f64("eps_random").unwrap_or(self.autotvm.eps_random);
            if let Some(g) = a.get("gbt") {
                self.autotvm.gbt = gbt_from_json(g, self.autotvm.gbt);
            }
        }
        if let Some(c) = doc.get("chameleon") {
            self.chameleon.episodes = c.get_usize("episode_rl").unwrap_or(self.chameleon.episodes);
            self.chameleon.steps = c.get_usize("step_rl").unwrap_or(self.chameleon.steps);
            self.chameleon.population =
                c.get_usize("population").unwrap_or(self.chameleon.population);
            if let Some(g) = c.get("gbt") {
                self.chameleon.gbt = gbt_from_json(g, self.chameleon.gbt);
            }
        }
        if let Some(e) = doc.get("eval") {
            if let Some(name) = e.get_str("backend") {
                if let Some(spec) = BackendSpec::parse(name) {
                    self.eval.backend = spec;
                } else {
                    crate::log_warn!(
                        "config",
                        "unknown eval backend '{name}' (known: {}, or remote:host:port[,...]); \
                         keeping {}",
                        BackendKind::known_names().join(", "),
                        self.eval.backend.describe()
                    );
                }
            }
            self.eval.cache = e.get_bool("cache").unwrap_or(self.eval.cache);
            if let Some(cap) = e.get_usize("cache_capacity") {
                self.eval.cache_capacity = Some(cap);
            }
            if let Some(path) = e.get_str("journal") {
                self.eval.journal = Some(PathBuf::from(path));
            }
            if let Some(name) = e.get_str("placement") {
                if let Some(p) = Placement::from_name(name) {
                    self.eval.placement = p;
                } else {
                    crate::log_warn!(
                        "config",
                        "unknown eval placement '{name}' (known: {}); keeping {}",
                        Placement::known_names().join(", "),
                        self.eval.placement.name()
                    );
                }
            }
        }
        if let Some(d) = doc.get("driver") {
            self.driver.concurrent = d.get_bool("concurrent").unwrap_or(self.driver.concurrent);
            self.driver.shared_budget =
                d.get_bool("shared_budget").unwrap_or(self.driver.shared_budget);
        }
        if let Some(s) = doc.get("seed").and_then(Json::as_usize) {
            self.seed = s as u64;
        }
    }

    /// Load defaults then overlay a config file.
    pub fn from_file(path: &Path) -> anyhow::Result<RunConfig> {
        let mut cfg = RunConfig::default();
        let doc = read_json_file(path)?;
        cfg.apply_json(&doc);
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_tables_4_and_5() {
        let c = RunConfig::default();
        // Table 4/5: Σb = 1000 measurements, batch 64.
        assert_eq!(c.budget.total_measurements, 1000);
        assert_eq!(c.budget.batch, 64);
        // Table 5: n_sa = 128 parallel chains, step_sa = 500.
        assert_eq!(c.autotvm.n_sa, 128);
        assert_eq!(c.autotvm.step_sa, 500);
        // GBT batch-planning mode: xgb-reg equivalent with 64 trees.
        assert_eq!(c.autotvm.gbt.n_trees, 64);
    }

    #[test]
    fn pipeline_depth_parses_and_clamps() {
        let mut c = RunConfig::default();
        assert_eq!(c.budget.pipeline_depth, 1, "serial is the reproducibility default");
        c.apply_json(&Json::parse(r#"{"budget": {"pipeline_depth": 4}}"#).unwrap());
        assert_eq!(c.budget.pipeline_depth, 4);
        // Partial overlay leaves it alone; zero clamps to serial.
        c.apply_json(&Json::parse(r#"{"budget": {"batch": 16}}"#).unwrap());
        assert_eq!(c.budget.pipeline_depth, 4);
        c.apply_json(&Json::parse(r#"{"budget": {"pipeline_depth": 0}}"#).unwrap());
        assert_eq!(c.budget.pipeline_depth, 1);
    }

    #[test]
    fn fidelity_overlays_and_rejects_bad_strings() {
        let mut c = RunConfig::default();
        assert_eq!(c.budget.fidelity, Fidelity::Exact, "exact is the reproducibility default");
        c.apply_json(&Json::parse(r#"{"budget": {"fidelity": "screen:0.25"}}"#).unwrap());
        assert_eq!(
            c.budget.fidelity,
            Fidelity::Screen { keep: 0.25, explore: crate::tuner::DEFAULT_EXPLORE_FRAC }
        );
        // Explicit exploration slice.
        c.apply_json(&Json::parse(r#"{"budget": {"fidelity": "screen:0.5:0.2"}}"#).unwrap());
        assert_eq!(c.budget.fidelity, Fidelity::Screen { keep: 0.5, explore: 0.2 });
        // Partial overlay leaves it alone; a bad string warns and keeps.
        c.apply_json(&Json::parse(r#"{"budget": {"batch": 16}}"#).unwrap());
        assert_eq!(c.budget.fidelity, Fidelity::Screen { keep: 0.5, explore: 0.2 });
        c.apply_json(&Json::parse(r#"{"budget": {"fidelity": "screen:2.0"}}"#).unwrap());
        assert_eq!(c.budget.fidelity, Fidelity::Screen { keep: 0.5, explore: 0.2 });
        c.apply_json(&Json::parse(r#"{"budget": {"fidelity": "exact"}}"#).unwrap());
        assert_eq!(c.budget.fidelity, Fidelity::Exact);
    }

    #[test]
    fn json_overlay_partial() {
        let mut c = RunConfig::default();
        let doc = Json::parse(
            r#"{"budget": {"total_measurements": 256},
                "arco": {"episode_rl": 4, "use_cs": false},
                "autotvm": {"n_sa": 16},
                "eval": {"backend": "analytical", "cache": false, "journal": "results/journal.json"},
                "seed": 7}"#,
        )
        .unwrap();
        c.apply_json(&doc);
        assert_eq!(c.budget.total_measurements, 256);
        assert_eq!(c.budget.batch, 64); // untouched
        assert_eq!(c.arco.explore.episodes, 4);
        assert!(!c.arco.use_cs);
        assert_eq!(c.autotvm.n_sa, 16);
        assert_eq!(c.eval.backend, BackendSpec::Builtin(BackendKind::Analytical));
        assert!(!c.eval.cache);
        assert_eq!(c.eval.journal.as_deref(), Some(Path::new("results/journal.json")));
        assert_eq!(c.seed, 7);
    }

    #[test]
    fn driver_options_overlay() {
        let mut c = RunConfig::default();
        assert!(!c.driver.concurrent);
        assert!(!c.driver.shared_budget);
        c.apply_json(
            &Json::parse(r#"{"driver": {"concurrent": true, "shared_budget": true}}"#).unwrap(),
        );
        assert!(c.driver.concurrent);
        assert!(c.driver.shared_budget);
        // Partial overlay leaves the other knob alone.
        c.apply_json(&Json::parse(r#"{"driver": {"concurrent": false}}"#).unwrap());
        assert!(!c.driver.concurrent);
        assert!(c.driver.shared_budget);
    }

    #[test]
    fn remote_backend_and_cache_capacity_parse() {
        let mut c = RunConfig::default();
        c.apply_json(
            &Json::parse(
                r#"{"eval": {"backend": "remote:10.0.0.1:4917,10.0.0.2:4917",
                             "cache_capacity": 4096, "placement": "weighted"}}"#,
            )
            .unwrap(),
        );
        assert_eq!(
            c.eval.backend,
            BackendSpec::Remote(vec!["10.0.0.1:4917".into(), "10.0.0.2:4917".into()])
        );
        assert_eq!(c.eval.cache_capacity, Some(4096));
        assert_eq!(c.eval.placement, Placement::Weighted);
        let ec = c.eval.engine_config(2);
        assert_eq!(ec.cache_capacity, Some(4096));
        assert_eq!(ec.placement, Placement::Weighted);
        assert!(ec.warm_start.is_none());
        // Unknown placement names are ignored, not fatal; uniform stays
        // the reproducibility default.
        let mut c2 = RunConfig::default();
        assert_eq!(c2.eval.placement, Placement::Uniform);
        c2.apply_json(&Json::parse(r#"{"eval": {"placement": "psychic"}}"#).unwrap());
        assert_eq!(c2.eval.placement, Placement::Uniform);
    }

    #[test]
    fn eval_defaults_are_cached_simulator() {
        let c = RunConfig::default();
        assert_eq!(c.eval.backend, BackendSpec::Builtin(BackendKind::VtaSim));
        assert!(c.eval.cache);
        assert!(c.eval.cache_capacity.is_none());
        assert!(c.eval.journal.is_none());
        let ec = c.eval.engine_config(3);
        assert_eq!(ec.workers, 3);
        assert!(ec.cache);
        // Unknown backend names are ignored, not fatal.
        let mut c2 = RunConfig::default();
        c2.apply_json(&Json::parse(r#"{"eval": {"backend": "quantum"}}"#).unwrap());
        assert_eq!(c2.eval.backend, BackendSpec::Builtin(BackendKind::VtaSim));
    }

    #[test]
    fn shipped_configs_parse() {
        for name in ["arco", "autotvm", "chameleon", "quick", "smoke", "pipelined"] {
            let path = std::path::Path::new("configs").join(format!("{name}.json"));
            if path.exists() {
                RunConfig::from_file(&path).unwrap_or_else(|e| panic!("{name}: {e}"));
            }
        }
    }
}
