//! Report emitters: CSV + markdown renderings of every paper table/figure,
//! written under `results/`.

use crate::eval::{EngineStats, LedgerStats};
use crate::tuner::{CompareReport, Framework, TraceFidelity};
use crate::util::json::Json;
use crate::workload::{model_by_name, model_names};
use std::fmt::Write as _;
use std::path::Path;

/// Write a string to `results/<name>`, creating directories.
pub fn write_result(name: &str, content: &str) -> anyhow::Result<std::path::PathBuf> {
    let path = Path::new("results").join(name);
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(&path, content)?;
    Ok(path)
}

/// Table 3: the model zoo.
pub fn table3_models() -> String {
    let mut s = String::from("| Network | Dataset | Number of Convolution Tasks | Conv GFLOPs |\n|---|---|---|---|\n");
    for name in model_names() {
        let m = model_by_name(name).unwrap();
        let _ = writeln!(
            s,
            "| {} | ImageNet | {} | {:.2} |",
            m.name,
            m.num_conv_tasks(),
            m.total_flops() as f64 / 1e9
        );
    }
    s
}

/// Table 6: mean inference times (seconds) per framework and model.
pub fn table6_inference(reports: &[CompareReport]) -> String {
    let frameworks = [Framework::AutoTvm, Framework::Chameleon, Framework::Arco];
    let mut s = String::from("| Model | AutoTVM | CHAMELEON | ARCO |\n|---|---|---|---|\n");
    for r in reports {
        let mut row = format!("| {} |", r.model);
        for f in frameworks {
            match r.outcome(f) {
                Some(o) => {
                    let _ = write!(row, " {:.5} |", o.inference_secs);
                }
                None => row.push_str(" - |"),
            }
        }
        let _ = writeln!(s, "{row}");
    }
    s
}

/// Fig. 5: throughput normalized to AutoTVM.
pub fn fig5_throughput(reports: &[CompareReport]) -> String {
    let frameworks = [Framework::AutoTvm, Framework::Chameleon, Framework::Arco];
    let mut s = String::from("model,framework,throughput_vs_autotvm\n");
    for r in reports {
        for f in frameworks {
            if let Some(rel) = r.throughput_vs_autotvm(f) {
                let _ = writeln!(s, "{},{},{:.4}", r.model, f.name(), rel);
            }
        }
    }
    s
}

/// Fig. 5 summary statistics (the abstract's headline numbers).
pub fn fig5_summary(reports: &[CompareReport]) -> String {
    let mut rels = Vec::new();
    for r in reports {
        if let Some(rel) = r.throughput_vs_autotvm(Framework::Arco) {
            rels.push(rel);
        }
    }
    let avg = crate::util::stats::mean(&rels);
    let max = rels.iter().cloned().fold(0.0f64, f64::max);
    format!(
        "ARCO throughput vs AutoTVM: average {:.3}x (paper: 1.17x), max improvement {:.2}% (paper: up to 37.95%)\n",
        avg,
        (max - 1.0) * 100.0
    )
}

/// Fig. 6: compilation (optimization) time per framework — modeled
/// time-to-parity with AutoTVM's final quality — plus ARCO's speedup
/// percentage, the number the paper reports as "up to 42.2%".
pub fn fig6_compile_time(reports: &[CompareReport]) -> String {
    let mut s =
        String::from("model,framework,compile_secs_to_parity,full_compile_secs,arco_speedup_vs_autotvm_pct\n");
    for r in reports {
        let auto = r.compile_secs_to_parity(Framework::AutoTvm);
        for o in &r.outcomes {
            let ttp = r.compile_secs_to_parity(o.framework);
            let speedup = match (auto, ttp) {
                (Some(a), Some(c)) if o.framework == Framework::Arco && a > 0.0 => {
                    format!("{:.1}", (1.0 - c / a) * 100.0)
                }
                _ => String::new(),
            };
            let _ = writeln!(
                s,
                "{},{},{:.3},{:.3},{}",
                r.model,
                o.framework.name(),
                ttp.unwrap_or(o.compile_secs),
                o.compile_secs,
                speedup
            );
        }
    }
    s
}

/// Fig. 7: convergence trace (best GFLOPS vs measurement count) for one
/// model's heaviest task under each framework. The x-axis counts
/// *simulator* measurements only — screened (analytical-tier) trace
/// entries are skipped so multi-fidelity curves stay comparable to exact
/// ones on the axis the paper plots.
pub fn fig7_convergence(report: &CompareReport) -> String {
    let mut s = String::from("framework,measurement,best_gflops\n");
    for o in &report.outcomes {
        // Heaviest task = most FLOPs-weighted: use the one with max
        // measurements (ties broken by first).
        if let Some(t) = o.tasks.iter().max_by_key(|t| t.result.trace.len()) {
            let mut measurement = 0usize;
            for e in &t.result.trace {
                if e.fidelity != TraceFidelity::Exact {
                    continue;
                }
                measurement += 1;
                let _ = writeln!(s, "{},{},{:.4}", o.framework.name(), measurement, e.best_gflops);
            }
        }
    }
    s
}

/// Fig. 4: measured configurations over time (before/after CS). Like
/// Fig. 7, only simulator-tier entries are plotted.
pub fn fig4_configs_over_time(
    label_a: &str,
    trace_a: &[crate::tuner::TraceEntry],
    label_b: &str,
    trace_b: &[crate::tuner::TraceEntry],
) -> String {
    let mut s = String::from("variant,measurement,at_secs,gflops,valid\n");
    for (label, trace) in [(label_a, trace_a), (label_b, trace_b)] {
        let mut measurement = 0usize;
        for e in trace {
            if e.fidelity != TraceFidelity::Exact {
                continue;
            }
            measurement += 1;
            let _ = writeln!(
                s,
                "{label},{},{:.4},{:.4},{}",
                measurement, e.at_secs, e.gflops, e.valid as u8
            );
        }
    }
    s
}

/// Ledger accounting table for a shared-budget run: what every
/// (framework, task) tenant was debited, split into freshly-simulated and
/// cache-served points ("measure once, charge everyone").
pub fn ledger_stats_md(stats: &LedgerStats) -> String {
    // The Screened column only appears when some account actually resolved
    // points at screening fidelity, so exact-mode reports stay
    // byte-identical to the pre-multi-fidelity rendering.
    let screening = stats.total_screened() > 0;
    let mut s = format!(
        "Shared measurement budget: {} points per (framework, task)\n\n",
        stats.per_task_points
    );
    if screening {
        s.push_str(
            "| Framework | Task | Charged | Fresh | Cache-served | Screened | Modeled HW (s) |\n\
             |---|---|---|---|---|---|---|\n",
        );
    } else {
        s.push_str(
            "| Framework | Task | Charged | Fresh | Cache-served | Modeled HW (s) |\n\
             |---|---|---|---|---|---|\n",
        );
    }
    for t in &stats.tenants {
        let screened_col = if screening {
            format!(" {} |", t.account.screened)
        } else {
            String::new()
        };
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {} |{screened_col} {:.3} |",
            t.framework,
            t.task,
            t.account.charged,
            t.account.fresh,
            t.account.cache_served,
            t.account.modeled_hw_secs
        );
    }
    let screened_total = if screening {
        format!(" {} |", stats.total_screened())
    } else {
        String::new()
    };
    let _ = writeln!(
        s,
        "| **total** | | {} | {} | {} |{screened_total} |",
        stats.total_charged(),
        stats.total_fresh(),
        stats.total_cache_served()
    );
    s
}

/// Fleet placement table for a remote-backend run: which shard served how
/// many points and batches, the service-time evidence behind weighted
/// placement, and each shard's warm-start coverage. Empty placement stats
/// (local backends) render nothing.
pub fn placement_md(mode: &str, stats: &EngineStats) -> String {
    if stats.placement.is_empty() {
        return String::new();
    }
    let mut s = format!(
        "Fleet placement ({mode}):\n\n\
         | Shard | Alive | Batches | Points | EWMA ms/point | Queue | Preloaded |\n\
         |---|---|---|---|---|---|---|\n"
    );
    for p in &stats.placement {
        let ewma = match p.ewma_secs_per_point {
            Some(secs) => format!("{:.3}", secs * 1e3),
            None => "-".to_string(),
        };
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {} | {} | {} |",
            p.addr,
            if p.alive { "yes" } else { "no" },
            p.batches,
            p.points,
            ewma,
            p.queue_depth,
            p.preloaded
        );
    }
    s
}

/// JSON dump of a comparison (machine-readable companion of the tables).
pub fn compare_json(reports: &[CompareReport]) -> Json {
    Json::Arr(
        reports
            .iter()
            .map(|r| {
                let mut fields = vec![
                    ("model", Json::str(r.model.clone())),
                    (
                        "outcomes",
                        Json::Arr(
                            r.outcomes
                                .iter()
                                .map(|o| {
                                    let mut obj = Json::obj(vec![
                                        ("framework", Json::str(o.framework.name())),
                                        ("inference_secs", Json::num(o.inference_secs)),
                                        ("compile_secs", Json::num(o.compile_secs)),
                                        ("measurements", Json::num(o.measurements as f64)),
                                        ("fresh", Json::num(o.fresh as f64)),
                                        ("cache_served", Json::num(o.cache_served as f64)),
                                        ("throughput", Json::num(o.throughput())),
                                    ]);
                                    // Additive: only rendered when the run
                                    // actually screened, keeping exact-mode
                                    // dumps byte-identical.
                                    if o.screened > 0 {
                                        obj.set("screened", Json::num(o.screened as f64));
                                    }
                                    obj
                                })
                                .collect(),
                        ),
                    ),
                ];
                if let Some(ledger) = &r.ledger {
                    fields.push(("ledger", ledger.to_json()));
                }
                Json::obj(fields)
            })
            .collect(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tuner::{compare_frameworks, TuneBudget};
    use crate::workload::model_by_name;

    #[test]
    fn table3_contains_all_models_and_counts() {
        let t = table3_models();
        assert!(t.contains("| resnet34 | ImageNet | 33 |"));
        assert!(t.contains("| alexnet | ImageNet | 5 |"));
        assert!(t.contains("| vgg19 | ImageNet | 16 |"));
    }

    #[test]
    fn placement_md_renders_shards_or_nothing() {
        use crate::eval::ShardPlacement;
        let mut stats = EngineStats::default();
        assert!(placement_md("uniform", &stats).is_empty());
        stats.placement = vec![
            ShardPlacement {
                addr: "10.0.0.1:4917".into(),
                alive: true,
                batches: 4,
                points: 96,
                ewma_secs_per_point: Some(0.0021),
                queue_depth: 1,
                preloaded: 64,
            },
            ShardPlacement {
                addr: "10.0.0.2:4917".into(),
                alive: false,
                batches: 1,
                points: 8,
                ewma_secs_per_point: None,
                queue_depth: 0,
                preloaded: 0,
            },
        ];
        let md = placement_md("weighted", &stats);
        assert!(md.contains("Fleet placement (weighted)"));
        assert!(md.contains("| 10.0.0.1:4917 | yes | 4 | 96 | 2.100 | 1 | 64 |"));
        assert!(md.contains("| 10.0.0.2:4917 | no | 1 | 8 | - | 0 | 0 |"));
    }

    #[test]
    fn ledger_stats_render() {
        use crate::eval::{BudgetLedger, Origin};
        let ledger = BudgetLedger::new(4);
        ledger.charge("autotvm", "t0", 4);
        ledger.settle("autotvm", "t0", &[Origin::Fresh; 4], 1.25);
        ledger.charge("arco", "t0", 4);
        ledger.settle("arco", "t0", &[Origin::Cached; 4], 1.25);
        let md = ledger_stats_md(&ledger.stats());
        assert!(md.contains("4 points per (framework, task)"));
        assert!(md.contains("| autotvm | t0 | 4 | 4 | 0 |"));
        assert!(md.contains("| arco | t0 | 4 | 0 | 4 |"));
        assert!(md.contains("| **total** | | 8 | 4 | 4 | |"));
        assert!(!md.contains("Screened"), "exact-mode ledger table must be unchanged");
    }

    #[test]
    fn ledger_stats_render_screened_column_when_screening_ran() {
        use crate::eval::{BudgetLedger, Origin};
        let ledger = BudgetLedger::new(8);
        ledger.charge("arco", "t0", 8);
        ledger.charge_screen("arco", "t0", 6, 1e-6);
        ledger.settle("arco", "t0", &[Origin::Fresh; 2], 0.5);
        let md = ledger_stats_md(&ledger.stats());
        assert!(md.contains("| Framework | Task | Charged | Fresh | Cache-served | Screened | Modeled HW (s) |"));
        assert!(md.contains("| arco | t0 | 8 | 2 | 0 | 6 | 0.500 |"));
        assert!(md.contains("| **total** | | 8 | 2 | 0 | 6 | |"));
    }

    #[test]
    fn reports_render_from_real_run() {
        let model = model_by_name("alexnet").unwrap();
        let budget = TuneBudget { total_measurements: 32, batch: 16, workers: 2, ..Default::default() };
        let report = compare_frameworks(
            &[Framework::AutoTvm, Framework::Chameleon, Framework::Arco],
            &model,
            budget,
            true,
            1,
        )
        .unwrap();
        let reports = vec![report];

        let t6 = table6_inference(&reports);
        assert!(t6.contains("alexnet"));
        assert!(t6.lines().count() >= 3);

        let f5 = fig5_throughput(&reports);
        assert!(f5.contains("arco"));
        assert_eq!(f5.lines().count(), 1 + 3);

        let f6 = fig6_compile_time(&reports);
        assert!(f6.contains("compile_secs"));

        let f7 = fig7_convergence(&reports[0]);
        assert!(f7.lines().count() > 10);

        let summary = fig5_summary(&reports);
        assert!(summary.contains("ARCO throughput"));

        let json = compare_json(&reports);
        assert!(json.dump().contains("inference_secs"));
    }
}
