//! Small numeric/statistics helpers shared by the tuners, cost models and
//! report generators.

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Median (average of middle two for even lengths). 0.0 for empty input.
pub fn median(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let n = v.len();
    if n % 2 == 1 {
        v[n / 2]
    } else {
        0.5 * (v[n / 2 - 1] + v[n / 2])
    }
}

/// Linear-interpolated percentile, `q` in [0, 100].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap_or(std::cmp::Ordering::Equal));
    let pos = (q / 100.0).clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Geometric mean of positive values (non-positive entries are skipped).
pub fn geomean(xs: &[f64]) -> f64 {
    let logs: Vec<f64> = xs.iter().filter(|x| **x > 0.0).map(|x| x.ln()).collect();
    if logs.is_empty() {
        0.0
    } else {
        (logs.iter().sum::<f64>() / logs.len() as f64).exp()
    }
}

/// Numerically-stable softmax.
pub fn softmax(xs: &[f64]) -> Vec<f64> {
    if xs.is_empty() {
        return Vec::new();
    }
    let max = xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let exps: Vec<f64> = xs.iter().map(|x| (x - max).exp()).collect();
    let sum: f64 = exps.iter().sum();
    if sum <= 0.0 || !sum.is_finite() {
        // Degenerate input (all -inf): uniform.
        return vec![1.0 / xs.len() as f64; xs.len()];
    }
    exps.iter().map(|e| e / sum).collect()
}

/// Index of the maximum element (first on ties); None for empty input.
pub fn argmax(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if best.map_or(true, |(_, b)| x > b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

/// Index of the minimum element (first on ties); None for empty input.
pub fn argmin(xs: &[f64]) -> Option<usize> {
    let mut best: Option<(usize, f64)> = None;
    for (i, &x) in xs.iter().enumerate() {
        if best.map_or(true, |(_, b)| x < b) {
            best = Some((i, x));
        }
    }
    best.map(|(i, _)| i)
}

/// Pearson correlation coefficient; 0.0 if degenerate.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    if xs.len() < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut num = 0.0;
    let mut dx = 0.0;
    let mut dy = 0.0;
    for (x, y) in xs.iter().zip(ys) {
        num += (x - mx) * (y - my);
        dx += (x - mx) * (x - mx);
        dy += (y - my) * (y - my);
    }
    if dx <= 0.0 || dy <= 0.0 {
        0.0
    } else {
        num / (dx.sqrt() * dy.sqrt())
    }
}

/// Exponential moving average over a series (alpha = smoothing weight of the
/// new sample). Used for the convergence traces in Fig 7.
pub fn ema(xs: &[f64], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(xs.len());
    let mut acc = None;
    for &x in xs {
        let next = match acc {
            None => x,
            Some(prev) => alpha * x + (1.0 - alpha) * prev,
        };
        acc = Some(next);
        out.push(next);
    }
    out
}

/// Running maximum (best-so-far curve).
pub fn running_max(xs: &[f64]) -> Vec<f64> {
    let mut best = f64::NEG_INFINITY;
    xs.iter()
        .map(|&x| {
            best = best.max(x);
            best
        })
        .collect()
}

/// Integer ceiling division.
#[inline]
pub fn ceil_div(a: usize, b: usize) -> usize {
    assert!(b > 0);
    a.div_ceil(b)
}

/// Round `a` up to the next multiple of `b`.
#[inline]
pub fn round_up(a: usize, b: usize) -> usize {
    ceil_div(a, b) * b
}

/// All divisor-factorizations used to build tiling knob candidates:
/// the sorted divisors of `n`.
pub fn divisors(n: usize) -> Vec<usize> {
    assert!(n > 0);
    let mut small = Vec::new();
    let mut large = Vec::new();
    let mut d = 1;
    while d * d <= n {
        if n % d == 0 {
            small.push(d);
            if d != n / d {
                large.push(n / d);
            }
        }
        d += 1;
    }
    large.reverse();
    small.extend(large);
    small
}

/// Powers of two `<= n` (at least `[1]`).
pub fn pow2_upto(n: usize) -> Vec<usize> {
    let mut v = vec![1usize];
    while v.last().unwrap() * 2 <= n {
        let next = v.last().unwrap() * 2;
        v.push(next);
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_median_percentile() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(mean(&xs), 2.5);
        assert_eq!(median(&xs), 2.5);
        assert_eq!(median(&[3.0, 1.0, 2.0]), 2.0);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((percentile(&xs, 50.0) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(median(&[]), 0.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
        assert_eq!(argmax(&[]), None);
        assert!(softmax(&[]).is_empty());
    }

    #[test]
    fn softmax_sums_to_one_and_orders() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        assert!((p.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!(p[2] > p[1] && p[1] > p[0]);
        // Stability for large inputs.
        let p = softmax(&[1000.0, 1000.0]);
        assert!((p[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn softmax_neg_inf_uniform() {
        let p = softmax(&[f64::NEG_INFINITY, f64::NEG_INFINITY]);
        assert!((p[0] - 0.5).abs() < 1e-12);
    }

    #[test]
    fn argminmax() {
        assert_eq!(argmax(&[1.0, 5.0, 3.0]), Some(1));
        assert_eq!(argmin(&[1.0, 5.0, 3.0]), Some(0));
        assert_eq!(argmax(&[2.0, 2.0]), Some(0));
    }

    #[test]
    fn pearson_perfect() {
        let xs = [1.0, 2.0, 3.0];
        let ys = [2.0, 4.0, 6.0];
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        let neg = [-2.0, -4.0, -6.0];
        assert!((pearson(&xs, &neg) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 0.0, 8.0]) - 4.0).abs() < 1e-12); // zero skipped
    }

    #[test]
    fn running_max_monotone() {
        assert_eq!(running_max(&[1.0, 3.0, 2.0, 5.0]), vec![1.0, 3.0, 3.0, 5.0]);
    }

    #[test]
    fn ema_first_is_sample() {
        let y = ema(&[10.0, 0.0], 0.5);
        assert_eq!(y[0], 10.0);
        assert_eq!(y[1], 5.0);
    }

    #[test]
    fn divisors_of_12() {
        assert_eq!(divisors(12), vec![1, 2, 3, 4, 6, 12]);
        assert_eq!(divisors(1), vec![1]);
        assert_eq!(divisors(7), vec![1, 7]);
    }

    #[test]
    fn pow2_list() {
        assert_eq!(pow2_upto(16), vec![1, 2, 4, 8, 16]);
        assert_eq!(pow2_upto(1), vec![1]);
        assert_eq!(pow2_upto(6), vec![1, 2, 4]);
    }

    #[test]
    fn ceil_div_round_up() {
        assert_eq!(ceil_div(7, 2), 4);
        assert_eq!(ceil_div(8, 2), 4);
        assert_eq!(round_up(5, 4), 8);
    }
}
