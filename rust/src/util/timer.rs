//! Wall-clock timing helpers and a hierarchical phase profiler used by the
//! tuning orchestrator (compilation-time accounting for Fig 6) and the bench
//! harness.

use std::collections::HashMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug, Clone)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Accumulates named phase durations; thread-unaware by design (each tuner
/// owns one and the orchestrator merges them).
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    totals: HashMap<String, Duration>,
    counts: HashMap<String, u64>,
    order: Vec<String>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a phase name.
    pub fn time<T>(&mut self, phase: &str, f: impl FnOnce() -> T) -> T {
        let sw = Stopwatch::start();
        let out = f();
        self.add(phase, sw.elapsed());
        out
    }

    /// Add an externally measured duration.
    pub fn add(&mut self, phase: &str, d: Duration) {
        if !self.totals.contains_key(phase) {
            self.order.push(phase.to_string());
        }
        *self.totals.entry(phase.to_string()).or_default() += d;
        *self.counts.entry(phase.to_string()).or_default() += 1;
    }

    /// Merge another timer into this one (phase-wise sums).
    pub fn merge(&mut self, other: &PhaseTimer) {
        for phase in &other.order {
            self.add(phase, other.totals[phase]);
            // add() bumps count by one; fix up to the real count.
            let c = self.counts.get_mut(phase).unwrap();
            *c = *c - 1 + other.counts[phase];
        }
    }

    pub fn total(&self, phase: &str) -> Duration {
        self.totals.get(phase).copied().unwrap_or_default()
    }

    pub fn total_secs(&self, phase: &str) -> f64 {
        self.total(phase).as_secs_f64()
    }

    pub fn count(&self, phase: &str) -> u64 {
        self.counts.get(phase).copied().unwrap_or(0)
    }

    pub fn grand_total(&self) -> Duration {
        self.totals.values().sum()
    }

    /// Phases in first-seen order with (total, count).
    pub fn phases(&self) -> Vec<(&str, Duration, u64)> {
        self.order
            .iter()
            .map(|p| (p.as_str(), self.totals[p], self.counts[p]))
            .collect()
    }

    /// Human-readable summary table.
    pub fn summary(&self) -> String {
        let mut s = String::new();
        let grand = self.grand_total().as_secs_f64().max(1e-12);
        for (phase, total, count) in self.phases() {
            let secs = total.as_secs_f64();
            s.push_str(&format!(
                "{phase:<28} {secs:>10.3}s  {:>5.1}%  x{count}\n",
                100.0 * secs / grand
            ));
        }
        s
    }
}

/// Format a duration compactly for reports ("1.23s", "45ms", "12.3us").
pub fn fmt_duration(d: Duration) -> String {
    let s = d.as_secs_f64();
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.2}us", s * 1e6)
    } else {
        format!("{:.0}ns", s * 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_advances() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(sw.elapsed_secs() > 0.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut t = PhaseTimer::new();
        t.add("measure", Duration::from_millis(10));
        t.add("measure", Duration::from_millis(20));
        t.add("plan", Duration::from_millis(5));
        assert_eq!(t.count("measure"), 2);
        assert_eq!(t.total("measure"), Duration::from_millis(30));
        assert_eq!(t.grand_total(), Duration::from_millis(35));
        let phases: Vec<&str> = t.phases().iter().map(|(p, _, _)| *p).collect();
        assert_eq!(phases, vec!["measure", "plan"]);
    }

    #[test]
    fn merge_sums_counts() {
        let mut a = PhaseTimer::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimer::new();
        b.add("x", Duration::from_millis(2));
        b.add("x", Duration::from_millis(2));
        b.add("y", Duration::from_millis(3));
        a.merge(&b);
        assert_eq!(a.count("x"), 3);
        assert_eq!(a.total("x"), Duration::from_millis(5));
        assert_eq!(a.total("y"), Duration::from_millis(3));
    }

    #[test]
    fn time_returns_value() {
        let mut t = PhaseTimer::new();
        let v = t.time("f", || 42);
        assert_eq!(v, 42);
        assert_eq!(t.count("f"), 1);
    }

    #[test]
    fn fmt_ranges() {
        assert_eq!(fmt_duration(Duration::from_secs(2)), "2.00s");
        assert_eq!(fmt_duration(Duration::from_millis(45)), "45.00ms");
        assert_eq!(fmt_duration(Duration::from_micros(12)), "12.00us");
        assert_eq!(fmt_duration(Duration::from_nanos(500)), "500ns");
    }
}
