//! Command-line argument parsing (clap is not in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, short `-k value`, and
//! positional arguments, with typed accessors and a generated usage string.

use std::collections::HashMap;

/// Declarative option spec used for help text and validation.
#[derive(Debug, Clone)]
pub struct OptSpec {
    pub long: &'static str,
    pub short: Option<char>,
    pub takes_value: bool,
    pub help: &'static str,
    pub default: Option<&'static str>,
}

/// Parsed arguments.
#[derive(Debug, Default, Clone)]
pub struct Args {
    values: HashMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

/// An argument parser for one (sub)command.
#[derive(Debug, Clone)]
pub struct Cli {
    name: String,
    about: String,
    opts: Vec<OptSpec>,
}

impl Cli {
    pub fn new(name: &str, about: &str) -> Self {
        Cli { name: name.to_string(), about: about.to_string(), opts: Vec::new() }
    }

    /// Register a value-taking option.
    pub fn opt(mut self, long: &'static str, short: Option<char>, help: &'static str, default: Option<&'static str>) -> Self {
        self.opts.push(OptSpec { long, short, takes_value: true, help, default });
        self
    }

    /// Register a boolean flag.
    pub fn flag(mut self, long: &'static str, short: Option<char>, help: &'static str) -> Self {
        self.opts.push(OptSpec { long, short, takes_value: false, help, default: None });
        self
    }

    fn find_long(&self, long: &str) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.long == long)
    }

    fn find_short(&self, short: char) -> Option<&OptSpec> {
        self.opts.iter().find(|o| o.short == Some(short))
    }

    /// Usage/help text.
    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.name, self.about);
        for o in &self.opts {
            let short = o.short.map(|c| format!("-{c}, ")).unwrap_or_else(|| "    ".into());
            let val = if o.takes_value { " <value>" } else { "" };
            let def = o.default.map(|d| format!(" [default: {d}]")).unwrap_or_default();
            s.push_str(&format!("  {short}--{}{val}\n        {}{def}\n", o.long, o.help));
        }
        s
    }

    /// Parse a raw token list (not including argv[0]).
    pub fn parse(&self, tokens: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for spec in &self.opts {
            if let Some(d) = spec.default {
                args.values.insert(spec.long.to_string(), d.to_string());
            }
        }
        let mut it = tokens.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k, Some(v.to_string())),
                    None => (rest, None),
                };
                let spec = self
                    .find_long(key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let val = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| format!("option --{key} requires a value"))?,
                    };
                    args.values.insert(key.to_string(), val);
                } else {
                    if inline.is_some() {
                        return Err(format!("flag --{key} does not take a value"));
                    }
                    args.flags.push(key.to_string());
                }
            } else if let Some(rest) = tok.strip_prefix('-') {
                if rest.is_empty() || rest.chars().next().unwrap().is_ascii_digit() {
                    // A lone "-" or negative number: positional.
                    args.positional.push(tok.clone());
                    continue;
                }
                for (i, c) in rest.chars().enumerate() {
                    let spec = self
                        .find_short(c)
                        .ok_or_else(|| format!("unknown option -{c}\n\n{}", self.usage()))?;
                    if spec.takes_value {
                        // Value must follow; either glued or next token.
                        let glued: String = rest.chars().skip(i + 1).collect();
                        let val = if !glued.is_empty() {
                            glued
                        } else {
                            it.next()
                                .cloned()
                                .ok_or_else(|| format!("option -{c} requires a value"))?
                        };
                        args.values.insert(spec.long.to_string(), val);
                        break;
                    } else {
                        args.flags.push(spec.long.to_string());
                    }
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, key: &str) -> Option<&str> {
        self.values.get(key).map(|s| s.as_str())
    }

    pub fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<usize>()
                .map(Some)
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_u64(&self, key: &str) -> Result<Option<u64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<u64>()
                .map(Some)
                .map_err(|_| format!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<f64>()
                .map(Some)
                .map_err(|_| format!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli() -> Cli {
        Cli::new("tune", "tune a model")
            .opt("model", Some('m'), "model name", Some("resnet18"))
            .opt("trials", Some('n'), "measurement budget", None)
            .flag("verbose", Some('v'), "chatty output")
            .flag("no-cs", None, "disable confidence sampling")
    }

    fn toks(s: &[&str]) -> Vec<String> {
        s.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let a = cli().parse(&toks(&[])).unwrap();
        assert_eq!(a.get("model"), Some("resnet18"));
        assert_eq!(a.get("trials"), None);
    }

    #[test]
    fn long_forms() {
        let a = cli().parse(&toks(&["--model", "vgg16", "--trials=500", "--verbose"])).unwrap();
        assert_eq!(a.get("model"), Some("vgg16"));
        assert_eq!(a.get_usize("trials").unwrap(), Some(500));
        assert!(a.has_flag("verbose"));
        assert!(!a.has_flag("no-cs"));
    }

    #[test]
    fn short_forms_and_glued() {
        let a = cli().parse(&toks(&["-m", "alexnet", "-n128", "-v"])).unwrap();
        assert_eq!(a.get("model"), Some("alexnet"));
        assert_eq!(a.get_usize("trials").unwrap(), Some(128));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn positionals_pass_through() {
        let a = cli().parse(&toks(&["run", "--verbose", "extra"])).unwrap();
        assert_eq!(a.positional(), &["run".to_string(), "extra".to_string()]);
    }

    #[test]
    fn unknown_rejected() {
        assert!(cli().parse(&toks(&["--bogus"])).is_err());
        assert!(cli().parse(&toks(&["-z"])).is_err());
    }

    #[test]
    fn missing_value_rejected() {
        assert!(cli().parse(&toks(&["--trials"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(cli().parse(&toks(&["--verbose=1"])).is_err());
    }

    #[test]
    fn bad_int_reported() {
        let a = cli().parse(&toks(&["--trials", "abc"])).unwrap();
        assert!(a.get_usize("trials").is_err());
    }

    #[test]
    fn usage_mentions_options() {
        let u = cli().usage();
        assert!(u.contains("--model"));
        assert!(u.contains("--no-cs"));
    }
}
