//! Zero-copy streaming JSON reader and direct-to-`Write` serializer.
//!
//! The tree API in the parent module builds a `Json` value for every
//! document, which is the right shape for configs and reports but a tax on
//! the measurement hot paths: the wire protocol and the journal mostly
//! *route* records (dedup on identity, forward bytes) without inspecting
//! every field. This module provides the allocation-light alternative both
//! are built on:
//!
//! - [`Reader`]: a pull-style tokenizer over a borrowed `&str`. Strings
//!   that contain no escapes are returned as `Cow::Borrowed` slices of the
//!   input; only escaped strings allocate. Parsing is iterative with an
//!   explicit container stack (capped at [`MAX_DEPTH`]), so adversarially
//!   deep documents fail with an error instead of overflowing the thread
//!   stack — these parsers face untrusted network input.
//! - [`Reader::skip_value`]: lazy field extraction — skip a whole subtree
//!   without materializing it, so a journal line can yield just its
//!   `(backend, task, knobs)` identity.
//! - [`StreamWriter`]: a push serializer writing straight into any
//!   `io::Write` (socket buffer, `Vec<u8>`), managing commas and colons.
//!   Its output is byte-identical to `Json::dump()` for the same value,
//!   which is what keeps new journals hash-compatible with old ones.
//! - [`Num`]: numbers are handed out as raw slices and converted lazily,
//!   so integers up to the full `u64`/`i64` range round-trip exactly
//!   (the `f64` tree representation silently corrupts integers > 2^53).
//!
//! The tree parser in the parent module is itself implemented on this
//! reader, so there is exactly one grammar implementation in the crate.

use std::borrow::Cow;
use std::io::{self, Write};

use super::JsonError;

/// Container nesting limit for the reader. Deeper input is a parse error,
/// never a stack overflow: the reader holds its state on the heap.
pub const MAX_DEPTH: usize = 512;

/// A JSON number, kept as the raw input slice and converted on demand.
///
/// Deferring conversion is both the zero-copy win (most journal fields are
/// skipped, not read) and the integer-fidelity fix: a pure-digit slice is
/// parsed directly as `u64`/`i64`, bypassing the lossy `f64` detour.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Num<'a> {
    raw: &'a str,
}

impl<'a> Num<'a> {
    /// The raw number text exactly as it appeared in the input.
    pub fn raw(&self) -> &'a str {
        self.raw
    }

    pub fn as_f64(&self) -> f64 {
        self.raw.parse().unwrap_or(f64::NAN)
    }

    /// Lossless for every `u64`, including values above 2^53; falls back
    /// to the `f64` interpretation for `1e3`-style spellings.
    pub fn as_u64(&self) -> Option<u64> {
        if let Ok(v) = self.raw.parse::<u64>() {
            return Some(v);
        }
        let x = self.as_f64();
        if x.is_finite() && x >= 0.0 && x.fract() == 0.0 && x < 18446744073709551616.0 {
            Some(x as u64)
        } else {
            None
        }
    }

    /// Lossless for every `i64`; falls back to the `f64` interpretation.
    pub fn as_i64(&self) -> Option<i64> {
        if let Ok(v) = self.raw.parse::<i64>() {
            return Some(v);
        }
        let x = self.as_f64();
        if x.is_finite()
            && x.fract() == 0.0
            && x >= -9223372036854775808.0
            && x < 9223372036854775808.0
        {
            Some(x as i64)
        } else {
            None
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_u64().and_then(|v| usize::try_from(v).ok())
    }
}

/// One parse event from [`Reader::next`].
#[derive(Debug, Clone, PartialEq)]
pub enum Token<'a> {
    ObjStart,
    ObjEnd,
    ArrStart,
    ArrEnd,
    /// An object key; the reader has already consumed the `:`.
    Key(Cow<'a, str>),
    Str(Cow<'a, str>),
    Num(Num<'a>),
    Bool(bool),
    Null,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum St {
    /// Expecting a value (top level, after a key, or after `,` in an array).
    Value,
    /// Just opened an array: an element or `]`.
    ElemOrEnd,
    /// Just opened an object: a key or `}`.
    FirstKey,
    /// After `,` in an object: a key.
    NextKey,
    /// After a value inside a container: `,` or the closer.
    PostValue,
    /// Top-level value complete; only whitespace may remain.
    Done,
}

/// Pull-style JSON tokenizer over a borrowed string.
pub struct Reader<'a> {
    text: &'a str,
    bytes: &'a [u8],
    pos: usize,
    /// Open containers, `true` = object.
    stack: Vec<bool>,
    state: St,
}

impl<'a> Reader<'a> {
    pub fn new(text: &'a str) -> Self {
        Reader { text, bytes: text.as_bytes(), pos: 0, stack: Vec::new(), state: St::Value }
    }

    /// Byte offset of the read head (for error reporting).
    pub fn pos(&self) -> usize {
        self.pos
    }

    /// Current container nesting depth.
    pub fn depth(&self) -> usize {
        self.stack.len()
    }

    /// True when nothing but whitespace remains after a complete value.
    pub fn at_end(&mut self) -> bool {
        self.skip_ws();
        self.state == St::Done && self.pos == self.bytes.len()
    }

    /// Next token, `Ok(None)` at clean end of input. Trailing non-space
    /// characters after the top-level value are an error.
    pub fn next(&mut self) -> Result<Option<Token<'a>>, JsonError> {
        loop {
            self.skip_ws();
            match self.state {
                St::Done => {
                    return if self.pos == self.bytes.len() {
                        Ok(None)
                    } else {
                        Err(self.err("trailing characters"))
                    };
                }
                St::Value => return self.value_token().map(Some),
                St::ElemOrEnd => {
                    if self.peek() == Some(b']') {
                        self.pos += 1;
                        return self.close().map(Some);
                    }
                    self.state = St::Value;
                }
                St::FirstKey => {
                    if self.peek() == Some(b'}') {
                        self.pos += 1;
                        return self.close().map(Some);
                    }
                    return self.key_token().map(Some);
                }
                St::NextKey => return self.key_token().map(Some),
                St::PostValue => match (self.stack.last().copied(), self.peek()) {
                    (Some(true), Some(b',')) => {
                        self.pos += 1;
                        self.state = St::NextKey;
                    }
                    (Some(true), Some(b'}')) => {
                        self.pos += 1;
                        return self.close().map(Some);
                    }
                    (Some(true), _) => return Err(self.err("expected ',' or '}'")),
                    (Some(false), Some(b',')) => {
                        self.pos += 1;
                        self.state = St::Value;
                    }
                    (Some(false), Some(b']')) => {
                        self.pos += 1;
                        return self.close().map(Some);
                    }
                    (Some(false), _) => return Err(self.err("expected ',' or ']'")),
                    (None, _) => return Err(self.err("trailing characters")),
                },
            }
        }
    }

    /// `next()` flattened to an `Option` for hot-path parsers that treat
    /// any malformation as "not a record".
    pub fn next_token(&mut self) -> Option<Token<'a>> {
        self.next().ok().flatten()
    }

    /// Consume exactly one complete value (scalar or whole subtree)
    /// without materializing it. Must be called in value position, i.e.
    /// right after a [`Token::Key`] or between array elements.
    pub fn skip_value(&mut self) -> Result<(), JsonError> {
        let base = self.stack.len();
        match self.next()? {
            None => Err(self.err("expected a JSON value")),
            Some(Token::ObjEnd | Token::ArrEnd) => Err(self.err("expected a JSON value")),
            Some(Token::Key(_)) => self.skip_value(),
            Some(Token::ObjStart | Token::ArrStart) => self.skip_to_depth(base),
            Some(_) => Ok(()),
        }
    }

    /// Drain tokens until the container nesting returns to `base` — the
    /// complement of [`Self::skip_value`] when an opener has already been
    /// consumed.
    pub fn skip_to_depth(&mut self, base: usize) -> Result<(), JsonError> {
        while self.stack.len() > base {
            if self.next()?.is_none() {
                return Err(self.err("unterminated container"));
            }
        }
        Ok(())
    }

    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn push(&mut self, is_obj: bool) -> Result<(), JsonError> {
        if self.stack.len() >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        self.stack.push(is_obj);
        Ok(())
    }

    /// A container just closed: pop it and emit the matching end token.
    fn close(&mut self) -> Result<Token<'a>, JsonError> {
        let was_obj = self.stack.pop().unwrap_or(false);
        self.after_value();
        Ok(if was_obj { Token::ObjEnd } else { Token::ArrEnd })
    }

    fn after_value(&mut self) {
        self.state = if self.stack.is_empty() { St::Done } else { St::PostValue };
    }

    fn value_token(&mut self) -> Result<Token<'a>, JsonError> {
        match self.peek() {
            Some(b'{') => {
                self.pos += 1;
                self.push(true)?;
                self.state = St::FirstKey;
                Ok(Token::ObjStart)
            }
            Some(b'[') => {
                self.pos += 1;
                self.push(false)?;
                self.state = St::ElemOrEnd;
                Ok(Token::ArrStart)
            }
            Some(b'"') => {
                let s = self.string()?;
                self.after_value();
                Ok(Token::Str(s))
            }
            Some(b't') => {
                self.literal("true")?;
                self.after_value();
                Ok(Token::Bool(true))
            }
            Some(b'f') => {
                self.literal("false")?;
                self.after_value();
                Ok(Token::Bool(false))
            }
            Some(b'n') => {
                self.literal("null")?;
                self.after_value();
                Ok(Token::Null)
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => {
                let n = self.number()?;
                self.after_value();
                Ok(Token::Num(n))
            }
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn key_token(&mut self) -> Result<Token<'a>, JsonError> {
        let k = self.string()?;
        self.skip_ws();
        if self.peek() == Some(b':') {
            self.pos += 1;
        } else {
            return Err(self.err("expected ':'"));
        }
        self.state = St::Value;
        Ok(Token::Key(k))
    }

    fn literal(&mut self, lit: &str) -> Result<(), JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(())
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    /// Scan a string. The common no-escape case borrows straight from the
    /// input: the bounds are both at ASCII `"` bytes, so the slice is
    /// always on a char boundary of the (already valid UTF-8) input.
    fn string(&mut self) -> Result<Cow<'a, str>, JsonError> {
        if self.peek() != Some(b'"') {
            return Err(self.err("expected a string"));
        }
        self.pos += 1;
        let start = self.pos;
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    let end = self.pos;
                    self.pos += 1;
                    let s = self
                        .text
                        .get(start..end)
                        .ok_or_else(|| self.err("string not on a char boundary"))?;
                    return Ok(Cow::Borrowed(s));
                }
                Some(b'\\') => {
                    let prefix = self
                        .text
                        .get(start..self.pos)
                        .ok_or_else(|| self.err("string not on a char boundary"))?;
                    let mut s = String::with_capacity(prefix.len() + 16);
                    s.push_str(prefix);
                    return self.string_owned(s).map(Cow::Owned);
                }
                Some(_) => self.pos += 1,
            }
        }
    }

    /// Slow path after the first escape: decode the rest into `s`.
    fn string_owned(&mut self, mut s: String) -> Result<String, JsonError> {
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the whole sequence through.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    /// Scan a number and validate its shape structurally (the same set of
    /// spellings Rust's `f64` parser accepts for JSON-scannable text), but
    /// do NOT convert: [`Num`] converts lazily on demand.
    fn number(&mut self) -> Result<Num<'a>, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let int_digits = self.digits();
        let mut frac_digits = 0;
        if self.peek() == Some(b'.') {
            self.pos += 1;
            frac_digits = self.digits();
        }
        let mut exp_ok = true;
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            exp_ok = self.digits() > 0;
        }
        if int_digits + frac_digits == 0 || !exp_ok {
            return Err(self.err("bad number"));
        }
        let raw = self
            .text
            .get(start..self.pos)
            .ok_or_else(|| self.err("number not on a char boundary"))?;
        Ok(Num { raw })
    }

    fn digits(&mut self) -> usize {
        let start = self.pos;
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        self.pos - start
    }
}

/// Format an `f64` exactly like `Json::dump()` does: integral values below
/// 1e15 as plain integers, other finite values via Rust's shortest
/// round-trip `Display`, non-finite as `null`.
pub fn write_f64<W: Write>(w: &mut W, x: f64) -> io::Result<()> {
    if x.is_finite() && x.fract() == 0.0 && x.abs() < 1e15 {
        write!(w, "{}", x as i64)
    } else if x.is_finite() {
        write!(w, "{x}")
    } else {
        w.write_all(b"null")
    }
}

/// Write a JSON string literal, escaping exactly like `Json::dump()`:
/// `" \ \n \r \t` by name, other control bytes as `\u00XX`, everything
/// else (including multi-byte UTF-8) passed through raw. Unescaped runs
/// are written in single calls.
pub fn write_escaped<W: Write>(w: &mut W, s: &str) -> io::Result<()> {
    w.write_all(b"\"")?;
    let bytes = s.as_bytes();
    let mut run = 0usize;
    for (i, &b) in bytes.iter().enumerate() {
        if b != b'"' && b != b'\\' && b >= 0x20 {
            continue;
        }
        if run < i {
            w.write_all(&bytes[run..i])?;
        }
        match b {
            b'"' => w.write_all(b"\\\"")?,
            b'\\' => w.write_all(b"\\\\")?,
            b'\n' => w.write_all(b"\\n")?,
            b'\r' => w.write_all(b"\\r")?,
            b'\t' => w.write_all(b"\\t")?,
            _ => write!(w, "\\u{:04x}", b as u32)?,
        }
        run = i + 1;
    }
    w.write_all(&bytes[run..])?;
    w.write_all(b"\"")
}

/// Push-style serializer writing compact JSON straight into an
/// `io::Write`. Commas and the key/value colon are managed by the writer;
/// callers just emit structure. Output is byte-identical to
/// `Json::dump()` of the equivalent tree (modulo the deliberate exception
/// that `u64_val`/`i64_val` print integers above 2^53 exactly, where the
/// `f64` tree could not represent them in the first place).
pub struct StreamWriter<W: Write> {
    w: W,
    /// Per open container: has an entry been written yet (comma needed)?
    stack: Vec<bool>,
    /// A key was just written; the next value takes no separator.
    after_key: bool,
}

impl<W: Write> StreamWriter<W> {
    pub fn new(w: W) -> Self {
        StreamWriter { w, stack: Vec::new(), after_key: false }
    }

    pub fn into_inner(self) -> W {
        self.w
    }

    /// The underlying writer, e.g. to append a record separator `\n`.
    pub fn get_mut(&mut self) -> &mut W {
        &mut self.w
    }

    fn sep(&mut self) -> io::Result<()> {
        if self.after_key {
            self.after_key = false;
        } else if let Some(written) = self.stack.last_mut() {
            if *written {
                self.w.write_all(b",")?;
            }
            *written = true;
        }
        Ok(())
    }

    pub fn begin_obj(&mut self) -> io::Result<()> {
        self.sep()?;
        self.stack.push(false);
        self.w.write_all(b"{")
    }

    pub fn end_obj(&mut self) -> io::Result<()> {
        self.stack.pop();
        self.w.write_all(b"}")
    }

    pub fn begin_arr(&mut self) -> io::Result<()> {
        self.sep()?;
        self.stack.push(false);
        self.w.write_all(b"[")
    }

    pub fn end_arr(&mut self) -> io::Result<()> {
        self.stack.pop();
        self.w.write_all(b"]")
    }

    pub fn key(&mut self, k: &str) -> io::Result<()> {
        self.sep()?;
        write_escaped(&mut self.w, k)?;
        self.w.write_all(b":")?;
        self.after_key = true;
        Ok(())
    }

    pub fn str_val(&mut self, s: &str) -> io::Result<()> {
        self.sep()?;
        write_escaped(&mut self.w, s)
    }

    pub fn f64_val(&mut self, x: f64) -> io::Result<()> {
        self.sep()?;
        write_f64(&mut self.w, x)
    }

    /// Exact, full-range integer output (the >2^53 fidelity fix).
    pub fn u64_val(&mut self, x: u64) -> io::Result<()> {
        self.sep()?;
        write!(self.w, "{x}")
    }

    pub fn i64_val(&mut self, x: i64) -> io::Result<()> {
        self.sep()?;
        write!(self.w, "{x}")
    }

    pub fn usize_val(&mut self, x: usize) -> io::Result<()> {
        self.u64_val(x as u64)
    }

    pub fn bool_val(&mut self, b: bool) -> io::Result<()> {
        self.sep()?;
        self.w.write_all(if b { b"true" } else { b"false" })
    }

    pub fn null_val(&mut self) -> io::Result<()> {
        self.sep()?;
        self.w.write_all(b"null")
    }

    /// Splice pre-serialized JSON (e.g. a retained raw journal line) as
    /// one value. The caller guarantees `raw` is a complete JSON value.
    pub fn raw_val(&mut self, raw: &str) -> io::Result<()> {
        self.sep()?;
        self.w.write_all(raw.as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tokens(text: &str) -> Result<Vec<Token<'_>>, JsonError> {
        let mut r = Reader::new(text);
        let mut out = Vec::new();
        while let Some(t) = r.next()? {
            out.push(t);
        }
        Ok(out)
    }

    #[test]
    fn scalar_tokens() {
        assert_eq!(tokens("null").unwrap(), vec![Token::Null]);
        assert_eq!(tokens(" true ").unwrap(), vec![Token::Bool(true)]);
        assert_eq!(tokens("\"hi\"").unwrap(), vec![Token::Str(Cow::Borrowed("hi"))]);
        let ts = tokens("-12.5e3").unwrap();
        assert_eq!(ts.len(), 1);
        match &ts[0] {
            Token::Num(n) => {
                assert_eq!(n.raw(), "-12.5e3");
                assert_eq!(n.as_f64(), -12500.0);
            }
            t => panic!("unexpected token {t:?}"),
        }
    }

    #[test]
    fn object_walk_borrows_clean_strings() {
        let mut r = Reader::new(r#"{"name":"arco","esc":"a\nb","n":7}"#);
        assert_eq!(r.next().unwrap(), Some(Token::ObjStart));
        match r.next().unwrap() {
            Some(Token::Key(Cow::Borrowed(k))) => assert_eq!(k, "name"),
            t => panic!("key should borrow, got {t:?}"),
        }
        match r.next().unwrap() {
            Some(Token::Str(Cow::Borrowed(s))) => assert_eq!(s, "arco"),
            t => panic!("clean string should borrow, got {t:?}"),
        }
        assert_eq!(r.next().unwrap(), Some(Token::Key(Cow::Borrowed("esc"))));
        match r.next().unwrap() {
            Some(Token::Str(Cow::Owned(s))) => assert_eq!(s, "a\nb"),
            t => panic!("escaped string should own, got {t:?}"),
        }
        assert_eq!(r.next().unwrap(), Some(Token::Key(Cow::Borrowed("n"))));
        match r.next().unwrap() {
            Some(Token::Num(n)) => assert_eq!(n.as_u64(), Some(7)),
            t => panic!("unexpected {t:?}"),
        }
        assert_eq!(r.next().unwrap(), Some(Token::ObjEnd));
        assert_eq!(r.next().unwrap(), None);
        assert!(r.at_end());
    }

    #[test]
    fn skip_value_skips_subtrees() {
        let mut r = Reader::new(r#"{"skip":{"a":[1,2,{"b":null}]},"keep":42}"#);
        assert_eq!(r.next().unwrap(), Some(Token::ObjStart));
        assert_eq!(r.next().unwrap(), Some(Token::Key(Cow::Borrowed("skip"))));
        r.skip_value().unwrap();
        assert_eq!(r.next().unwrap(), Some(Token::Key(Cow::Borrowed("keep"))));
        match r.next().unwrap() {
            Some(Token::Num(n)) => assert_eq!(n.as_u64(), Some(42)),
            t => panic!("unexpected {t:?}"),
        }
        assert_eq!(r.next().unwrap(), Some(Token::ObjEnd));
        assert_eq!(r.next().unwrap(), None);
    }

    #[test]
    fn integers_above_2_53_roundtrip() {
        let big = (1u64 << 53) + 3;
        let text = format!("{big}");
        let mut r = Reader::new(&text);
        match r.next().unwrap() {
            Some(Token::Num(n)) => {
                assert_eq!(n.as_u64(), Some(big));
                // The f64 interpretation is lossy for the same input.
                assert_ne!(n.as_f64() as u64, big);
            }
            t => panic!("unexpected {t:?}"),
        }
        let mut buf = Vec::new();
        let mut w = StreamWriter::new(&mut buf);
        w.u64_val(big).unwrap();
        assert_eq!(String::from_utf8(buf).unwrap(), text);
    }

    #[test]
    fn i64_extremes_roundtrip() {
        for v in [i64::MIN, i64::MAX, -1, 0] {
            let text = format!("{v}");
            let mut r = Reader::new(&text);
            match r.next().unwrap() {
                Some(Token::Num(n)) => assert_eq!(n.as_i64(), Some(v), "{text}"),
                t => panic!("unexpected {t:?}"),
            }
        }
        assert_eq!(Num { raw: "18446744073709551615" }.as_u64(), Some(u64::MAX));
        assert_eq!(Num { raw: "1e3" }.as_u64(), Some(1000));
        assert_eq!(Num { raw: "1.5" }.as_u64(), None);
        assert_eq!(Num { raw: "-1" }.as_u64(), None);
    }

    #[test]
    fn depth_is_capped_not_fatal() {
        let text = "[".repeat(MAX_DEPTH + 10);
        let mut r = Reader::new(&text);
        let mut res = Ok(());
        loop {
            match r.next() {
                Ok(Some(_)) => continue,
                Ok(None) => break,
                Err(e) => {
                    res = Err(e);
                    break;
                }
            }
        }
        let e = res.unwrap_err();
        assert!(e.msg.contains("nesting too deep"), "{e}");
    }

    #[test]
    fn malformed_inputs_error() {
        for text in ["{", "[1,", "tru", "\"abc", "{\"a\" 1}", "01x", "", "1 2", "{]", "[,1]"] {
            assert!(tokens(text).is_err(), "{text:?}");
        }
    }

    #[test]
    fn writer_matches_tree_dump() {
        let mut buf = Vec::new();
        let mut w = StreamWriter::new(&mut buf);
        w.begin_obj().unwrap();
        w.key("a").unwrap();
        w.begin_arr().unwrap();
        w.u64_val(1).unwrap();
        w.f64_val(2.5).unwrap();
        w.null_val().unwrap();
        w.end_arr().unwrap();
        w.key("s").unwrap();
        w.str_val("x\ny\"z\"").unwrap();
        w.key("b").unwrap();
        w.bool_val(false).unwrap();
        w.key("empty").unwrap();
        w.begin_obj().unwrap();
        w.end_obj().unwrap();
        w.end_obj().unwrap();
        let got = String::from_utf8(buf).unwrap();
        assert_eq!(got, r#"{"a":[1,2.5,null],"s":"x\ny\"z\"","b":false,"empty":{}}"#);
    }
}
