//! Minimal JSON tree, parser and pretty-printer.
//!
//! `serde`/`serde_json` are not available in the offline vendor set, so the
//! config system, artifact manifest and report emitters use this small
//! implementation instead. It supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null) and preserves object
//! key insertion order, which keeps emitted reports diffable.
//!
//! The grammar itself lives in [`stream`]: a zero-copy pull reader and a
//! direct-to-`Write` serializer used by the measurement wire protocol and
//! the journal hot paths. The tree parser here is a thin fold over that
//! reader, so the crate has exactly one JSON grammar implementation.

pub mod stream;

use std::collections::BTreeMap;
use std::fmt;
use std::io;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered (key, value) pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Convenience: `obj.get_f64("lr")`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Build an object from pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::str(*s)).collect())
    }

    /// Set (or replace) a field on an object. Panics on non-objects.
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(fields) => {
                if let Some(slot) = fields.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    fields.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut r = stream::Reader::new(text);
        let v = Json::from_reader(&mut r)?;
        // A complete top-level value leaves the reader in its end state;
        // `next()` reports trailing non-space characters as an error.
        r.next()?;
        Ok(v)
    }

    /// Build one complete value from a streaming reader positioned at a
    /// value. Used by `parse` for whole documents and by the wire decoder
    /// to materialize an embedded subtree (e.g. shard stats) mid-line.
    ///
    /// Iterative fold with an explicit frame stack: the reader already
    /// caps nesting at [`stream::MAX_DEPTH`], and keeping the builder
    /// non-recursive means hostile input can never exhaust the thread
    /// stack anywhere in the pipeline.
    pub fn from_reader(r: &mut stream::Reader<'_>) -> Result<Json, JsonError> {
        use stream::Token;
        enum Frame {
            Arr(Vec<Json>),
            Obj(Vec<(String, Json)>, Option<String>),
        }
        let mut stack: Vec<Frame> = Vec::new();
        loop {
            let tok = match r.next()? {
                Some(t) => t,
                None => return Err(JsonError { pos: r.pos(), msg: "expected a JSON value".into() }),
            };
            let value = match tok {
                Token::ObjStart => {
                    stack.push(Frame::Obj(Vec::new(), None));
                    continue;
                }
                Token::ArrStart => {
                    stack.push(Frame::Arr(Vec::new()));
                    continue;
                }
                Token::Key(k) => {
                    if let Some(Frame::Obj(_, pending)) = stack.last_mut() {
                        *pending = Some(k.into_owned());
                    }
                    continue;
                }
                Token::ObjEnd | Token::ArrEnd => match stack.pop() {
                    Some(Frame::Obj(fields, _)) => Json::Obj(fields),
                    Some(Frame::Arr(items)) => Json::Arr(items),
                    // The reader never emits a closer without its opener.
                    None => {
                        return Err(JsonError { pos: r.pos(), msg: "unbalanced close".into() })
                    }
                },
                Token::Str(s) => Json::Str(s.into_owned()),
                Token::Num(n) => Json::Num(n.as_f64()),
                Token::Bool(b) => Json::Bool(b),
                Token::Null => Json::Null,
            };
            match stack.last_mut() {
                None => return Ok(value),
                Some(Frame::Arr(items)) => items.push(value),
                Some(Frame::Obj(fields, pending)) => {
                    // The reader guarantees a key precedes every value.
                    let key = pending.take().unwrap_or_default();
                    fields.push((key, value));
                }
            }
        }
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut buf = Vec::with_capacity(64);
        let mut w = stream::StreamWriter::new(&mut buf);
        self.write_stream(&mut w).expect("writing JSON to a Vec cannot fail");
        String::from_utf8(buf).expect("serialized JSON is valid UTF-8")
    }

    /// Serialize compactly into a [`stream::StreamWriter`] — the bridge
    /// for embedding a tree value (config, stats) inside a streamed frame.
    /// Byte-identical to `dump()`.
    pub fn write_stream<W: io::Write>(&self, w: &mut stream::StreamWriter<W>) -> io::Result<()> {
        match self {
            Json::Null => w.null_val(),
            Json::Bool(b) => w.bool_val(*b),
            Json::Num(x) => w.f64_val(*x),
            Json::Str(s) => w.str_val(s),
            Json::Arr(items) => {
                w.begin_arr()?;
                for item in items {
                    item.write_stream(w)?;
                }
                w.end_arr()
            }
            Json::Obj(fields) => {
                w.begin_obj()?;
                for (k, v) in fields {
                    w.key(k)?;
                    v.write_stream(w)?;
                }
                w.end_obj()
            }
        }
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn fmt_num(x: f64) -> String {
    let mut buf = Vec::with_capacity(24);
    stream::write_f64(&mut buf, x).expect("writing a number to a Vec cannot fail");
    String::from_utf8(buf).expect("formatted numbers are ASCII")
}

fn write_escaped(out: &mut String, s: &str) {
    let mut buf = Vec::with_capacity(s.len() + 2);
    stream::write_escaped(&mut buf, s).expect("writing a string to a Vec cannot fail");
    out.push_str(std::str::from_utf8(&buf).expect("escaped JSON strings are valid UTF-8"));
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

/// Parse a JSON file from disk.
pub fn read_json_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Write a JSON value to disk, pretty-printed, creating parent dirs.
pub fn write_json_file(path: &std::path::Path, value: &Json) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, value.pretty() + "\n")?;
    Ok(())
}

/// A BTreeMap view of an object (sorted keys) for order-insensitive compares.
pub fn to_map(v: &Json) -> BTreeMap<String, Json> {
    match v {
        Json::Obj(fields) => fields.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-12", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get_str("c"), Some("x\ny"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.dump(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" back\\ tab\t nl\n unicode\u{1F600}".into());
        let rt = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["{", "[1,", "tru", "\"abc", "{\"a\" 1}", "01x", ""] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn trailing_chars_rejected() {
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("arco")),
            ("knobs", Json::arr_f64(&[1.0, 2.0, 4.0])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn set_replaces_and_appends() {
        let mut v = Json::obj(vec![("a", Json::num(1.0))]);
        v.set("a", Json::num(2.0));
        v.set("b", Json::num(3.0));
        assert_eq!(v.get_f64("a"), Some(2.0));
        assert_eq!(v.get_f64("b"), Some(3.0));
    }

    #[test]
    fn numbers_precision() {
        let v = Json::parse("0.1").unwrap();
        assert!((v.as_f64().unwrap() - 0.1).abs() < 1e-12);
        assert_eq!(Json::Num(1e15).dump(), "1000000000000000");
    }
}
