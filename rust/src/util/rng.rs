//! Deterministic pseudo-random number generation.
//!
//! crates.io is unavailable in this build environment, so instead of the
//! `rand` crate we ship a small, well-tested PCG-XSH-RR 64/32 generator plus
//! the handful of distributions the tuners need (uniform ints/floats,
//! Gaussian via Box-Muller, categorical/weighted choice, shuffling).
//! Everything in the framework that uses randomness threads one of these
//! through explicitly, so whole tuning runs are reproducible from a seed.

/// PCG-XSH-RR 64/32: 64-bit state, 32-bit output, period 2^64 per stream.
///
/// Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
/// Statistically Good Algorithms for Random Number Generation" (2014).
#[derive(Debug, Clone)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Create a generator from a seed and stream id.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 { state: 0, inc: (stream << 1) | 1 };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Create a generator from a seed with the default stream.
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    /// Derive an independent child generator (used to give each worker
    /// thread / agent its own stream while staying reproducible).
    pub fn split(&mut self) -> Self {
        let seed = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        let stream = ((self.next_u32() as u64) << 32) | self.next_u32() as u64;
        Self::new(seed, stream)
    }

    /// Next raw 32 bits.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64 bits.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform integer in `[0, bound)` without modulo bias (Lemire's method).
    #[inline]
    pub fn gen_range(&mut self, bound: usize) -> usize {
        assert!(bound > 0, "gen_range bound must be positive");
        let bound = bound as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let l = m as u64;
            if l >= bound || l >= l.wrapping_neg() % bound {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    #[inline]
    pub fn gen_range_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo, "empty range");
        lo + self.gen_range(hi - lo)
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn gen_f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn gen_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn gen_f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.gen_f64()
    }

    /// Bernoulli trial with probability `p`.
    #[inline]
    pub fn gen_bool(&mut self, p: f64) -> bool {
        self.gen_f64() < p
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast
    /// here, the tuners draw few Gaussians).
    pub fn gen_normal(&mut self) -> f64 {
        loop {
            let u1 = self.gen_f64();
            if u1 > f64::EPSILON {
                let u2 = self.gen_f64();
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Sample an index from unnormalized non-negative weights.
    /// Falls back to uniform if all weights are zero/non-finite.
    pub fn gen_weighted(&mut self, weights: &[f64]) -> usize {
        assert!(!weights.is_empty());
        let total: f64 = weights.iter().filter(|w| w.is_finite() && **w > 0.0).sum();
        if total <= 0.0 {
            return self.gen_range(weights.len());
        }
        let mut target = self.gen_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            if w.is_finite() && w > 0.0 {
                target -= w;
                if target <= 0.0 {
                    return i;
                }
            }
        }
        weights.len() - 1
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.gen_range(i + 1);
            items.swap(i, j);
        }
    }

    /// Choose `k` distinct indices from `[0, n)` (reservoir if k << n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k * 4 >= n {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            // Floyd's algorithm for sparse sampling.
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.gen_range(j + 1);
                let pick = if chosen.contains(&t) { j } else { t };
                chosen.insert(pick);
                out.push(pick);
            }
            out
        }
    }

    /// Pick a uniform element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.gen_range(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn range_is_unbiased_enough() {
        let mut rng = Pcg32::seeded(7);
        let mut counts = [0usize; 10];
        let n = 100_000;
        for _ in 0..n {
            counts[rng.gen_range(10)] += 1;
        }
        for &c in &counts {
            let expect = n / 10;
            assert!((c as i64 - expect as i64).abs() < (expect as i64 / 10));
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg32::seeded(3);
        for _ in 0..10_000 {
            let x = rng.gen_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_mean_and_var() {
        let mut rng = Pcg32::seeded(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gen_normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = Pcg32::seeded(5);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.gen_weighted(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.3, "ratio {ratio}");
    }

    #[test]
    fn weighted_all_zero_falls_back_uniform() {
        let mut rng = Pcg32::seeded(5);
        let w = [0.0, 0.0];
        let mut seen = [false; 2];
        for _ in 0..100 {
            seen[rng.gen_weighted(&w)] = true;
        }
        assert!(seen[0] && seen[1]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg32::seeded(9);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Pcg32::seeded(13);
        for &(n, k) in &[(100usize, 5usize), (10, 10), (1000, 3), (8, 6)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k.min(n));
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), s.len());
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn split_streams_are_independent() {
        let mut root = Pcg32::seeded(21);
        let mut c1 = root.split();
        let mut c2 = root.split();
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }
}
