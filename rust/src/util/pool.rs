//! A small scoped worker pool for batched hardware measurements.
//!
//! tokio is not in the offline vendor set, and the measurement workload
//! (simulating a batch of candidate configs on the VTA++ model) is pure CPU
//! fan-out, so `std::thread::scope` plus a shared atomic work index is the
//! right tool: no async runtime, no allocation in the steady state, and
//! deterministic output ordering (results land at their input index).

use std::sync::atomic::{AtomicUsize, Ordering};

/// Map `f` over `items` using up to `workers` OS threads, preserving order.
///
/// `f` must be `Sync` (it is shared by reference across workers). Panics in
/// workers propagate to the caller.
pub fn parallel_map<T, R, F>(items: &[T], workers: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    let n = items.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    if workers == 1 {
        return items.iter().enumerate().map(|(i, t)| f(i, t)).collect();
    }

    let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let next = AtomicUsize::new(0);
    // SAFETY-free approach: split `out` into per-index cells via raw pointers
    // is avoided; instead each worker collects (idx, result) pairs and we
    // merge afterwards. Simplicity over the last few percent.
    let chunks: Vec<Vec<(usize, R)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut local = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((i, f(i, &items[i])));
                    }
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("worker panicked")).collect()
    });
    for chunk in chunks {
        for (i, r) in chunk {
            out[i] = Some(r);
        }
    }
    out.into_iter().map(|r| r.expect("missing result")).collect()
}

/// Default worker count: physical parallelism minus one spare, at least 1.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).saturating_sub(1).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn maps_in_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 8, |_, &x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_path() {
        let items = vec![1, 2, 3];
        let out = parallel_map(&items, 1, |i, &x| i + x);
        assert_eq!(out, vec![1, 3, 5]);
    }

    #[test]
    fn empty_input() {
        let items: Vec<u32> = vec![];
        let out: Vec<u32> = parallel_map(&items, 4, |_, &x| x);
        assert!(out.is_empty());
    }

    #[test]
    fn more_workers_than_items() {
        let items = vec![10, 20];
        let out = parallel_map(&items, 16, |_, &x| x + 1);
        assert_eq!(out, vec![11, 21]);
    }

    #[test]
    #[should_panic(expected = "worker panicked")]
    fn worker_panic_propagates() {
        let items = vec![0usize, 1, 2, 3, 4, 5, 6, 7];
        let _ = parallel_map(&items, 4, |_, &x| {
            if x == 3 {
                panic!("boom");
            }
            x
        });
    }

    #[test]
    fn default_workers_positive() {
        assert!(default_workers() >= 1);
    }
}
