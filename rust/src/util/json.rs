//! Minimal JSON tree, parser and pretty-printer.
//!
//! `serde`/`serde_json` are not available in the offline vendor set, so the
//! config system, artifact manifest and report emitters use this small
//! implementation instead. It supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bools, null) and preserves object
//! key insertion order, which keeps emitted reports diffable.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object: insertion-ordered (key, value) pairs.
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }
    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }
    /// Convenience: `obj.get_f64("lr")`.
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }
    pub fn get_usize(&self, key: &str) -> Option<usize> {
        self.get(key).and_then(Json::as_usize)
    }
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }
    pub fn get_bool(&self, key: &str) -> Option<bool> {
        self.get(key).and_then(Json::as_bool)
    }

    /// Build an object from pairs.
    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }
    pub fn arr_f64(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }
    pub fn arr_str(xs: &[&str]) -> Json {
        Json::Arr(xs.iter().map(|s| Json::str(*s)).collect())
    }

    /// Set (or replace) a field on an object. Panics on non-objects.
    pub fn set(&mut self, key: &str, value: Json) {
        match self {
            Json::Obj(fields) => {
                if let Some(slot) = fields.iter_mut().find(|(k, _)| k == key) {
                    slot.1 = value;
                } else {
                    fields.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn dump(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(x) => out.push_str(&fmt_num(*x)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !items.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if !fields.is_empty() {
                    newline_indent(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn fmt_num(x: f64) -> String {
    if x.is_finite() && x.fract() == 0.0 && x.abs() < 1e15 {
        format!("{}", x as i64)
    } else if x.is_finite() {
        // Shortest roundtrip representation rust provides.
        format!("{x}")
    } else {
        // JSON has no inf/nan; emit null like most lenient writers.
        "null".to_string()
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek();
        if b.is_some() {
            self.pos += 1;
        }
        b
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut fields = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            fields.push((key, val));
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(fields)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("invalid codepoint"))?
                        } else {
                            char::from_u32(cp).ok_or_else(|| self.err("invalid codepoint"))?
                        };
                        s.push(c);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x80 => s.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the remaining continuation bytes.
                    let len = if b >= 0xF0 {
                        4
                    } else if b >= 0xE0 {
                        3
                    } else {
                        2
                    };
                    let start = self.pos - 1;
                    let end = start + len;
                    if end > self.bytes.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    let chunk = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    s.push_str(chunk);
                    self.pos = end;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

/// Parse a JSON file from disk.
pub fn read_json_file(path: &std::path::Path) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
    Json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))
}

/// Write a JSON value to disk, pretty-printed, creating parent dirs.
pub fn write_json_file(path: &std::path::Path, value: &Json) -> anyhow::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::write(path, value.pretty() + "\n")?;
    Ok(())
}

/// A BTreeMap view of an object (sorted keys) for order-insensitive compares.
pub fn to_map(v: &Json) -> BTreeMap<String, Json> {
    match v {
        Json::Obj(fields) => fields.iter().cloned().collect(),
        _ => BTreeMap::new(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_scalars() {
        for text in ["null", "true", "false", "0", "-12", "3.5", "1e3", "\"hi\""] {
            let v = Json::parse(text).unwrap();
            let v2 = Json::parse(&v.dump()).unwrap();
            assert_eq!(v, v2, "{text}");
        }
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get_str("c"), Some("x\ny"));
        assert_eq!(v.get("a").unwrap().as_arr().unwrap()[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn preserves_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2,"m":3}"#).unwrap();
        assert_eq!(v.dump(), r#"{"z":1,"a":2,"m":3}"#);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" back\\ tab\t nl\n unicode\u{1F600}".into());
        let rt = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, rt);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600}"));
    }

    #[test]
    fn rejects_garbage() {
        for text in ["{", "[1,", "tru", "\"abc", "{\"a\" 1}", "01x", ""] {
            assert!(Json::parse(text).is_err(), "{text}");
        }
    }

    #[test]
    fn trailing_chars_rejected() {
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![
            ("name", Json::str("arco")),
            ("knobs", Json::arr_f64(&[1.0, 2.0, 4.0])),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        assert_eq!(Json::parse(&v.pretty()).unwrap(), v);
    }

    #[test]
    fn set_replaces_and_appends() {
        let mut v = Json::obj(vec![("a", Json::num(1.0))]);
        v.set("a", Json::num(2.0));
        v.set("b", Json::num(3.0));
        assert_eq!(v.get_f64("a"), Some(2.0));
        assert_eq!(v.get_f64("b"), Some(3.0));
    }

    #[test]
    fn numbers_precision() {
        let v = Json::parse("0.1").unwrap();
        assert!((v.as_f64().unwrap() - 0.1).abs() < 1e-12);
        assert_eq!(Json::Num(1e15).dump(), "1000000000000000");
    }
}
