//! Criterion-style micro/macro benchmark harness (criterion itself is not in
//! the offline vendor set).
//!
//! Benches are plain binaries (`[[bench]] harness = false`) that construct a
//! [`BenchRunner`] and register closures. Each benchmark is warmed up, then
//! run for a target measuring time with per-iteration timing; the runner
//! reports mean / median / p95 and writes a machine-readable JSON line per
//! bench under `results/bench/`.

use super::stats;
use std::hint::black_box as bb;
use std::time::{Duration, Instant};

/// Re-export so bench code can `bench::black_box(..)`.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// One benchmark's collected timings.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    /// Optional throughput denominator (elements per iteration).
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / (self.mean_ns * 1e-9))
    }
}

/// Harness configuration.
#[derive(Debug, Clone)]
pub struct BenchConfig {
    pub warmup: Duration,
    pub measure: Duration,
    pub max_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        // Modest defaults: full `cargo bench` regenerates every paper
        // table/figure and must finish in CI-scale time.
        BenchConfig {
            warmup: Duration::from_millis(200),
            measure: Duration::from_millis(800),
            max_iters: 1_000_000,
        }
    }
}

/// Collects and reports benchmark results.
pub struct BenchRunner {
    suite: String,
    config: BenchConfig,
    results: Vec<BenchResult>,
}

impl BenchRunner {
    pub fn new(suite: &str) -> Self {
        // Honour quick mode for smoke runs: ARCO_BENCH_QUICK=1.
        let mut config = BenchConfig::default();
        if std::env::var("ARCO_BENCH_QUICK").is_ok_and(|v| v == "1") {
            config.warmup = Duration::from_millis(20);
            config.measure = Duration::from_millis(100);
        }
        println!("== bench suite: {suite} ==");
        BenchRunner { suite: suite.to_string(), config, results: Vec::new() }
    }

    pub fn with_config(mut self, config: BenchConfig) -> Self {
        self.config = config;
        self
    }

    /// Benchmark `f` (called once per iteration).
    pub fn bench<T>(&mut self, name: &str, mut f: impl FnMut() -> T) {
        self.bench_with_elements(name, None, move || {
            bb(f());
        });
    }

    /// Benchmark with a throughput denominator (e.g. simulated instructions
    /// per call) so the report can print items/sec.
    pub fn bench_with_elements(&mut self, name: &str, elements: Option<u64>, mut f: impl FnMut()) {
        // Warmup.
        let w = Instant::now();
        let mut warm_iters = 0usize;
        while w.elapsed() < self.config.warmup && warm_iters < self.config.max_iters {
            f();
            warm_iters += 1;
        }
        // Choose a batch size so one batch is ~1ms (keeps timer overhead low
        // for nanosecond-scale bodies).
        let per_iter = (w.elapsed().as_secs_f64() / warm_iters.max(1) as f64).max(1e-9);
        let batch = ((1e-3 / per_iter).ceil() as usize).clamp(1, 65_536);

        let mut samples_ns: Vec<f64> = Vec::new();
        let mut iters = 0usize;
        let m = Instant::now();
        while m.elapsed() < self.config.measure && iters < self.config.max_iters {
            let t = Instant::now();
            for _ in 0..batch {
                f();
            }
            let ns = t.elapsed().as_nanos() as f64 / batch as f64;
            samples_ns.push(ns);
            iters += batch;
        }
        let result = BenchResult {
            name: name.to_string(),
            iters,
            mean_ns: stats::mean(&samples_ns),
            median_ns: stats::median(&samples_ns),
            p95_ns: stats::percentile(&samples_ns, 95.0),
            min_ns: samples_ns.iter().cloned().fold(f64::INFINITY, f64::min),
            elements,
        };
        self.print_one(&result);
        self.results.push(result);
    }

    fn print_one(&self, r: &BenchResult) {
        let tput = r
            .throughput_per_sec()
            .map(|t| format!("  {:>12.3e} elem/s", t))
            .unwrap_or_default();
        println!(
            "{:<44} mean {:>12}  median {:>12}  p95 {:>12}  ({} iters){tput}",
            r.name,
            fmt_ns(r.mean_ns),
            fmt_ns(r.median_ns),
            fmt_ns(r.p95_ns),
            r.iters
        );
    }

    /// Write results as JSON to `results/bench/<suite>.json` and return them.
    pub fn finish(self) -> Vec<BenchResult> {
        use super::json::Json;
        let items: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_ns", Json::num(r.mean_ns)),
                    ("median_ns", Json::num(r.median_ns)),
                    ("p95_ns", Json::num(r.p95_ns)),
                    ("min_ns", Json::num(r.min_ns)),
                    (
                        "elements",
                        r.elements.map(|e| Json::num(e as f64)).unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        let doc = Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            ("results", Json::Arr(items)),
        ]);
        let path = std::path::Path::new("results/bench").join(format!("{}.json", self.suite));
        if let Err(e) = super::json::write_json_file(&path, &doc) {
            eprintln!("warning: could not write {}: {e}", path.display());
        }
        self.results
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3}s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3}ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3}us", ns / 1e3)
    } else {
        format!("{ns:.1}ns")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let mut r = BenchRunner::new("unit-test").with_config(BenchConfig {
            warmup: Duration::from_millis(5),
            measure: Duration::from_millis(20),
            max_iters: 100_000,
        });
        let mut acc = 0u64;
        r.bench("add", || {
            acc = acc.wrapping_add(1);
            acc
        });
        let results = r.finish();
        assert_eq!(results.len(), 1);
        assert!(results[0].iters > 0);
        assert!(results[0].mean_ns > 0.0);
        assert!(results[0].median_ns <= results[0].p95_ns * 1.0001);
    }

    #[test]
    fn throughput_computed() {
        let r = BenchResult {
            name: "x".into(),
            iters: 10,
            mean_ns: 1000.0,
            median_ns: 1000.0,
            p95_ns: 1000.0,
            min_ns: 1000.0,
            elements: Some(2000),
        };
        let t = r.throughput_per_sec().unwrap();
        assert!((t - 2e9).abs() / 2e9 < 1e-9);
    }

    #[test]
    fn fmt_ns_ranges() {
        assert_eq!(fmt_ns(5.0), "5.0ns");
        assert_eq!(fmt_ns(5_000.0), "5.000us");
        assert_eq!(fmt_ns(5e6), "5.000ms");
        assert_eq!(fmt_ns(5e9), "5.000s");
    }
}
