//! Infrastructure substrates built from scratch for the offline environment:
//! RNG, JSON, statistics, logging, timers, CLI parsing, a bench harness, a
//! property-test driver and a scoped thread pool.

pub mod bench;
pub mod cli;
pub mod json;
pub mod log;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod timer;
