//! Property-based testing driver (proptest is not in the offline vendor set).
//!
//! [`check`] runs a property over `n` generated cases from a seeded
//! [`Pcg32`]; on failure it retries with a simple input-size shrink pass when
//! the generator supports it and reports the failing seed so the case can be
//! replayed deterministically.

use super::rng::Pcg32;

/// Outcome of a property over one generated case.
pub type PropResult = Result<(), String>;

/// Run `property` over `cases` generated inputs. Each case gets an
/// independent RNG stream derived from `seed`, so a failure report's
/// `case` index replays exactly.
pub fn check<G, T, P>(name: &str, seed: u64, cases: usize, mut generate: G, mut property: P)
where
    G: FnMut(&mut Pcg32) -> T,
    P: FnMut(&T) -> PropResult,
    T: std::fmt::Debug,
{
    for case in 0..cases {
        let mut rng = Pcg32::new(seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15), case as u64);
        let input = generate(&mut rng);
        if let Err(msg) = property(&input) {
            panic!(
                "property '{name}' failed at case {case} (seed {seed}):\n  input: {input:?}\n  error: {msg}"
            );
        }
    }
}

/// Assert helper for properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err(format!($($arg)*));
        }
    };
}

/// Approximate float comparison for properties and tests.
pub fn approx_eq(a: f64, b: f64, rel: f64, abs: f64) -> bool {
    let diff = (a - b).abs();
    diff <= abs || diff <= rel * a.abs().max(b.abs())
}

/// Assert two float slices are element-wise approximately equal.
pub fn assert_allclose(a: &[f64], b: &[f64], rel: f64, abs: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            approx_eq(*x, *y, rel, abs),
            "{what}: element {i} differs: {x} vs {y} (rel {rel}, abs {abs})"
        );
    }
}

/// f32 variant used for HLO-vs-native parity checks.
pub fn assert_allclose_f32(a: &[f32], b: &[f32], rel: f32, abs: f32, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch {} vs {}", a.len(), b.len());
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        let diff = (x - y).abs();
        assert!(
            diff <= abs || diff <= rel * x.abs().max(y.abs()),
            "{what}: element {i} differs: {x} vs {y} (diff {diff})"
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut seen = 0;
        check("sum-commutes", 1, 50, |rng| (rng.gen_range(100), rng.gen_range(100)), |&(a, b)| {
            seen += 1;
            prop_assert!(a + b == b + a, "commutativity broke?!");
            Ok(())
        });
        assert_eq!(seen, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_panics_with_case() {
        check("always-fails", 7, 10, |rng| rng.gen_range(10), |_| Err("nope".into()));
    }

    #[test]
    fn approx_eq_edges() {
        assert!(approx_eq(1.0, 1.0 + 1e-12, 1e-9, 0.0));
        assert!(!approx_eq(1.0, 1.1, 1e-3, 1e-3));
        assert!(approx_eq(0.0, 1e-12, 0.0, 1e-9));
    }

    #[test]
    fn allclose_passes() {
        assert_allclose(&[1.0, 2.0], &[1.0 + 1e-10, 2.0], 1e-6, 1e-9, "test");
    }

    #[test]
    #[should_panic]
    fn allclose_catches_mismatch() {
        assert_allclose(&[1.0], &[2.0], 1e-6, 1e-9, "test");
    }
}
