//! Tiny leveled logger (the `log`/`env_logger` pairing is not in the offline
//! vendor set and we want structured per-tuner prefixes anyway).
//!
//! Level is process-global, set once from the CLI (`-v/-q`) or the
//! `ARCO_LOG` environment variable (`error|warn|info|debug|trace`).

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);

static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

/// Set the global log level.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Initialize from `ARCO_LOG` if present.
pub fn init_from_env() {
    if let Ok(v) = std::env::var("ARCO_LOG") {
        if let Some(l) = Level::from_str(&v) {
            set_level(l);
        }
    }
    let _ = START.get_or_init(Instant::now);
}

/// Current global level.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Warn,
        2 => Level::Info,
        3 => Level::Debug,
        _ => Level::Trace,
    }
}

/// True if `l` would be emitted.
pub fn enabled(l: Level) -> bool {
    l <= level()
}

/// Emit a log line (used through the macros below).
pub fn emit(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3}s {} {target}] {msg}", l.tag());
}

#[macro_export]
macro_rules! log_error {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Error, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_warn {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Warn, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Info, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_debug {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Debug, $target, format_args!($($arg)*))
    };
}

#[macro_export]
macro_rules! log_trace {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::emit($crate::util::log::Level::Trace, $target, format_args!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parse_and_order() {
        assert_eq!(Level::from_str("warn"), Some(Level::Warn));
        assert_eq!(Level::from_str("TRACE"), Some(Level::Trace));
        assert_eq!(Level::from_str("nope"), None);
        assert!(Level::Error < Level::Trace);
    }

    #[test]
    fn set_and_check() {
        let prev = level();
        set_level(Level::Error);
        assert!(enabled(Level::Error));
        assert!(!enabled(Level::Info));
        set_level(prev);
    }
}
