//! PJRT execution engine for the AOT artifacts.
//!
//! One `PjRtClient` (CPU), one compiled executable per entry point, all
//! compiled once at startup (`Engine::load`). Hot-path calls marshal flat
//! f32/i32 slices into `xla::Literal`s, execute, and unwrap the result
//! tuple (aot.py lowers with `return_tuple=True`).

use super::manifest::Manifest;
use std::collections::HashMap;
use std::path::Path;

/// Engine errors.
#[derive(Debug)]
pub enum EngineError {
    MissingArtifact(String),
    BadShape { what: &'static str, got: usize, want: usize },
    Xla(String),
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::MissingArtifact(name) => {
                write!(f, "artifact '{name}' missing from manifest")
            }
            EngineError::BadShape { what, got, want } => {
                write!(f, "input '{what}' has {got} elements, expected {want}")
            }
            EngineError::Xla(msg) => write!(f, "xla error: {msg}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<xla::Error> for EngineError {
    fn from(e: xla::Error) -> Self {
        EngineError::Xla(e.to_string())
    }
}

/// Outputs of one PPO policy update.
#[derive(Debug, Clone)]
pub struct PolicyTrainOut {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    pub loss: f32,
    pub entropy: f32,
    pub clip_frac: f32,
}

/// Outputs of one critic update.
#[derive(Debug, Clone)]
pub struct ValueTrainOut {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub t: f32,
    pub loss: f32,
}

/// The loaded runtime: compiled executables for every entry point.
pub struct Engine {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Engine {
    /// Compile all artifacts in `dir` on the PJRT CPU client.
    pub fn load(dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(dir)?;
        let client = xla::PjRtClient::cpu()?;
        let mut exes = HashMap::new();
        for (name, file) in &manifest.artifact_files {
            let path = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(name.clone(), exe);
        }
        crate::log_info!(
            "runtime",
            "loaded {} artifacts on {} ({} devices)",
            exes.len(),
            client.platform_name(),
            client.device_count()
        );
        Ok(Engine { manifest, client, exes })
    }

    /// Platform name (for diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn exe(&self, name: &str) -> Result<&xla::PjRtLoadedExecutable, EngineError> {
        self.exes.get(name).ok_or_else(|| EngineError::MissingArtifact(name.to_string()))
    }

    fn run(&self, name: &str, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>, EngineError> {
        let exe = self.exe(name)?;
        let result = exe.execute::<xla::Literal>(inputs)?[0][0].to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Masked log-probs for a batch of observations.
    /// `obs` is row-major (b_pol, obs_dim); returns (b_pol, act_dim) flat.
    pub fn policy_forward(
        &self,
        params: &[f32],
        obs: &[f32],
        mask: &[f32],
    ) -> Result<Vec<f32>, EngineError> {
        let d = self.manifest.dims;
        check("params", params.len(), d.p_policy)?;
        check("obs", obs.len(), d.b_pol * d.obs_dim)?;
        check("mask", mask.len(), d.act_dim)?;
        let inputs = [
            lit1(params),
            lit2(obs, d.b_pol, d.obs_dim)?,
            lit1(mask),
        ];
        let out = self.run("policy_forward", &inputs)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Critic values for a batch of global states (b_pol rows).
    pub fn value_forward(&self, params: &[f32], state: &[f32]) -> Result<Vec<f32>, EngineError> {
        let d = self.manifest.dims;
        check("params", params.len(), d.p_value)?;
        check("state", state.len(), d.b_pol * d.gstate_dim)?;
        let inputs = [lit1(params), lit2(state, d.b_pol, d.gstate_dim)?];
        let out = self.run("value_forward", &inputs)?;
        Ok(out[0].to_vec::<f32>()?)
    }

    /// GAE over the fixed horizon. Returns (advantages, returns).
    pub fn gae(
        &self,
        rewards: &[f32],
        values: &[f32],
        bootstrap: f32,
        gamma: f32,
        lam: f32,
    ) -> Result<(Vec<f32>, Vec<f32>), EngineError> {
        let d = self.manifest.dims;
        check("rewards", rewards.len(), d.t_gae)?;
        check("values", values.len(), d.t_gae)?;
        let inputs = [lit1(rewards), lit1(values), lit1(&[bootstrap]), lit1(&[gamma, lam])];
        let out = self.run("gae", &inputs)?;
        Ok((out[0].to_vec::<f32>()?, out[1].to_vec::<f32>()?))
    }

    /// One PPO-clip policy update (batch padded to b_train; `weight`=0 rows
    /// are ignored by the baked loss).
    #[allow(clippy::too_many_arguments)]
    pub fn policy_train(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        t: f32,
        obs: &[f32],
        mask: &[f32],
        actions: &[i32],
        old_logp: &[f32],
        adv: &[f32],
        weight: &[f32],
    ) -> Result<PolicyTrainOut, EngineError> {
        let d = self.manifest.dims;
        check("params", params.len(), d.p_policy)?;
        check("m", m.len(), d.p_policy)?;
        check("v", v.len(), d.p_policy)?;
        check("obs", obs.len(), d.b_train * d.obs_dim)?;
        check("mask", mask.len(), d.act_dim)?;
        check("actions", actions.len(), d.b_train)?;
        check("old_logp", old_logp.len(), d.b_train)?;
        check("adv", adv.len(), d.b_train)?;
        check("weight", weight.len(), d.b_train)?;
        let inputs = [
            lit1(params),
            lit1(m),
            lit1(v),
            lit1(&[t]),
            lit2(obs, d.b_train, d.obs_dim)?,
            lit1(mask),
            lit1_i32(actions),
            lit1(old_logp),
            lit1(adv),
            lit1(weight),
        ];
        let out = self.run("policy_train", &inputs)?;
        Ok(PolicyTrainOut {
            params: out[0].to_vec::<f32>()?,
            m: out[1].to_vec::<f32>()?,
            v: out[2].to_vec::<f32>()?,
            t: out[3].to_vec::<f32>()?[0],
            loss: out[4].to_vec::<f32>()?[0],
            entropy: out[5].to_vec::<f32>()?[0],
            clip_frac: out[6].to_vec::<f32>()?[0],
        })
    }

    /// One critic MSE update.
    pub fn value_train(
        &self,
        params: &[f32],
        m: &[f32],
        v: &[f32],
        t: f32,
        state: &[f32],
        returns: &[f32],
        weight: &[f32],
    ) -> Result<ValueTrainOut, EngineError> {
        let d = self.manifest.dims;
        check("params", params.len(), d.p_value)?;
        check("state", state.len(), d.b_train * d.gstate_dim)?;
        check("returns", returns.len(), d.b_train)?;
        check("weight", weight.len(), d.b_train)?;
        let inputs = [
            lit1(params),
            lit1(m),
            lit1(v),
            lit1(&[t]),
            lit2(state, d.b_train, d.gstate_dim)?,
            lit1(returns),
            lit1(weight),
        ];
        let out = self.run("value_train", &inputs)?;
        Ok(ValueTrainOut {
            params: out[0].to_vec::<f32>()?,
            m: out[1].to_vec::<f32>()?,
            v: out[2].to_vec::<f32>()?,
            t: out[3].to_vec::<f32>()?[0],
            loss: out[4].to_vec::<f32>()?[0],
        })
    }
}

fn check(what: &'static str, got: usize, want: usize) -> Result<(), EngineError> {
    if got == want {
        Ok(())
    } else {
        Err(EngineError::BadShape { what, got, want })
    }
}

fn lit1(data: &[f32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

fn lit1_i32(data: &[i32]) -> xla::Literal {
    xla::Literal::vec1(data)
}

fn lit2(data: &[f32], rows: usize, cols: usize) -> Result<xla::Literal, EngineError> {
    Ok(xla::Literal::vec1(data).reshape(&[rows as i64, cols as i64])?)
}
