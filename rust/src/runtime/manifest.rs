//! `artifacts/manifest.json` — the L2 ↔ L3 shape/hyper-parameter contract.

use crate::util::json::{read_json_file, Json};
use std::path::{Path, PathBuf};

/// Static dims the AOT artifacts were lowered with (python/compile/dims.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelDims {
    pub obs_dim: usize,
    pub act_dim: usize,
    pub gstate_dim: usize,
    pub hidden: usize,
    pub b_pol: usize,
    pub b_train: usize,
    pub t_gae: usize,
    pub p_policy: usize,
    pub p_value: usize,
}

impl Default for ModelDims {
    /// Compile-time mirror of python/compile/dims.py; used when artifacts
    /// are absent (native backend) and validated against the manifest when
    /// they are present.
    fn default() -> Self {
        ModelDims {
            obs_dim: 16,
            act_dim: 27,
            gstate_dim: 24,
            hidden: 20,
            b_pol: 64,
            b_train: 256,
            t_gae: 512,
            p_policy: 907,
            p_value: 1361,
        }
    }
}

/// Baked training hyper-parameters recorded by aot.py.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BakedHyper {
    pub clip_eps: f64,
    pub entropy_coef: f64,
    pub lr_policy: f64,
    pub lr_value: f64,
    pub max_grad_norm: f64,
}

impl Default for BakedHyper {
    fn default() -> Self {
        BakedHyper {
            clip_eps: 0.2,
            entropy_coef: 0.01,
            lr_policy: 5e-3,
            lr_value: 5e-3,
            max_grad_norm: 10.0,
        }
    }
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dims: ModelDims,
    pub hyper: BakedHyper,
    pub dir: PathBuf,
    pub artifact_files: Vec<(String, String)>,
}

impl Manifest {
    /// Load and validate `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let doc = read_json_file(&dir.join("manifest.json"))?;
        let d = doc
            .get("dims")
            .ok_or_else(|| anyhow::anyhow!("manifest missing 'dims'"))?;
        let need = |key: &str| -> anyhow::Result<usize> {
            d.get_usize(key).ok_or_else(|| anyhow::anyhow!("manifest dims missing '{key}'"))
        };
        let dims = ModelDims {
            obs_dim: need("obs_dim")?,
            act_dim: need("act_dim")?,
            gstate_dim: need("gstate_dim")?,
            hidden: need("hidden")?,
            b_pol: need("b_pol")?,
            b_train: need("b_train")?,
            t_gae: need("t_gae")?,
            p_policy: need("p_policy")?,
            p_value: need("p_value")?,
        };
        let h = doc.get("hyper");
        let hd = BakedHyper::default();
        let hyper = match h {
            Some(h) => BakedHyper {
                clip_eps: h.get_f64("clip_eps").unwrap_or(hd.clip_eps),
                entropy_coef: h.get_f64("entropy_coef").unwrap_or(hd.entropy_coef),
                lr_policy: h.get_f64("lr_policy").unwrap_or(hd.lr_policy),
                lr_value: h.get_f64("lr_value").unwrap_or(hd.lr_value),
                max_grad_norm: h.get_f64("max_grad_norm").unwrap_or(hd.max_grad_norm),
            },
            None => hd,
        };
        let mut artifact_files = Vec::new();
        if let Some(Json::Obj(arts)) = doc.get("artifacts") {
            for (name, meta) in arts {
                if let Some(file) = meta.get_str("file") {
                    artifact_files.push((name.clone(), file.to_string()));
                }
            }
        }
        let m = Manifest { dims, hyper, dir: dir.to_path_buf(), artifact_files };
        m.validate()?;
        Ok(m)
    }

    /// Cross-check the manifest against the compiled-in expectations.
    pub fn validate(&self) -> anyhow::Result<()> {
        let expect = ModelDims::default();
        if self.dims != expect {
            anyhow::bail!(
                "artifact dims {:?} do not match the rust build's expectations {:?}; \
                 re-run `make artifacts`",
                self.dims,
                expect
            );
        }
        // Param-count identity: P = (obs*h + h) + (h*act + act).
        let d = self.dims;
        let p_pol = d.obs_dim * d.hidden + d.hidden + d.hidden * d.act_dim + d.act_dim;
        let p_val = d.gstate_dim * d.hidden + d.hidden
            + 2 * (d.hidden * d.hidden + d.hidden)
            + d.hidden
            + 1;
        if p_pol != d.p_policy || p_val != d.p_value {
            anyhow::bail!("manifest param counts are inconsistent with its dims");
        }
        Ok(())
    }

    /// Path of an artifact by entry-point name.
    pub fn artifact_path(&self, name: &str) -> Option<PathBuf> {
        self.artifact_files
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, f)| self.dir.join(f))
    }
}

/// Default artifacts directory, overridable with ARCO_ARTIFACTS.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("ARCO_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_dims_param_counts() {
        let d = ModelDims::default();
        assert_eq!(d.p_policy, d.obs_dim * d.hidden + d.hidden + d.hidden * d.act_dim + d.act_dim);
        assert_eq!(
            d.p_value,
            d.gstate_dim * d.hidden + d.hidden + 2 * (d.hidden * d.hidden + d.hidden) + d.hidden + 1
        );
    }

    #[test]
    fn loads_real_manifest_when_present() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipping: no artifacts built");
            return;
        }
        let m = Manifest::load(&dir).expect("manifest should load");
        assert_eq!(m.dims, ModelDims::default());
        assert!(m.artifact_path("policy_forward").is_some());
        assert!(m.artifact_path("nonexistent").is_none());
        for (_, file) in &m.artifact_files {
            assert!(dir.join(file).exists(), "{file} listed but missing");
        }
    }

    #[test]
    fn rejects_mismatched_dims() {
        let tmp = std::env::temp_dir().join(format!("arco-manifest-test-{}", std::process::id()));
        std::fs::create_dir_all(&tmp).unwrap();
        std::fs::write(
            tmp.join("manifest.json"),
            r#"{"dims": {"obs_dim": 8, "act_dim": 27, "gstate_dim": 24, "hidden": 20,
                "b_pol": 64, "b_train": 256, "t_gae": 512, "p_policy": 907, "p_value": 1361}}"#,
        )
        .unwrap();
        assert!(Manifest::load(&tmp).is_err());
        std::fs::remove_dir_all(&tmp).ok();
    }
}
