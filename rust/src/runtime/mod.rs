//! L3 runtime: load AOT-compiled HLO artifacts and execute them via PJRT.
//!
//! Python runs once at build time (`make artifacts`); afterwards the rust
//! binary is self-contained: `PjRtClient::cpu()` compiles the HLO text
//! modules and the MARL hot path calls [`Engine`] with flat f32 buffers.
//!
//! The [`manifest`] module reads `artifacts/manifest.json` (shapes and baked
//! hyper-parameters), so rust and python can never drift silently.

pub mod engine;
pub mod manifest;

pub use engine::{Engine, EngineError};
pub use manifest::{Manifest, ModelDims};
