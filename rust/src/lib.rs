//! # ARCO — Adaptive MARL-based HW/SW co-optimization compiler (reproduction)
//!
//! A three-layer reproduction of "ARCO: Adaptive Multi-Agent Reinforcement
//! Learning-Based Hardware/Software Co-Optimization Compiler for Improved
//! Performance in DNN Accelerator Design" (Fayyazi, Kamal, Pedram).
//!
//! - **L3 (this crate)**: the co-optimizing compiler — VTA++ simulator,
//!   design space, code generator, MAPPO MARL exploration with Confidence
//!   Sampling, AutoTVM/CHAMELEON baselines, tuning orchestrator, reports.
//! - **L2 (python/compile/model.py)**: MAPPO policy/critic graphs and train
//!   steps in JAX, AOT-lowered once to `artifacts/*.hlo.txt`.
//! - **L1 (python/compile/kernels/)**: fused Pallas MLP/GAE kernels inside
//!   those graphs, validated against pure-jnp oracles.
//!
//! Python never runs on the tuning path: [`runtime::Engine`] loads the HLO
//! text via PJRT (`xla` crate) and the MARL hot loop calls it directly.
//! See DESIGN.md for the full system inventory and experiment index.

pub mod util;
pub mod workload;
pub mod vta;
pub mod space;
pub mod codegen;
pub mod costmodel;
pub mod ml;
pub mod runtime;
pub mod marl;
pub mod baselines;
pub mod tuner;
pub mod config;
pub mod report;
