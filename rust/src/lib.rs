//! # ARCO — Adaptive MARL-based HW/SW co-optimization compiler (reproduction)
//!
//! A three-layer reproduction of "ARCO: Adaptive Multi-Agent Reinforcement
//! Learning-Based Hardware/Software Co-Optimization Compiler for Improved
//! Performance in DNN Accelerator Design" (Fayyazi, Kamal, Pedram).
//!
//! - **L3 (this crate)**: the co-optimizing compiler — VTA++ simulator,
//!   design space, code generator, MAPPO MARL exploration with Confidence
//!   Sampling, AutoTVM/CHAMELEON baselines, tuning orchestrator, reports.
//! - **L2 (python/compile/model.py)**: MAPPO policy/critic graphs and train
//!   steps in JAX, AOT-lowered once to `artifacts/*.hlo.txt`.
//! - **L1 (python/compile/kernels/)**: fused Pallas MLP/GAE kernels inside
//!   those graphs, validated against pure-jnp oracles.
//!
//! Python never runs on the tuning path: [`runtime::Engine`] loads the HLO
//! text via PJRT (`xla` crate) and the MARL hot loop calls it directly.
//! See DESIGN.md for the full system inventory and experiment index.
//!
//! ## The measurement layer
//!
//! Every framework's bottleneck is the hardware-measurement call `f[τ(Θ)]`
//! (§2.3). All of those calls flow through one seam: [`eval::Engine`].
//! The engine takes *batches* of [`space::PointConfig`]s, deduplicates
//! within each batch, serves repeats from a concurrent point-keyed cache
//! (keyed on decoded knob values, so frameworks and spaces share entries;
//! optionally LRU-bounded for long-lived services), coalesces points a
//! concurrent batch is already measuring, fans unique misses out over the
//! [`util::pool`] worker threads, and can persist every measurement to a
//! fingerprinted append-only JSONL journal for cross-process reuse.
//! Backends are pluggable via [`eval::MeasureBackend`]:
//! [`eval::VtaSimBackend`] is the cycle-accurate decode → lower → simulate
//! oracle, [`eval::AnalyticalBackend`] a roofline proxy for smoke runs
//! (`arco ... --backend analytical`), and [`eval::RemoteBackend`] shards
//! batches across a fleet of `arco serve-measure` processes
//! (`--backend remote:host:port[,...]`), with retry and re-dispatch when
//! a shard dies mid-batch.

pub mod util;
pub mod workload;
pub mod vta;
pub mod space;
pub mod codegen;
pub mod eval;
pub mod costmodel;
pub mod ml;
pub mod runtime;
pub mod marl;
pub mod baselines;
pub mod tuner;
pub mod config;
pub mod report;
pub mod devcheck;
