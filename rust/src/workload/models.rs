//! The model zoo of the paper's evaluation (Table 3): AlexNet, VGG-11/13/16/19
//! and ResNet-18/34, as extracted from MXNet's ImageNet model definitions.
//!
//! The paper counts one "convolution task" per convolution layer:
//! AlexNet 5, VGG-11 8, VGG-13 10, VGG-16 13, VGG-19 16, ResNet-18 17,
//! ResNet-34 33 (ResNet downsample 1x1 projections are folded into their
//! blocks by TVM's task extraction and are not counted — we follow that).
//! Tuners work on *unique* shapes ([`ModelSpec::unique_tasks`]); end-to-end
//! inference time is the weight-of-shape-multiplied sum.

use super::conv::Conv2dTask;

/// A network: ordered convolution layers (one entry per layer).
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: &'static str,
    pub layers: Vec<Conv2dTask>,
}

impl ModelSpec {
    /// Unique tunable tasks with their layer multiplicities, in first
    /// appearance order.
    pub fn unique_tasks(&self) -> Vec<(Conv2dTask, usize)> {
        let mut out: Vec<(Conv2dTask, usize)> = Vec::new();
        for layer in &self.layers {
            if let Some(slot) = out.iter_mut().find(|(t, _)| t == layer) {
                slot.1 += 1;
            } else {
                out.push((*layer, 1));
            }
        }
        out
    }

    /// Total conv FLOPs of one inference.
    pub fn total_flops(&self) -> u64 {
        self.layers.iter().map(|l| l.flops()).sum()
    }

    /// Number of convolution tasks (= layers), the Table 3 column.
    pub fn num_conv_tasks(&self) -> usize {
        self.layers.len()
    }
}

fn conv(ci: usize, hw: usize, co: usize, k: usize, s: usize, p: usize) -> Conv2dTask {
    Conv2dTask::new(1, ci, hw, hw, co, k, k, s, p)
}

fn alexnet() -> ModelSpec {
    ModelSpec {
        name: "alexnet",
        layers: vec![
            conv(3, 224, 64, 11, 4, 2),
            conv(64, 27, 192, 5, 1, 2),
            conv(192, 13, 384, 3, 1, 1),
            conv(384, 13, 256, 3, 1, 1),
            conv(256, 13, 256, 3, 1, 1),
        ],
    }
}

/// VGG stage plan: (convs per stage) over channels [64,128,256,512,512]
/// at spatial sizes [224,112,56,28,14]; every conv is 3x3 s1 p1.
fn vgg(name: &'static str, per_stage: [usize; 5]) -> ModelSpec {
    let chans = [64usize, 128, 256, 512, 512];
    let sizes = [224usize, 112, 56, 28, 14];
    let mut layers = Vec::new();
    let mut in_c = 3usize;
    for stage in 0..5 {
        let out_c = chans[stage];
        for _ in 0..per_stage[stage] {
            layers.push(conv(in_c, sizes[stage], out_c, 3, 1, 1));
            in_c = out_c;
        }
    }
    ModelSpec { name, layers }
}

/// ResNet basic-block stage plan (blocks per stage), channels
/// [64,128,256,512] at sizes [56,28,14,7]; stride-2 entry conv from stage 2.
fn resnet(name: &'static str, blocks: [usize; 4]) -> ModelSpec {
    let chans = [64usize, 128, 256, 512];
    let sizes = [56usize, 28, 14, 7];
    let mut layers = vec![conv(3, 224, 64, 7, 2, 3)];
    let mut in_c = 64usize;
    for stage in 0..4 {
        let out_c = chans[stage];
        for block in 0..blocks[stage] {
            if stage > 0 && block == 0 {
                // Downsampling entry conv: operates on the previous stage's
                // spatial size with stride 2.
                layers.push(conv(in_c, sizes[stage - 1], out_c, 3, 2, 1));
            } else {
                layers.push(conv(in_c, sizes[stage], out_c, 3, 1, 1));
            }
            layers.push(conv(out_c, sizes[stage], out_c, 3, 1, 1));
            in_c = out_c;
        }
    }
    ModelSpec { name, layers }
}

/// All zoo model names in the paper's presentation order.
pub fn model_names() -> Vec<&'static str> {
    vec!["alexnet", "vgg11", "vgg13", "vgg16", "vgg19", "resnet18", "resnet34"]
}

/// Look up a zoo model by name.
pub fn model_by_name(name: &str) -> Option<ModelSpec> {
    match name {
        "alexnet" => Some(alexnet()),
        "vgg11" => Some(vgg("vgg11", [1, 1, 2, 2, 2])),
        "vgg13" => Some(vgg("vgg13", [2, 2, 2, 2, 2])),
        "vgg16" => Some(vgg("vgg16", [2, 2, 3, 3, 3])),
        "vgg19" => Some(vgg("vgg19", [2, 2, 4, 4, 4])),
        "resnet18" => Some(resnet("resnet18", [2, 2, 2, 2])),
        "resnet34" => Some(resnet("resnet34", [3, 4, 6, 3])),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_task_counts() {
        // The Table 3 column this zoo must reproduce exactly.
        let expect = [
            ("alexnet", 5),
            ("vgg11", 8),
            ("vgg13", 10),
            ("vgg16", 13),
            ("vgg19", 16),
            ("resnet18", 17),
            ("resnet34", 33),
        ];
        for (name, count) in expect {
            let m = model_by_name(name).unwrap();
            assert_eq!(m.num_conv_tasks(), count, "{name}");
        }
    }

    #[test]
    fn vgg16_flops_match_literature() {
        // VGG-16 convs are ~15.3 GFLOPs (30.7G with 2 FLOPs/MAC convention).
        let m = model_by_name("vgg16").unwrap();
        let gflops = m.total_flops() as f64 / 1e9;
        assert!((gflops - 30.7).abs() < 1.0, "vgg16 conv GFLOPs {gflops}");
    }

    #[test]
    fn resnet18_flops_match_literature() {
        // ResNet-18 is ~1.8 GFLOPs; convs dominate (~3.6G at 2 FLOPs/MAC).
        let m = model_by_name("resnet18").unwrap();
        let gflops = m.total_flops() as f64 / 1e9;
        assert!((2.5..4.5).contains(&gflops), "resnet18 conv GFLOPs {gflops}");
    }

    #[test]
    fn unique_tasks_weights_sum_to_layers() {
        for name in model_names() {
            let m = model_by_name(name).unwrap();
            let uniq = m.unique_tasks();
            let total: usize = uniq.iter().map(|(_, w)| w).sum();
            assert_eq!(total, m.layers.len(), "{name}");
            // Dedup actually reduces VGG/ResNet task lists.
            if name.starts_with("vgg") || name.starts_with("resnet") {
                assert!(uniq.len() < m.layers.len(), "{name} should have repeated shapes");
            }
        }
    }

    #[test]
    fn all_layer_shapes_valid() {
        for name in model_names() {
            let m = model_by_name(name).unwrap();
            for l in &m.layers {
                assert!(l.oh() > 0 && l.ow() > 0, "{name} {l:?}");
                assert!(l.kh <= l.h + 2 * l.pad, "{name} {l:?}");
            }
        }
    }

    #[test]
    fn resnet_spatial_chain_consistent() {
        // Each layer's output spatial size must equal the next layer's input
        // size (basic-block main path). conv1 is followed by a 2x2-stride
        // maxpool (112 -> 56), so the chain check starts after it.
        let m = model_by_name("resnet34").unwrap();
        for pair in m.layers[1..].windows(2) {
            assert_eq!(pair[0].oh(), pair[1].h, "{:?} -> {:?}", pair[0], pair[1]);
        }
    }

    #[test]
    fn vgg_spatial_chain_halves_per_stage() {
        let m = model_by_name("vgg19").unwrap();
        let sizes: Vec<usize> = m.layers.iter().map(|l| l.h).collect();
        assert_eq!(sizes[0], 224);
        assert_eq!(*sizes.last().unwrap(), 14);
    }

    #[test]
    fn unknown_model_is_none() {
        assert!(model_by_name("mobilenet").is_none());
    }
}
