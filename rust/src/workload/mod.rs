//! DNN workloads: convolution task definitions and the model zoo used by the
//! paper's evaluation (Table 3).

pub mod conv;
pub mod models;

pub use conv::Conv2dTask;
pub use models::{model_by_name, model_names, ModelSpec};
