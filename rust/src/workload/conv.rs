//! 2-D convolution task descriptions.
//!
//! A task is one tunable unit of work, matching TVM's notion of a
//! convolution "task" extracted from a network: a unique
//! (N, CI, H, W, CO, KH, KW, stride, pad) shape. The tuners optimize each
//! task independently and the end-to-end inference time of a network is the
//! weighted sum of its tasks' runtimes (weight = how many layers share that
//! shape).

use crate::util::json::stream::{Reader, StreamWriter, Token};
use crate::util::json::Json;
use std::io;

/// One convolution workload shape (NCHW).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Conv2dTask {
    /// Batch size.
    pub n: usize,
    /// Input channels.
    pub ci: usize,
    /// Input spatial height.
    pub h: usize,
    /// Input spatial width.
    pub w: usize,
    /// Output channels.
    pub co: usize,
    /// Kernel height.
    pub kh: usize,
    /// Kernel width.
    pub kw: usize,
    /// Stride (same in both dims, as in all zoo networks).
    pub stride: usize,
    /// Zero padding (same on all sides).
    pub pad: usize,
}

impl Conv2dTask {
    pub const fn new(
        n: usize,
        ci: usize,
        h: usize,
        w: usize,
        co: usize,
        kh: usize,
        kw: usize,
        stride: usize,
        pad: usize,
    ) -> Self {
        Conv2dTask { n, ci, h, w, co, kh, kw, stride, pad }
    }

    /// Output spatial height.
    pub fn oh(&self) -> usize {
        (self.h + 2 * self.pad - self.kh) / self.stride + 1
    }

    /// Output spatial width.
    pub fn ow(&self) -> usize {
        (self.w + 2 * self.pad - self.kw) / self.stride + 1
    }

    /// Multiply-accumulate count of the direct convolution.
    pub fn macs(&self) -> u64 {
        (self.n * self.co * self.oh() * self.ow()) as u64 * (self.ci * self.kh * self.kw) as u64
    }

    /// FLOPs (2 per MAC), the numerator of the GFLOPS metric in Fig 7.
    pub fn flops(&self) -> u64 {
        2 * self.macs()
    }

    /// Input tensor element count (padded input not included).
    pub fn input_elems(&self) -> u64 {
        (self.n * self.ci * self.h * self.w) as u64
    }

    /// Weight tensor element count.
    pub fn weight_elems(&self) -> u64 {
        (self.co * self.ci * self.kh * self.kw) as u64
    }

    /// Output tensor element count.
    pub fn output_elems(&self) -> u64 {
        (self.n * self.co * self.oh() * self.ow()) as u64
    }

    /// Arithmetic intensity in MACs per byte moved (int8 inputs/weights,
    /// int32 accumulators), a rough roofline coordinate for the simulator.
    pub fn arithmetic_intensity(&self) -> f64 {
        let bytes = self.input_elems() + self.weight_elems() + 4 * self.output_elems();
        self.macs() as f64 / bytes as f64
    }

    /// Short display id like `c 3x224x224 -> 64 k7s2p3`.
    pub fn short_id(&self) -> String {
        format!(
            "c{}x{}x{}-{}k{}s{}p{}",
            self.ci, self.h, self.w, self.co, self.kh, self.stride, self.pad
        )
    }

    /// JSON encoding used by reports and golden tests.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("n", Json::num(self.n as f64)),
            ("ci", Json::num(self.ci as f64)),
            ("h", Json::num(self.h as f64)),
            ("w", Json::num(self.w as f64)),
            ("co", Json::num(self.co as f64)),
            ("kh", Json::num(self.kh as f64)),
            ("kw", Json::num(self.kw as f64)),
            ("stride", Json::num(self.stride as f64)),
            ("pad", Json::num(self.pad as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Option<Self> {
        Some(Conv2dTask {
            n: v.get_usize("n")?,
            ci: v.get_usize("ci")?,
            h: v.get_usize("h")?,
            w: v.get_usize("w")?,
            co: v.get_usize("co")?,
            kh: v.get_usize("kh")?,
            kw: v.get_usize("kw")?,
            stride: v.get_usize("stride")?,
            pad: v.get_usize("pad")?,
        })
    }

    /// Streaming twin of [`Self::to_json`]`.dump()`: same fields, same
    /// order, byte-identical output, no intermediate tree.
    pub fn write_stream<W: io::Write>(&self, w: &mut StreamWriter<W>) -> io::Result<()> {
        w.begin_obj()?;
        w.key("n")?;
        w.usize_val(self.n)?;
        w.key("ci")?;
        w.usize_val(self.ci)?;
        w.key("h")?;
        w.usize_val(self.h)?;
        w.key("w")?;
        w.usize_val(self.w)?;
        w.key("co")?;
        w.usize_val(self.co)?;
        w.key("kh")?;
        w.usize_val(self.kh)?;
        w.key("kw")?;
        w.usize_val(self.kw)?;
        w.key("stride")?;
        w.usize_val(self.stride)?;
        w.key("pad")?;
        w.usize_val(self.pad)?;
        w.end_obj()
    }

    /// Streaming decode in value position: consumes one complete object.
    /// Field-order-insensitive; unknown fields are skipped lazily.
    pub fn from_stream(r: &mut Reader<'_>) -> Option<Self> {
        if !matches!(r.next_token()?, Token::ObjStart) {
            return None;
        }
        let mut n = None;
        let mut ci = None;
        let mut h = None;
        let mut wd = None;
        let mut co = None;
        let mut kh = None;
        let mut kw = None;
        let mut stride = None;
        let mut pad = None;
        loop {
            match r.next_token()? {
                Token::ObjEnd => break,
                Token::Key(k) => {
                    let slot = match k.as_ref() {
                        "n" => &mut n,
                        "ci" => &mut ci,
                        "h" => &mut h,
                        "w" => &mut wd,
                        "co" => &mut co,
                        "kh" => &mut kh,
                        "kw" => &mut kw,
                        "stride" => &mut stride,
                        "pad" => &mut pad,
                        _ => {
                            r.skip_value().ok()?;
                            continue;
                        }
                    };
                    match r.next_token()? {
                        Token::Num(v) => *slot = Some(v.as_usize()?),
                        _ => return None,
                    }
                }
                _ => return None,
            }
        }
        Some(Conv2dTask {
            n: n?,
            ci: ci?,
            h: h?,
            w: wd?,
            co: co?,
            kh: kh?,
            kw: kw?,
            stride: stride?,
            pad: pad?,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // ResNet-18 conv1: 3x224x224 -> 64, k7 s2 p3.
    const RESNET_C1: Conv2dTask = Conv2dTask::new(1, 3, 224, 224, 64, 7, 7, 2, 3);

    #[test]
    fn output_dims() {
        assert_eq!(RESNET_C1.oh(), 112);
        assert_eq!(RESNET_C1.ow(), 112);
        // 3x3 same conv preserves dims.
        let t = Conv2dTask::new(1, 64, 56, 56, 64, 3, 3, 1, 1);
        assert_eq!(t.oh(), 56);
        assert_eq!(t.ow(), 56);
    }

    #[test]
    fn macs_known_value() {
        // 1*64*112*112 * 3*7*7 = 802816 * 147 = 118013952
        assert_eq!(RESNET_C1.macs(), 118_013_952);
        assert_eq!(RESNET_C1.flops(), 236_027_904);
    }

    #[test]
    fn tensor_sizes() {
        assert_eq!(RESNET_C1.input_elems(), 3 * 224 * 224);
        assert_eq!(RESNET_C1.weight_elems(), 64 * 3 * 7 * 7);
        assert_eq!(RESNET_C1.output_elems(), 64 * 112 * 112);
    }

    #[test]
    fn intensity_positive_and_sane() {
        let ai = RESNET_C1.arithmetic_intensity();
        assert!(ai > 1.0 && ai < 1000.0, "{ai}");
    }

    #[test]
    fn json_roundtrip() {
        let v = RESNET_C1.to_json();
        let back = Conv2dTask::from_json(&v).unwrap();
        assert_eq!(back, RESNET_C1);
    }

    #[test]
    fn short_id_stable() {
        assert_eq!(RESNET_C1.short_id(), "c3x224x224-64k7s2p3");
    }
}
